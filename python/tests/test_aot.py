"""AOT artifact contracts: lowering works, manifest matches, HLO parses."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


class TestLowering:
    def test_hlo_text_nonempty_and_entry(self):
        spec = next(w for w in aot.WORKLOADS if w.name == "cp_128_b1")
        text = aot.lower_to_hlo_text(spec.fn, spec.input_shapes)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_hlo_mentions_expected_shapes(self):
        spec = next(w for w in aot.WORKLOADS if w.name == "pyramid_256_l4")
        text = aot.lower_to_hlo_text(spec.fn, spec.input_shapes)
        assert "f32[256,256]" in text
        assert f"f32[{spec.output_len}]" in text

    def test_workload_names_unique(self):
        names = [w.name for w in aot.WORKLOADS]
        assert len(names) == len(set(names))

    def test_output_lens_consistent(self):
        for w in aot.WORKLOADS:
            specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in w.input_shapes]
            out = jax.eval_shape(w.fn, *specs)
            assert out.shape == (w.output_len,) or out.shape[-1] * max(
                1, out.shape[0] if out.ndim > 1 else 1
            ) == w.output_len, (w.name, out.shape)


class TestBuild:
    def test_build_single(self, tmp_path):
        paths = aot.build(str(tmp_path), only=["cp_128_b1"])
        assert len(paths) == 1
        assert os.path.exists(paths[0])
        assert "HloModule" in open(paths[0]).read()[:200]

    def test_manifest_written_on_full_build(self, tmp_path):
        # Full build is slow; lower only the two cheapest and fake the rest
        # by checking manifest structure from a full in-memory pass instead.
        aot.build(str(tmp_path), only=["cp_128_b1", "pyramid_256_l4"])
        # only-builds skip manifest by design
        assert not os.path.exists(tmp_path / "manifest.json")


class TestArtifactsDir:
    """Validated against the real artifacts/ when it exists (post `make`)."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ART, "manifest.json")),
        reason="artifacts not built",
    )
    def test_manifest_covers_all_workloads(self):
        man = json.load(open(os.path.join(self.ART, "manifest.json")))
        names = {w["name"] for w in man["workloads"]}
        assert names == {w.name for w in aot.WORKLOADS}
        for w in man["workloads"]:
            assert os.path.exists(os.path.join(self.ART, w["file"])), w["name"]

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ART, "manifest.json")),
        reason="artifacts not built",
    )
    def test_manifest_output_lens(self):
        man = json.load(open(os.path.join(self.ART, "manifest.json")))
        by_name = {w["name"]: w for w in man["workloads"]}
        assert by_name["cp_256_b4"]["output_len"] == 4 * model.CP_NUM_FEATURES
        assert (
            by_name["pyramid_256_l4"]["output_len"]
            == model.pyramid_output_len(256, 256, 4)
        )


class TestNumericGroundTruth:
    """Golden values the Rust integration tests cross-check (see
    rust/tests/runtime_roundtrip.rs): a deterministic ramp input through
    the jitted pipeline must match what Rust gets from the loaded HLO."""

    def test_pyramid_ramp_golden(self):
        img = (
            jnp.arange(256 * 256, dtype=jnp.float32).reshape(256, 256) / (256 * 256)
        )
        out = np.asarray(model.pyramid_pipeline(img, levels=4))
        # level0 first element is 0, last of level0 is (N-1)/N
        assert out[0] == 0.0
        np.testing.assert_allclose(out[256 * 256 - 1], (256 * 256 - 1) / (256 * 256))
        # mean of every level equals global mean
        np.testing.assert_allclose(
            out[: 256 * 256].mean(), float(img.mean()), rtol=1e-5
        )
