"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes and kernel parameters; every property asserts
allclose against ref.py.  Tolerances are f32-accumulation-order loose.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 1e-5, 1e-5


def rand(shape, seed=0, lo=0.0, hi=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# sep_conv2d
# ---------------------------------------------------------------------------


class TestSepConv2d:
    def test_identity_taps(self):
        x = rand((32, 48))
        taps = jnp.array([0.0, 1.0, 0.0], jnp.float32)
        out = kernels.sep_conv2d(x, taps, radius=1)
        np.testing.assert_allclose(out, x, rtol=RTOL, atol=ATOL)

    def test_constant_image_invariant(self):
        x = jnp.full((64, 64), 0.7, jnp.float32)
        taps = kernels.gaussian_taps(2.0, 5)
        out = kernels.sep_conv2d(x, taps, radius=5)
        np.testing.assert_allclose(out, x, rtol=1e-4, atol=1e-4)

    def test_matches_ref_single(self):
        x = rand((96, 128), seed=1)
        taps = kernels.gaussian_taps(1.5, 4)
        out = kernels.sep_conv2d(x, taps, radius=4)
        exp = ref.sep_conv2d_ref(x, taps, radius=4)
        np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)

    def test_matches_ref_batched(self):
        x = rand((3, 64, 80), seed=2)
        taps = kernels.gaussian_taps(2.0, 6)
        out = kernels.sep_conv2d(x, taps, radius=6)
        exp = ref.sep_conv2d_ref(x, taps, radius=6)
        assert out.shape == (3, 64, 80)
        np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)

    def test_taps_normalized(self):
        taps = kernels.gaussian_taps(3.0, 9)
        assert taps.shape == (19,)
        np.testing.assert_allclose(float(jnp.sum(taps)), 1.0, rtol=1e-6)

    def test_smoothing_reduces_variance(self):
        x = rand((128, 128), seed=3)
        taps = kernels.gaussian_taps(3.0, 8)
        out = kernels.sep_conv2d(x, taps, radius=8)
        assert float(jnp.std(out)) < float(jnp.std(x))

    @settings(max_examples=25, deadline=None)
    @given(
        h=st.integers(8, 96),
        w=st.integers(8, 96),
        b=st.integers(1, 4),
        radius=st.integers(1, 7),
        seed=st.integers(0, 2**16),
    )
    def test_property_matches_ref(self, h, w, b, radius, seed):
        x = rand((b, h, w), seed=seed)
        taps = kernels.gaussian_taps(max(radius / 2.0, 0.5), radius)
        out = kernels.sep_conv2d(x, taps, radius=radius)
        exp = ref.sep_conv2d_ref(x, taps, radius=radius)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# downsample2x
# ---------------------------------------------------------------------------


class TestDownsample2x:
    def test_exact_small(self):
        x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
        out = kernels.downsample2x(x)
        exp = ref.downsample2x_ref(x)
        np.testing.assert_allclose(out, exp, rtol=0, atol=0)

    def test_blocked_path(self):
        # height divisible by BLOCK_ROWS*2 -> multi-block grid exercised
        x = rand((1, 4 * kernels.DOWNSAMPLE_BLOCK_ROWS, 256), seed=5)
        out = kernels.downsample2x(x)
        exp = ref.downsample2x_ref(x)
        assert out.shape == (1, 2 * kernels.DOWNSAMPLE_BLOCK_ROWS, 128)
        np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)

    def test_odd_dims_rejected(self):
        with pytest.raises(ValueError):
            kernels.downsample2x(jnp.zeros((5, 4), jnp.float32))

    def test_mean_preserved(self):
        x = rand((64, 64), seed=6)
        out = kernels.downsample2x(x)
        np.testing.assert_allclose(float(jnp.mean(out)), float(jnp.mean(x)), rtol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        h2=st.integers(1, 64),
        w2=st.integers(1, 64),
        b=st.integers(1, 3),
        seed=st.integers(0, 2**16),
    )
    def test_property_matches_ref(self, h2, w2, b, seed):
        x = rand((b, 2 * h2, 2 * w2), seed=seed)
        out = kernels.downsample2x(x)
        exp = ref.downsample2x_ref(x)
        np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# masked_stats
# ---------------------------------------------------------------------------


class TestMaskedStats:
    def test_full_mask(self):
        x = rand((32, 32), seed=7)
        m = jnp.ones_like(x)
        out = kernels.masked_stats(x, m)
        np.testing.assert_allclose(float(out[0]), float(jnp.sum(x)), rtol=1e-5)
        np.testing.assert_allclose(float(out[2]), 32 * 32, rtol=0)
        np.testing.assert_allclose(float(out[3]), float(jnp.max(x)), rtol=1e-6)
        np.testing.assert_allclose(float(out[4]), float(jnp.min(x)), rtol=1e-6)

    def test_matches_ref(self):
        x = rand((128, 96), seed=8)
        m = (rand((128, 96), seed=9) > 0.5).astype(jnp.float32)
        out = kernels.masked_stats(x, m)
        exp = ref.masked_stats_ref(x, m)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)

    def test_blocked_accumulation(self):
        # multi row-block grid: H = 4 * BLOCK_ROWS
        h = 4 * kernels.STATS_BLOCK_ROWS
        x = rand((h, 64), seed=10)
        m = (rand((h, 64), seed=11) > 0.3).astype(jnp.float32)
        out = kernels.masked_stats(x, m)
        exp = ref.masked_stats_ref(x, m)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)

    def test_batched(self):
        x = rand((4, 64, 64), seed=12)
        m = (rand((4, 64, 64), seed=13) > 0.6).astype(jnp.float32)
        out = kernels.masked_stats(x, m)
        exp = ref.masked_stats_ref(x, m)
        assert out.shape == (4, kernels.STATS_WIDTH)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)

    def test_empty_mask_count_zero(self):
        x = rand((32, 32), seed=14)
        out = kernels.masked_stats(x, jnp.zeros_like(x))
        assert float(out[2]) == 0.0
        assert float(out[0]) == 0.0

    @settings(max_examples=25, deadline=None)
    @given(
        h=st.integers(4, 128),
        w=st.integers(4, 96),
        b=st.integers(1, 3),
        thresh=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_property_matches_ref(self, h, w, b, thresh, seed):
        x = rand((b, h, w), seed=seed)
        m = (rand((b, h, w), seed=seed + 1) > thresh).astype(jnp.float32)
        out = kernels.masked_stats(x, m)
        exp = ref.masked_stats_ref(x, m)
        # sentinel max/min for empty masks are equal by construction
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)
