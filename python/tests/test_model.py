"""L2 pipeline contracts: shapes, invariants, pallas-vs-ref independence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


def synth_image(size=128, n_blobs=12, seed=0):
    """Synthetic microscopy field: Gaussian blobs + illumination + noise.

    Mirrors rust workloads::synth (same qualitative structure; the rust
    generator is the one used at runtime, this one only drives tests).
    """
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    img = np.zeros((size, size), np.float32)
    for _ in range(n_blobs):
        cy, cx = rng.uniform(8, size - 8, 2)
        s = rng.uniform(2.0, 5.0)
        amp = rng.uniform(0.4, 1.0)
        img += amp * np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * s * s)))
    # vignetting illumination + background + noise
    cy = cx = size / 2
    illum = 1.0 - 0.4 * (((yy - cy) ** 2 + (xx - cx) ** 2) / (cy * cy + cx * cx))
    img = img * illum + 0.05 + rng.normal(0, 0.01, (size, size)).astype(np.float32)
    return jnp.asarray(np.clip(img, 0, 2).astype(np.float32))


class TestCellprofilerPipeline:
    def test_shape(self):
        imgs = jnp.stack([synth_image(128, seed=i) for i in range(2)])
        out = model.cellprofiler_pipeline(imgs)
        assert out.shape == (2, model.CP_NUM_FEATURES)

    def test_finite(self):
        imgs = synth_image(128, seed=3)[None]
        out = model.cellprofiler_pipeline(imgs)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_pallas_matches_ref_impl(self):
        imgs = jnp.stack([synth_image(128, seed=i) for i in range(2)])
        a = model.cellprofiler_pipeline(imgs, impl="pallas")
        b = model.cellprofiler_pipeline(imgs, impl="ref")
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    def test_foreground_brighter_than_background(self):
        imgs = synth_image(128, n_blobs=16, seed=4)[None]
        out = np.asarray(model.cellprofiler_pipeline(imgs))[0]
        feat = dict(zip(model.CP_FEATURE_NAMES, out))
        assert feat["fg_mean"] > feat["bg_mean"]
        assert 0.0 < feat["fg_fraction"] < 0.6

    def test_blob_count_scales_with_density(self):
        lo = model.cellprofiler_pipeline(synth_image(128, n_blobs=4, seed=5)[None])
        hi = model.cellprofiler_pipeline(synth_image(128, n_blobs=40, seed=5)[None])
        i = model.CP_FEATURE_NAMES.index("object_count_proxy")
        assert float(hi[0, i]) > float(lo[0, i])

    def test_blank_image_no_nans(self):
        imgs = jnp.zeros((1, 128, 128), jnp.float32)
        out = model.cellprofiler_pipeline(imgs)
        assert bool(jnp.all(jnp.isfinite(out)))


class TestStitchPipeline:
    def _tiles(self, grid=2, tile=128, overlap=16, seed=0):
        """Cut overlapping tiles out of one big field -> perfect seams."""
        side = model.stitch_montage_side(grid, tile, overlap)
        big = synth_image(side if side % 2 == 0 else side + 1, n_blobs=30, seed=seed)
        big = big[:side, :side]
        step = tile - overlap
        tiles = [
            big[r * step : r * step + tile, c * step : c * step + tile]
            for r in range(grid)
            for c in range(grid)
        ]
        return jnp.stack(tiles), big

    def test_output_len(self):
        tiles, _ = self._tiles()
        out = model.stitch_pipeline(tiles, grid=2, overlap=16)
        assert out.shape == (model.stitch_output_len(2, 128, 16),)

    def test_seam_scores_high_for_consistent_tiles(self):
        tiles, _ = self._tiles(seed=1)
        out = np.asarray(model.stitch_pipeline(tiles, grid=2, overlap=16))
        side = model.stitch_montage_side(2, 128, 16)
        scores = out[side * side :]
        assert scores.shape == (4,)
        assert (scores > 0.8).all(), scores

    def test_seam_scores_low_for_shuffled_tiles(self):
        tiles, _ = self._tiles(seed=2)
        shuffled = tiles[::-1]
        out = np.asarray(model.stitch_pipeline(shuffled, grid=2, overlap=16))
        side = model.stitch_montage_side(2, 128, 16)
        scores = out[side * side :]
        assert scores.mean() < 0.8

    def test_pallas_matches_ref_impl(self):
        tiles, _ = self._tiles(seed=3)
        a = model.stitch_pipeline(tiles, impl="pallas")
        b = model.stitch_pipeline(tiles, impl="ref")
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    def test_montage_resembles_source(self):
        tiles, big = self._tiles(seed=4)
        out = np.asarray(model.stitch_pipeline(tiles, grid=2, overlap=16))
        side = model.stitch_montage_side(2, 128, 16)
        montage = out[: side * side].reshape(side, side)
        # Normalization (flat-field divide) changes scale; check correlation.
        corr = np.corrcoef(montage.ravel(), np.asarray(big).ravel())[0, 1]
        assert corr > 0.95, corr


class TestPyramidPipeline:
    def test_output_len(self):
        img = synth_image(256, seed=0)
        out = model.pyramid_pipeline(img, levels=4)
        assert out.shape == (model.pyramid_output_len(256, 256, 4),)

    def test_level0_is_input(self):
        img = synth_image(128, seed=1)
        out = np.asarray(model.pyramid_pipeline(img, levels=3))
        np.testing.assert_allclose(out[: 128 * 128], np.asarray(img).ravel())

    def test_levels_preserve_mean(self):
        img = synth_image(256, seed=2)
        out = np.asarray(model.pyramid_pipeline(img, levels=4))
        off = 0
        m0 = float(np.mean(np.asarray(img)))
        for size in (256, 128, 64, 32):
            lvl = out[off : off + size * size]
            np.testing.assert_allclose(lvl.mean(), m0, rtol=1e-4)
            off += size * size

    def test_pallas_matches_ref_impl(self):
        img = synth_image(256, seed=3)
        a = model.pyramid_pipeline(img, impl="pallas")
        b = model.pyramid_pipeline(img, impl="ref")
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


class TestOtsu:
    def test_bimodal_separates(self):
        rng = np.random.default_rng(0)
        a = rng.normal(0.2, 0.03, 5000)
        b = rng.normal(0.8, 0.03, 5000)
        x = jnp.asarray(np.concatenate([a, b]).reshape(100, 100).astype(np.float32))
        t = float(model._otsu_threshold(x))
        # Between-class variance is flat across the empty gap between the
        # modes, so any threshold separating the classes is a valid Otsu
        # solution; assert clean separation rather than a specific value.
        assert np.quantile(a, 0.999) < t < b.min()
        frac = float((x > t).mean())
        assert abs(frac - 0.5) < 0.01
