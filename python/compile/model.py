"""Layer-2 JAX pipelines — the three "Somethings" this repo distributes.

Each pipeline mirrors one of the paper's shipped implementations:

* :func:`cellprofiler_pipeline`  — Distributed-CellProfiler: per-image
  illumination correction, smoothing, Otsu thresholding, and a fixed-width
  feature vector (the "measurement" a CellProfiler pipeline would emit).
* :func:`stitch_pipeline`        — Distributed-Fiji: per-tile flat-field
  normalization, seam cross-correlation scores, and a linear-blend montage
  of a tile grid (the canonical "large machine, one big task" workload).
* :func:`pyramid_pipeline`       — Distributed-OmeZarrCreator: an L-level
  2x average-pool pyramid, flattened+concatenated so the Rust worker can
  chunk it into a zarr-like store.

All pipelines call the Layer-1 Pallas kernels through the ``impl``
indirection so tests can swap in the pure-jnp oracles and assert the full
pipeline is kernel-implementation-independent.  Outputs are single flat
f32 vectors: xla_extension 0.5.1's tuple handling on the Rust side is
limited to 1-tuples, so each artifact returns exactly one array.
"""

from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref as kref

__all__ = [
    "cellprofiler_pipeline",
    "stitch_pipeline",
    "pyramid_pipeline",
    "CP_FEATURE_NAMES",
    "CP_NUM_FEATURES",
    "stitch_montage_side",
    "stitch_output_len",
    "pyramid_output_len",
    "HIST_BINS",
]

HIST_BINS = 64

# ---------------------------------------------------------------------------
# Kernel indirection: "pallas" (production) vs "ref" (oracle) implementations.
# ---------------------------------------------------------------------------

_IMPLS: Dict[str, Dict[str, Callable]] = {
    "pallas": {
        "sep_conv2d": kernels.sep_conv2d,
        "downsample2x": kernels.downsample2x,
        "masked_stats": kernels.masked_stats,
    },
    "ref": {
        "sep_conv2d": kref.sep_conv2d_ref,
        "downsample2x": kref.downsample2x_ref,
        "masked_stats": kref.masked_stats_ref,
    },
}


def _impl(name: str, impl: str) -> Callable:
    return _IMPLS[impl][name]


# ---------------------------------------------------------------------------
# Distributed-CellProfiler analogue
# ---------------------------------------------------------------------------

CP_FEATURE_NAMES = [
    "fg_mean",
    "fg_std",
    "fg_fraction",
    "fg_max",
    "fg_min",
    "bg_mean",
    "bg_std",
    "otsu_threshold",
    "edge_mean",
    "edge_max",
    "illum_scale",
    "raw_mean",
    "raw_std",
    "smooth_mean",
    "granularity",
    "object_count_proxy",
]
CP_NUM_FEATURES = len(CP_FEATURE_NAMES)


def _otsu_threshold(x: jax.Array) -> jax.Array:
    """Otsu's method over a HIST_BINS histogram of ``x`` (2-D image)."""
    mn, mx = jnp.min(x), jnp.max(x)
    span = jnp.maximum(mx - mn, 1e-6)
    idx = jnp.clip(((x - mn) / span * HIST_BINS).astype(jnp.int32), 0, HIST_BINS - 1)
    hist = jnp.zeros((HIST_BINS,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    p = hist / jnp.sum(hist)
    centers = mn + (jnp.arange(HIST_BINS, dtype=jnp.float32) + 0.5) * span / HIST_BINS
    w0 = jnp.cumsum(p)
    w1 = 1.0 - w0
    mu_cum = jnp.cumsum(p * centers)
    mu_t = mu_cum[-1]
    mu0 = mu_cum / jnp.maximum(w0, 1e-9)
    mu1 = (mu_t - mu_cum) / jnp.maximum(w1, 1e-9)
    between = w0 * w1 * (mu0 - mu1) ** 2
    k = jnp.argmax(between)
    return centers[k]


def _stats_features(stats: jax.Array, npix: float):
    """(sum, sumsq, count, max, min) -> (mean, std, fraction, max, min)."""
    s, s2, c, mx, mn = stats[0], stats[1], stats[2], stats[3], stats[4]
    safe_c = jnp.maximum(c, 1.0)
    mean = s / safe_c
    var = jnp.maximum(s2 / safe_c - mean * mean, 0.0)
    has = c > 0
    mean = jnp.where(has, mean, 0.0)
    std = jnp.where(has, jnp.sqrt(var), 0.0)
    mx = jnp.where(has, mx, 0.0)
    mn = jnp.where(has, mn, 0.0)
    return mean, std, c / npix, mx, mn


def _cp_single(img: jax.Array, *, sigma: float, radius: int, impl: str) -> jax.Array:
    """One (H, W) image -> (CP_NUM_FEATURES,) feature vector."""
    conv = _impl("sep_conv2d", impl)
    stats = _impl("masked_stats", impl)
    down = _impl("downsample2x", impl)
    h, w = img.shape
    npix = float(h * w)
    taps = kernels.gaussian_taps(sigma, radius)
    # Illumination correction: divide by a coarse illumination estimate
    # (heavy smooth), renormalized to mean 1 (CellProfiler's
    # CorrectIlluminationCalculate/Apply in its simplest form).  The
    # illumination filter must be much wider than the objects or it tracks
    # the blobs themselves and flattens them.  Perf (§Perf L2): instead of
    # a radius-4R conv at full resolution, estimate on a 4x-downsampled
    # image with a radius-R conv and nearest-upsample — the same effective
    # support at ~1/16 the FLOPs, and the estimate is smooth enough that
    # nearest upsampling is exact to the tolerance the divide needs.
    small = down(down(img))  # (H/4, W/4)
    wide = kernels.gaussian_taps(sigma * 2.0, radius)
    illum_small = conv(small, wide, radius=radius)
    illum = jnp.repeat(jnp.repeat(illum_small, 4, axis=0), 4, axis=1)
    illum_scale = jnp.maximum(jnp.mean(illum), 1e-6)
    corrected = img * illum_scale / jnp.maximum(illum, 1e-6)
    # Smooth + threshold + mask.
    smooth = conv(corrected, taps, radius=radius)
    t = _otsu_threshold(smooth)
    mask = (smooth > t).astype(jnp.float32)
    # Masked foreground / background statistics (fused Pallas reduction).
    fg = stats(corrected, mask)
    bg = stats(corrected, 1.0 - mask)
    fg_mean, fg_std, fg_frac, fg_max, fg_min = _stats_features(fg, npix)
    bg_mean, bg_std, _, _, _ = _stats_features(bg, npix)
    # Edge strength (central-difference gradient magnitude) on the smooth.
    gy = smooth[2:, 1:-1] - smooth[:-2, 1:-1]
    gx = smooth[1:-1, 2:] - smooth[1:-1, :-2]
    edge = jnp.sqrt(gx * gx + gy * gy)
    # Granularity proxy: energy lost by a down/up round trip.
    small = down(smooth)
    up = jnp.repeat(jnp.repeat(small, 2, axis=0), 2, axis=1)
    gran = jnp.mean(jnp.abs(smooth - up))
    # Object-count proxy: foreground area / expected blob area at ``sigma``.
    blob_area = jnp.float32(3.14159 * (3.0 * sigma) ** 2)
    count_proxy = fg[2] / jnp.maximum(blob_area, 1.0)
    return jnp.stack(
        [
            fg_mean,
            fg_std,
            fg_frac,
            fg_max,
            fg_min,
            bg_mean,
            bg_std,
            t,
            jnp.mean(edge),
            jnp.max(edge),
            illum_scale,
            jnp.mean(img),
            jnp.std(img),
            jnp.mean(smooth),
            gran,
            count_proxy,
        ]
    )


@partial(jax.jit, static_argnames=("sigma", "radius", "impl"))
def cellprofiler_pipeline(
    imgs: jax.Array, *, sigma: float = 2.0, radius: int = 6, impl: str = "pallas"
) -> jax.Array:
    """(B, H, W) image batch -> (B, CP_NUM_FEATURES) measurements."""
    return jax.vmap(lambda im: _cp_single(im, sigma=sigma, radius=radius, impl=impl))(
        imgs
    )


# ---------------------------------------------------------------------------
# Distributed-Fiji analogue: grid stitching
# ---------------------------------------------------------------------------


def stitch_montage_side(grid: int, tile: int, overlap: int) -> int:
    """Edge length of the stitched montage."""
    return grid * tile - (grid - 1) * overlap


def stitch_output_len(grid: int, tile: int, overlap: int) -> int:
    """Flat output length: montage pixels + seam scores."""
    side = stitch_montage_side(grid, tile, overlap)
    n_seams = 2 * grid * (grid - 1)
    return side * side + n_seams


def _ncc(a: jax.Array, b: jax.Array) -> jax.Array:
    """Normalized cross-correlation of two equally-shaped patches."""
    am = a - jnp.mean(a)
    bm = b - jnp.mean(b)
    denom = jnp.sqrt(jnp.sum(am * am) * jnp.sum(bm * bm))
    return jnp.sum(am * bm) / jnp.maximum(denom, 1e-9)


def _tile_weight(tile: int, overlap: int) -> jax.Array:
    """Separable linear blend ramp: 0->1 over each ``overlap`` margin."""
    up = jnp.arange(tile, dtype=jnp.float32) + 1.0
    ramp = jnp.minimum(
        jnp.minimum(up, jnp.float32(overlap)),
        jnp.minimum(up[::-1], jnp.float32(overlap)),
    ) / jnp.float32(overlap)
    return ramp[:, None] * ramp[None, :]


@partial(jax.jit, static_argnames=("grid", "overlap", "sigma", "radius", "impl"))
def stitch_pipeline(
    tiles: jax.Array,
    *,
    grid: int = 2,
    overlap: int = 16,
    sigma: float = 1.5,
    radius: int = 4,
    impl: str = "pallas",
) -> jax.Array:
    """Stitch a (grid*grid, T, T) tile stack.

    Returns a flat f32 vector: montage (row-major) followed by seam NCC
    scores (horizontal seams row-major, then vertical seams).
    """
    conv = _impl("sep_conv2d", impl)
    n, t, t2 = tiles.shape
    assert t == t2 and n == grid * grid
    taps = kernels.gaussian_taps(sigma, radius)
    # Smooth tiles (Pallas hot spot, batched) for noise-robust seam
    # scoring; the montage itself blends the raw pixels (Fiji's grid
    # stitcher registers on filtered images but composites originals).
    norm = conv(tiles, taps, radius=radius)

    # Seam scores over the shared overlap strips of the *smoothed* tiles.
    h_scores = []  # tile (r, c) vs (r, c+1)
    v_scores = []  # tile (r, c) vs (r+1, c)
    for r in range(grid):
        for c in range(grid - 1):
            left = norm[r * grid + c][:, t - overlap :]
            right = norm[r * grid + c + 1][:, :overlap]
            h_scores.append(_ncc(left, right))
    for r in range(grid - 1):
        for c in range(grid):
            top = norm[r * grid + c][t - overlap :, :]
            bot = norm[(r + 1) * grid + c][:overlap, :]
            v_scores.append(_ncc(top, bot))
    scores = jnp.stack(h_scores + v_scores)

    # Linear-blend montage: weighted accumulate + normalize.
    side = stitch_montage_side(grid, t, overlap)
    acc = jnp.zeros((side, side), jnp.float32)
    wacc = jnp.zeros((side, side), jnp.float32)
    wt = _tile_weight(t, overlap)
    step = t - overlap
    for r in range(grid):
        for c in range(grid):
            pad = ((r * step, side - t - r * step), (c * step, side - t - c * step))
            acc = acc + jnp.pad(tiles[r * grid + c] * wt, pad)
            wacc = wacc + jnp.pad(wt, pad)
    montage = acc / jnp.maximum(wacc, 1e-9)
    return jnp.concatenate([montage.reshape(-1), scores])


# ---------------------------------------------------------------------------
# Distributed-OmeZarrCreator analogue: multi-scale pyramid
# ---------------------------------------------------------------------------


def pyramid_output_len(h: int, w: int, levels: int) -> int:
    """Flat output length of a ``levels``-level pyramid over (h, w)."""
    total, ch, cw = 0, h, w
    for _ in range(levels):
        total += ch * cw
        ch //= 2
        cw //= 2
    return total


@partial(jax.jit, static_argnames=("levels", "impl"))
def pyramid_pipeline(
    img: jax.Array, *, levels: int = 4, impl: str = "pallas"
) -> jax.Array:
    """(H, W) image -> flat concat of ``levels`` pyramid levels.

    Level 0 is the input itself (ome.zarr keeps full resolution as scale
    0); each subsequent level is a 2x average-pool of the previous
    (Pallas kernel).
    """
    down = _impl("downsample2x", impl)
    parts = [img.reshape(-1)]
    cur = img
    for _ in range(levels - 1):
        cur = down(cur)
        parts.append(cur.reshape(-1))
    return jnp.concatenate(parts)
