"""AOT: lower every workload variant to HLO text + a manifest for Rust.

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text
parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Run once at build time (``make artifacts``); the Rust binary is fully
self-contained afterwards.  Python is never on the request path.

Usage:  python -m compile.aot --out-dir ../artifacts [--only NAME ...]
"""

import argparse
import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

__all__ = ["WORKLOADS", "lower_to_hlo_text", "build", "WorkloadSpec"]


@dataclass(frozen=True)
class WorkloadSpec:
    """One AOT artifact: a jitted function at a fixed input shape."""

    name: str
    kind: str  # "cellprofiler" | "stitch" | "pyramid"
    fn: Callable
    input_shapes: Tuple[Tuple[int, ...], ...]
    output_len: int
    params: Dict[str, float] = field(default_factory=dict)

    @property
    def filename(self) -> str:
        return f"{self.name}.hlo.txt"


def _cp(name: str, batch: int, size: int, sigma: float = 2.0, radius: int = 6):
    return WorkloadSpec(
        name=name,
        kind="cellprofiler",
        fn=lambda x: model.cellprofiler_pipeline(x, sigma=sigma, radius=radius),
        input_shapes=((batch, size, size),),
        output_len=batch * model.CP_NUM_FEATURES,
        params={"batch": batch, "size": size, "sigma": sigma, "radius": radius},
    )


def _stitch(name: str, grid: int, tile: int, overlap: int):
    return WorkloadSpec(
        name=name,
        kind="stitch",
        fn=lambda x: model.stitch_pipeline(x, grid=grid, overlap=overlap),
        input_shapes=((grid * grid, tile, tile),),
        output_len=model.stitch_output_len(grid, tile, overlap),
        params={"grid": grid, "tile": tile, "overlap": overlap},
    )


def _pyramid(name: str, size: int, levels: int):
    return WorkloadSpec(
        name=name,
        kind="pyramid",
        fn=lambda x: model.pyramid_pipeline(x, levels=levels),
        input_shapes=((size, size),),
        output_len=model.pyramid_output_len(size, size, levels),
        params={"size": size, "levels": levels},
    )


#: Every artifact the Rust runtime can load.  Names are stable public API:
#: the Config file's DOCKERHUB_TAG analog ("workload id") points at one.
WORKLOADS: List[WorkloadSpec] = [
    _cp("cp_128_b1", batch=1, size=128),
    _cp("cp_256_b1", batch=1, size=256),
    _cp("cp_256_b4", batch=4, size=256),
    _stitch("stitch_g2_t128_o16", grid=2, tile=128, overlap=16),
    _stitch("stitch_g3_t128_o16", grid=3, tile=128, overlap=16),
    _pyramid("pyramid_256_l4", size=256, levels=4),
    _pyramid("pyramid_512_l5", size=512, levels=5),
]


def lower_to_hlo_text(fn: Callable, input_shapes: Sequence[Tuple[int, ...]]) -> str:
    """jit -> lower -> stablehlo -> XlaComputation -> HLO text."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in input_shapes]
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _source_digest() -> str:
    """Digest of the compile package: manifest invalidation key."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        if "__pycache__" in root:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def build(out_dir: str, only: Sequence[str] = ()) -> List[str]:
    """Lower all (or ``only``) workloads into ``out_dir``; write manifest."""
    os.makedirs(out_dir, exist_ok=True)
    written = []
    manifest = {"source_digest": _source_digest(), "workloads": []}
    for spec in WORKLOADS:
        if only and spec.name not in only:
            continue
        path = os.path.join(out_dir, spec.filename)
        text = lower_to_hlo_text(spec.fn, spec.input_shapes)
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        manifest["workloads"].append(
            {
                "name": spec.name,
                "kind": spec.kind,
                "file": spec.filename,
                "input_shapes": [list(s) for s in spec.input_shapes],
                "dtype": "f32",
                "output_len": spec.output_len,
                "params": spec.params,
            }
        )
        print(f"  lowered {spec.name:24s} -> {path} ({len(text)} chars)")
    if not only:
        with open(os.path.join(out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        print(f"  wrote manifest ({len(manifest['workloads'])} workloads)")
    return written


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", nargs="*", default=[])
    args = p.parse_args()
    build(args.out_dir, args.only)


if __name__ == "__main__":
    main()
