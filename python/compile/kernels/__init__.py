"""Layer-1 Pallas kernels (build-time only; never imported at runtime).

Every kernel runs with ``interpret=True`` so its lowering is plain HLO the
CPU PJRT plugin can execute (real-TPU Mosaic lowering is compile-only on
this image — see DESIGN.md §Hardware-Adaptation).
"""

from .downsample import BLOCK_ROWS as DOWNSAMPLE_BLOCK_ROWS
from .downsample import downsample2x
from .reduce_stats import BLOCK_ROWS as STATS_BLOCK_ROWS
from .reduce_stats import STATS_WIDTH, masked_stats
from .sep_conv2d import gaussian_taps, sep_conv2d

__all__ = [
    "downsample2x",
    "masked_stats",
    "sep_conv2d",
    "gaussian_taps",
    "STATS_WIDTH",
    "DOWNSAMPLE_BLOCK_ROWS",
    "STATS_BLOCK_ROWS",
]
