"""Fused masked-statistics reduction as a Pallas kernel.

The feature-extraction hot spot of the cellprofiler-like pipeline: given
an image and a foreground mask it produces, in a single pass over the
data, the tuple

    (sum, sum_sq, count, max, min)

of masked pixel intensities.  Fusing the five reductions means the image
crosses HBM->VMEM exactly once instead of five times (arithmetic intensity
5 flops/byte instead of 1 — DESIGN.md §Perf).

The grid tiles (batch, row-blocks); partial results accumulate into the
output ref across row-block grid steps, using the standard
initialize-on-first-step pattern (well-defined under Pallas sequential
grid semantics, and exact in interpret mode).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["masked_stats", "STATS_WIDTH", "BLOCK_ROWS"]

STATS_WIDTH = 5  # sum, sum_sq, count, max, min
BLOCK_ROWS = 64

# Sentinels for empty masks; plain Python floats so the kernel body does
# not capture traced constants (pallas_call rejects captured arrays).
_NEG = -3.4e38
_POS = 3.4e38


def _kernel(x_ref, m_ref, o_ref):
    """x_ref,m_ref: (1, bh, W); o_ref: (1, STATS_WIDTH) accumulated."""
    j = pl.program_id(1)
    x = x_ref[0]
    m = m_ref[0]
    s = jnp.sum(x * m)
    s2 = jnp.sum(x * x * m)
    c = jnp.sum(m)
    mx = jnp.max(jnp.where(m > 0, x, _NEG))
    mn = jnp.min(jnp.where(m > 0, x, _POS))

    @pl.when(j == 0)
    def _init():
        o_ref[0] = jnp.stack([s, s2, c, mx, mn])

    @pl.when(j != 0)
    def _acc():
        prev = o_ref[0]
        o_ref[0] = jnp.stack(
            [
                prev[0] + s,
                prev[1] + s2,
                prev[2] + c,
                jnp.maximum(prev[3], mx),
                jnp.minimum(prev[4], mn),
            ]
        )


@jax.jit
def masked_stats(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Single-pass masked statistics.

    Args:
      x: (B, H, W) or (H, W) float32 intensities.
      mask: same shape, {0,1} float32 foreground mask.

    Returns:
      (B, 5) (or (5,)) float32: [sum, sum_sq, count, max, min].  max/min are
      sentinel-valued (+/-3.4e38) for an all-zero mask; callers guard with
      ``count``.
    """
    squeeze = x.ndim == 2
    if squeeze:
        x, mask = x[None], mask[None]
    b, h, w = x.shape
    bh = BLOCK_ROWS if h % BLOCK_ROWS == 0 and h >= BLOCK_ROWS else h
    grid = (b, h // bh)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bh, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bh, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, STATS_WIDTH), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, STATS_WIDTH), jnp.float32),
        interpret=True,
    )(x, mask.astype(jnp.float32))
    return out[0] if squeeze else out
