"""2x2 average-pool downsample as a Pallas kernel.

The hot spot of the OmeZarrCreator-like pyramid pipeline: each pyramid
level halves both spatial dims by averaging disjoint 2x2 windows.  No halo
is needed, so the grid tiles the batch dimension and row blocks directly:
input block (1, 2*bh, W) -> output block (1, bh, W//2).  Row-block tiling
keeps the VMEM-resident block at 2*bh*W*4 bytes regardless of image height
(bh=64 -> 0.5 MB for W=1024), demonstrating the HBM<->VMEM schedule the
paper's per-container workload would express with threads (DESIGN.md
§Hardware-Adaptation).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["downsample2x", "BLOCK_ROWS"]

# Output rows per grid step.  Heights are required to be multiples of this
# (the pyramid pipeline only feeds power-of-two images >= 2*BLOCK_ROWS) —
# smaller inputs fall back to a single full-height block.
BLOCK_ROWS = 64


def _kernel(x_ref, o_ref, *, bh: int, wo: int):
    """x_ref: (1, 2*bh, 2*wo) -> o_ref: (1, bh, wo) via 2x2 mean."""
    x = x_ref[0]
    a = x[0::2, 0::2]
    b = x[0::2, 1::2]
    c = x[1::2, 0::2]
    d = x[1::2, 1::2]
    o_ref[0] = (a + b + c + d) * jnp.float32(0.25)


@jax.jit
def downsample2x(x: jax.Array) -> jax.Array:
    """Average-pool ``x`` by 2 in both spatial dims.

    Args:
      x: (B, H, W) or (H, W) float32 with H, W even.

    Returns:
      (B, H//2, W//2) (or (H//2, W//2)) float32.
    """
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    b, h, w = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"downsample2x needs even dims, got {(h, w)}")
    ho, wo = h // 2, w // 2
    bh = BLOCK_ROWS if ho % BLOCK_ROWS == 0 and ho >= BLOCK_ROWS else ho
    grid = (b, ho // bh)

    out = pl.pallas_call(
        partial(_kernel, bh=bh, wo=wo),
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2 * bh, w), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((1, bh, wo), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo), jnp.float32),
        interpret=True,
    )(x)
    return out[0] if squeeze else out
