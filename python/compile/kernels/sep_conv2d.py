"""Separable 2-D convolution as a Pallas kernel.

The Gaussian-smooth hot spot shared by the cellprofiler-like and
Fiji/stitch-like pipelines.  A separable kernel w (length 2r+1) is applied
along rows then columns.  The caller pre-pads the image by r on each side
("SAME" semantics with edge replication handled by the wrapper), so the
kernel body is a pure shift-multiply-accumulate stencil: for the row pass,

    out[i, :] = sum_k w[k] * x[i + k, :]

which maps onto the TPU VPU as vectorized row ops (no im2col, no MXU waste
on tiny stencils — see DESIGN.md §Hardware-Adaptation).  The grid iterates
over the batch dimension: one image per grid step, so each block is a
single padded image resident in VMEM (<= 4.3 MB for 1024^2 f32; within the
~16 MB VMEM budget).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sep_conv2d", "gaussian_taps"]


def gaussian_taps(sigma: float, radius: int) -> jax.Array:
    """Normalized 1-D Gaussian taps of length 2*radius+1 (f32)."""
    x = jnp.arange(-radius, radius + 1, dtype=jnp.float32)
    w = jnp.exp(-0.5 * (x / jnp.float32(sigma)) ** 2)
    return w / jnp.sum(w)


def _row_pass(x, w, radius, h):
    # x: (h + 2r, W)   out: (h, W)
    acc = jnp.zeros((h, x.shape[1]), dtype=jnp.float32)
    for k in range(2 * radius + 1):
        acc = acc + w[k] * jax.lax.dynamic_slice_in_dim(x, k, h, axis=0)
    return acc


def _col_pass(x, w, radius, wd):
    # x: (H, wd + 2r)  out: (H, wd)
    acc = jnp.zeros((x.shape[0], wd), dtype=jnp.float32)
    for k in range(2 * radius + 1):
        acc = acc + w[k] * jax.lax.dynamic_slice_in_dim(x, k, wd, axis=1)
    return acc


def _kernel(x_ref, w_ref, o_ref, *, radius: int, h: int, wd: int):
    """One padded image -> one smoothed image.

    x_ref: (1, h+2r, wd+2r) padded block; w_ref: (2r+1,) taps;
    o_ref: (1, h, wd).
    """
    x = x_ref[0]
    w = w_ref[...]
    rows = _row_pass(x, w, radius, h)            # (h, wd + 2r)
    o_ref[0] = _col_pass(rows, w, radius, wd)    # (h, wd)


@partial(jax.jit, static_argnames=("radius",))
def sep_conv2d(x: jax.Array, taps: jax.Array, *, radius: int) -> jax.Array:
    """Separable 2-D convolution with edge-replicate padding.

    Args:
      x: (B, H, W) or (H, W) float32 image batch.
      taps: (2*radius+1,) separable filter taps.
      radius: static stencil radius.

    Returns:
      Smoothed array of the same shape as ``x``.
    """
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    b, h, wd = x.shape
    xp = jnp.pad(x, ((0, 0), (radius, radius), (radius, radius)), mode="edge")

    out = pl.pallas_call(
        partial(_kernel, radius=radius, h=h, wd=wd),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h + 2 * radius, wd + 2 * radius), lambda i: (i, 0, 0)),
            pl.BlockSpec((2 * radius + 1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, wd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, wd), jnp.float32),
        interpret=True,
    )(xp, taps.astype(jnp.float32))
    return out[0] if squeeze else out
