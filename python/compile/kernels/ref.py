"""Pure-jnp oracles for every Pallas kernel.

These are the correctness contracts: pytest asserts allclose between each
kernel and its oracle across a hypothesis sweep of shapes/params (see
python/tests/test_kernels.py).  Keep these boring and obviously right.
"""

import jax
import jax.numpy as jnp

__all__ = ["sep_conv2d_ref", "downsample2x_ref", "masked_stats_ref"]


def sep_conv2d_ref(x: jax.Array, taps: jax.Array, *, radius: int) -> jax.Array:
    """Edge-replicate separable conv via explicit shift-and-add."""
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    b, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (radius, radius), (radius, radius)), mode="edge")
    taps = taps.astype(jnp.float32)
    rows = jnp.zeros((b, h, w + 2 * radius), jnp.float32)
    for k in range(2 * radius + 1):
        rows = rows + taps[k] * xp[:, k : k + h, :]
    out = jnp.zeros((b, h, w), jnp.float32)
    for k in range(2 * radius + 1):
        out = out + taps[k] * rows[:, :, k : k + w]
    return out[0] if squeeze else out


def downsample2x_ref(x: jax.Array) -> jax.Array:
    """2x2 mean pool via reshape."""
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    b, h, w = x.shape
    out = x.reshape(b, h // 2, 2, w // 2, 2).mean(axis=(2, 4))
    return out[0] if squeeze else out


def masked_stats_ref(x: jax.Array, mask: jax.Array) -> jax.Array:
    """[sum, sum_sq, count, max, min] of masked pixels, per batch entry."""
    squeeze = x.ndim == 2
    if squeeze:
        x, mask = x[None], mask[None]
    m = mask.astype(jnp.float32)
    s = jnp.sum(x * m, axis=(1, 2))
    s2 = jnp.sum(x * x * m, axis=(1, 2))
    c = jnp.sum(m, axis=(1, 2))
    mx = jnp.max(jnp.where(m > 0, x, jnp.float32(-3.4e38)), axis=(1, 2))
    mn = jnp.min(jnp.where(m > 0, x, jnp.float32(3.4e38)), axis=(1, 2))
    out = jnp.stack([s, s2, c, mx, mn], axis=1)
    return out[0] if squeeze else out
