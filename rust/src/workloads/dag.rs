//! Canonical DAG workflow shapes for the readiness scheduler and the
//! T15 data-sharing study (DESIGN.md §11).
//!
//! Each generator returns a concrete, validated [`WorkflowSpec`] with
//! declared artifact sizes, so a rendered sweep plan embedding one is
//! hermetic — a shard worker in another process rebuilds the identical
//! DAG from the name alone via [`shape`].  Sizes are chosen to make the
//! sharing-mode axis *bite* on the standard net profile (10 Gbit/s
//! bucket, 1.25 Gbit/s NICs): artifacts are tens to hundreds of MB, so
//! staging them takes seconds to minutes, comparable to job runtimes.

use crate::workflow::WorkflowSpec;

const MB: u64 = 1_000_000;

/// Shape names accepted by [`shape`] (and therefore by `--workflow`).
pub const SHAPES: [&str; 4] = ["diamond", "fanout", "linear", "mosaic"];

/// Look up a canonical shape by name.
pub fn shape(name: &str) -> Option<WorkflowSpec> {
    match name {
        "diamond" => Some(diamond()),
        "fanout" => Some(fan_out_in()),
        "linear" => Some(linear()),
        "mosaic" => Some(mosaic()),
        _ => None,
    }
}

/// Split → four parallel branches → merge (6 nodes, 8 edges, critical
/// path 3).  The smallest shape where readiness and artifact fan-in
/// both matter.
pub fn diamond() -> WorkflowSpec {
    let mut b = WorkflowSpec::builder("diamond").job("split", 256 * MB);
    for branch in ["branch-a", "branch-b", "branch-c", "branch-d"] {
        b = b
            .job(branch, 64 * MB)
            .edge("split", branch, "tiles");
    }
    b = b.job("merge", 32 * MB);
    for branch in ["branch-a", "branch-b", "branch-c", "branch-d"] {
        b = b.edge(branch, "merge", "partial");
    }
    b.build().expect("diamond shape is valid by construction")
}

/// One source fanning out to eight workers that fan back into a sink
/// (10 nodes, 16 edges, critical path 3).  Stresses one producer
/// serving many consumers — the shape where node-local sharing contends
/// hardest on the producer's link.
pub fn fan_out_in() -> WorkflowSpec {
    let mut b = WorkflowSpec::builder("fanout").job("source", 512 * MB);
    let workers: Vec<String> = (1..=8).map(|i| format!("worker-{i}")).collect();
    for w in &workers {
        b = b.job(w, 32 * MB).edge("source", w, "shard");
    }
    b = b.job("sink", 16 * MB);
    for w in &workers {
        b = b.edge(w, "sink", "result");
    }
    b.build().expect("fanout shape is valid by construction")
}

/// Five-stage linear pipeline (5 nodes, 4 edges, critical path 5): the
/// pure serial case — sharing mode changes cost, never parallelism.
pub fn linear() -> WorkflowSpec {
    let mut b = WorkflowSpec::builder("linear");
    for i in 1..=5 {
        b = b.job(&format!("stage-{i}"), 128 * MB);
        if i > 1 {
            b = b.edge(
                &format!("stage-{}", i - 1),
                &format!("stage-{i}"),
                "frames",
            );
        }
    }
    b.build().expect("linear shape is valid by construction")
}

/// Montage-shaped mosaic (Berriman et al., PAPERS.md): 6 projections,
/// pairwise difference fits, one background model, per-tile background
/// correction, co-addition, shrink.  20 nodes, 34 edges, critical path
/// 6 — the realistic mixed shape with both wide and narrow stages.
pub fn mosaic() -> WorkflowSpec {
    let mut b = WorkflowSpec::builder("mosaic");
    for i in 1..=6 {
        b = b.job(&format!("project-{i}"), 96 * MB);
    }
    for i in 1..=5 {
        let diff = format!("diff-{i}");
        b = b
            .job(&diff, 8 * MB)
            .edge(&format!("project-{i}"), &diff, "reprojected")
            .edge(&format!("project-{}", i + 1), &diff, "reprojected");
    }
    b = b.job("fit", MB);
    for i in 1..=5 {
        b = b.edge(&format!("diff-{i}"), "fit", "fit-plane");
    }
    for i in 1..=6 {
        let bg = format!("background-{i}");
        b = b
            .job(&bg, 96 * MB)
            .edge("fit", &bg, "corrections")
            .edge(&format!("project-{i}"), &bg, "reprojected");
    }
    b = b.job("add", 256 * MB);
    for i in 1..=6 {
        b = b.edge(&format!("background-{i}"), "add", "corrected");
    }
    b = b.job("shrink", 16 * MB).edge("add", "shrink", "mosaic");
    b.build().expect("mosaic shape is valid by construction")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_shape_resolves_and_validates() {
        for name in SHAPES {
            let wf = shape(name).unwrap_or_else(|| panic!("shape {name} missing"));
            assert_eq!(wf.name, name);
            assert!(wf.node_count() > 0);
            // Validated at build: topo order covers every node.
            assert_eq!(wf.topo_order().len(), wf.node_count());
        }
        assert!(shape("moebius").is_none());
    }

    #[test]
    fn shape_topology_counts_are_pinned() {
        // (name, nodes, edges, critical path) — the describe/dry-run
        // surface prints exactly these numbers.
        let want = [
            ("diamond", 6, 8, 3),
            ("fanout", 10, 16, 3),
            ("linear", 5, 4, 5),
            ("mosaic", 20, 34, 6),
        ];
        for (name, nodes, edges, cp) in want {
            let wf = shape(name).unwrap();
            assert_eq!(wf.node_count(), nodes, "{name} nodes");
            assert_eq!(wf.edge_count(), edges, "{name} edges");
            assert_eq!(wf.critical_path_len(), cp, "{name} critical path");
        }
    }

    #[test]
    fn shapes_render_parse_round_trip() {
        for name in SHAPES {
            let wf = shape(name).unwrap();
            let back = WorkflowSpec::parse(&wf.render()).unwrap();
            assert_eq!(back, wf, "{name} round trip");
            assert_eq!(back.fingerprint(), wf.fingerprint());
        }
    }

    #[test]
    fn fingerprints_are_distinct_across_shapes() {
        let prints: Vec<u64> = SHAPES.iter().map(|n| shape(n).unwrap().fingerprint()).collect();
        for i in 0..prints.len() {
            for j in (i + 1)..prints.len() {
                assert_ne!(prints[i], prints[j], "{} vs {}", SHAPES[i], SHAPES[j]);
            }
        }
    }
}
