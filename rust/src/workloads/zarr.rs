//! Minimal zarr-like chunked multiscale store layout.
//!
//! Distributed-OmeZarrCreator converts images into `.ome.zarr`: a
//! directory tree of fixed-size chunks per resolution level plus JSON
//! metadata.  This module reproduces the *layout contract* (keys,
//! chunking, metadata) over simulated S3 — enough for the conversion
//! workload to produce a browsable, FAIR-shaped output and for
//! CHECK_IF_DONE to count its files.
//!
//! Layout, for store prefix `out/img0.zarr`:
//!   out/img0.zarr/.zattrs                 multiscales metadata
//!   out/img0.zarr/<level>/.zarray         per-level array metadata
//!   out/img0.zarr/<level>/<cy>.<cx>       raw f32 LE chunk

use crate::json::Value;

/// Chunk edge length (pixels).
pub const CHUNK: usize = 64;

/// One resolution level to write.
#[derive(Debug, Clone)]
pub struct Level {
    pub index: usize,
    pub height: usize,
    pub width: usize,
}

/// Compute the levels of a `levels`-deep pyramid over (h, w).
pub fn pyramid_levels(h: usize, w: usize, levels: usize) -> Vec<Level> {
    let mut out = Vec::with_capacity(levels);
    let (mut ch, mut cw) = (h, w);
    for index in 0..levels {
        out.push(Level {
            index,
            height: ch,
            width: cw,
        });
        ch /= 2;
        cw /= 2;
    }
    out
}

/// Number of chunk objects a level needs.
pub fn chunk_count(level: &Level) -> usize {
    level.height.div_ceil(CHUNK) * level.width.div_ceil(CHUNK)
}

/// Total objects a full store will contain (chunks + per-level .zarray +
/// one .zattrs) — what EXPECTED_NUMBER_FILES should be set to.
pub fn expected_objects(levels: &[Level]) -> usize {
    levels.iter().map(chunk_count).sum::<usize>() + levels.len() + 1
}

/// Split one level's flat image into (key_suffix, chunk_bytes) pairs.
/// Edge chunks are zero-padded to CHUNK×CHUNK (zarr pads partial chunks).
pub fn chunk_level(level: &Level, data: &[f32]) -> Vec<(String, Vec<u8>)> {
    assert_eq!(data.len(), level.height * level.width);
    let mut out = Vec::with_capacity(chunk_count(level));
    let rows = level.height.div_ceil(CHUNK);
    let cols = level.width.div_ceil(CHUNK);
    for cy in 0..rows {
        for cx in 0..cols {
            let mut chunk = vec![0f32; CHUNK * CHUNK];
            for y in 0..CHUNK {
                let sy = cy * CHUNK + y;
                if sy >= level.height {
                    break;
                }
                for x in 0..CHUNK {
                    let sx = cx * CHUNK + x;
                    if sx >= level.width {
                        break;
                    }
                    chunk[y * CHUNK + x] = data[sy * level.width + sx];
                }
            }
            out.push((
                format!("{}/{cy}.{cx}", level.index),
                super::synth::f32_to_bytes(&chunk),
            ));
        }
    }
    out
}

/// Exact byte footprint of a full store named `name`: every chunk is a
/// padded CHUNK×CHUNK f32 object, plus the per-level `.zarray` and the
/// one `.zattrs` JSON.  This is the realistic `output_bytes` for an
/// OME-Zarr conversion job in the S3 data plane — unlike a flat
/// "images/8" guess it grows with pyramid depth and chunk padding.
pub fn store_bytes(name: &str, levels: &[Level]) -> u64 {
    let chunk_bytes: u64 = levels
        .iter()
        .map(|l| chunk_count(l) as u64 * (CHUNK * CHUNK * 4) as u64)
        .sum();
    let meta_bytes: u64 = levels
        .iter()
        .map(|l| zarray_metadata(l).len() as u64)
        .sum::<u64>()
        + zattrs_metadata(name, levels).len() as u64;
    chunk_bytes + meta_bytes
}

/// `.zarray` metadata for a level.
pub fn zarray_metadata(level: &Level) -> String {
    Value::obj()
        .with("zarr_format", 2u64)
        .with(
            "shape",
            Value::Arr(vec![level.height.into(), level.width.into()]),
        )
        .with("chunks", Value::Arr(vec![CHUNK.into(), CHUNK.into()]))
        .with("dtype", "<f4")
        .with("compressor", Value::Null)
        .with("fill_value", 0.0)
        .with("order", "C")
        .pretty()
}

/// `.zattrs` multiscales metadata (OME-NGFF shaped).
pub fn zattrs_metadata(name: &str, levels: &[Level]) -> String {
    let datasets: Vec<Value> = levels
        .iter()
        .map(|l| Value::obj().with("path", l.index.to_string().as_str()))
        .collect();
    Value::obj()
        .with(
            "multiscales",
            Value::Arr(vec![Value::obj()
                .with("version", "0.4")
                .with("name", name)
                .with("datasets", Value::Arr(datasets))
                .with("type", "mean")]),
        )
        .pretty()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pyramid_levels_halve() {
        let ls = pyramid_levels(256, 256, 4);
        let dims: Vec<(usize, usize)> = ls.iter().map(|l| (l.height, l.width)).collect();
        assert_eq!(dims, vec![(256, 256), (128, 128), (64, 64), (32, 32)]);
    }

    #[test]
    fn chunk_counts() {
        let ls = pyramid_levels(256, 256, 4);
        let counts: Vec<usize> = ls.iter().map(chunk_count).collect();
        assert_eq!(counts, vec![16, 4, 1, 1]);
        // 22 chunks + 4 .zarray + 1 .zattrs
        assert_eq!(expected_objects(&ls), 27);
    }

    #[test]
    fn chunks_cover_data_exactly() {
        let level = Level {
            index: 0,
            height: 128,
            width: 128,
        };
        let data: Vec<f32> = (0..128 * 128).map(|i| i as f32).collect();
        let chunks = chunk_level(&level, &data);
        assert_eq!(chunks.len(), 4);
        // Reassemble and compare.
        let mut back = vec![0f32; 128 * 128];
        for (key, bytes) in &chunks {
            let parts: Vec<usize> = key
                .split('/')
                .nth(1)
                .unwrap()
                .split('.')
                .map(|p| p.parse().unwrap())
                .collect();
            let vals = super::super::synth::bytes_to_f32(bytes);
            for y in 0..CHUNK {
                for x in 0..CHUNK {
                    back[(parts[0] * CHUNK + y) * 128 + parts[1] * CHUNK + x] =
                        vals[y * CHUNK + x];
                }
            }
        }
        assert_eq!(back, data);
    }

    #[test]
    fn edge_chunks_padded() {
        let level = Level {
            index: 1,
            height: 96,
            width: 70,
        };
        let data = vec![1f32; 96 * 70];
        let chunks = chunk_level(&level, &data);
        assert_eq!(chunks.len(), 2 * 2);
        // Every chunk is exactly CHUNK*CHUNK f32s.
        for (_, bytes) in &chunks {
            assert_eq!(bytes.len(), CHUNK * CHUNK * 4);
        }
    }

    #[test]
    fn store_bytes_matches_materialized_objects() {
        // Build the store the pyramid driver would and sum its bodies.
        let ls = pyramid_levels(192, 160, 3);
        let mut total = zattrs_metadata("img0", &ls).len() as u64;
        for l in &ls {
            total += zarray_metadata(l).len() as u64;
            let data = vec![0.5f32; l.height * l.width];
            for (_, bytes) in chunk_level(l, &data) {
                total += bytes.len() as u64;
            }
        }
        assert_eq!(store_bytes("img0", &ls), total);
        assert!(total > (192 * 160 * 4) as u64, "padding + metadata overhead");
    }

    #[test]
    fn metadata_parses() {
        let ls = pyramid_levels(256, 256, 3);
        let za = crate::json::parse(&zarray_metadata(&ls[1])).unwrap();
        assert_eq!(za.get("dtype").unwrap().as_str(), Some("<f4"));
        let attrs = crate::json::parse(&zattrs_metadata("img0", &ls)).unwrap();
        let ms = &attrs.get("multiscales").unwrap().as_arr().unwrap()[0];
        assert_eq!(ms.get("datasets").unwrap().as_arr().unwrap().len(), 3);
    }
}
