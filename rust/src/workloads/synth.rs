//! Synthetic microscopy image generator.
//!
//! Deterministic per (plate, well, site): Gaussian-blob "cells" over a
//! vignetting illumination field plus background and sensor noise — the
//! same qualitative structure as the python test generator
//! (python/tests/test_model.py::synth_image), so the feature pipeline
//! behaves the same on both sides.  Used by the end-to-end examples to
//! stage input data into simulated S3 and by the quickstart to keep
//! everything self-contained.

use crate::sim::SimRng;

/// Parameters for one synthetic field of view.
#[derive(Debug, Clone)]
pub struct SynthImage {
    pub size: usize,
    pub n_blobs: u32,
    /// Vignetting strength 0..1 (0.4 matches the python generator).
    pub vignette: f64,
    pub background: f32,
    pub noise_sd: f32,
}

impl Default for SynthImage {
    fn default() -> Self {
        Self {
            size: 256,
            n_blobs: 24,
            vignette: 0.4,
            background: 0.05,
            noise_sd: 0.01,
        }
    }
}

/// Stable seed for a (plate, well, site) triple.
pub fn image_seed(plate: &str, well: &str, site: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in plate.bytes().chain([0]).chain(well.bytes()).chain([0]) {
        h = (h ^ u64::from(b)).wrapping_mul(0x1000_0000_01b3);
    }
    h ^ site.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

impl SynthImage {
    /// Render the field for `seed` as a flat row-major f32 image in [0, 2].
    pub fn render(&self, seed: u64) -> Vec<f32> {
        let n = self.size;
        let mut rng = SimRng::new(seed);
        let mut img = vec![0f32; n * n];
        // Blobs: amplitude 0.4-1.0, sigma 2-5 px, inside an 8 px margin.
        for _ in 0..self.n_blobs {
            let cy = rng.range_f64(8.0, (n - 8) as f64);
            let cx = rng.range_f64(8.0, (n - 8) as f64);
            let s = rng.range_f64(2.0, 5.0);
            let amp = rng.range_f64(0.4, 1.0) as f32;
            let r = (4.0 * s).ceil() as i64;
            let inv2s2 = 1.0 / (2.0 * s * s);
            let y0 = ((cy as i64) - r).max(0) as usize;
            let y1 = (((cy as i64) + r) as usize).min(n - 1);
            let x0 = ((cx as i64) - r).max(0) as usize;
            let x1 = (((cx as i64) + r) as usize).min(n - 1);
            for y in y0..=y1 {
                let dy = y as f64 - cy;
                for x in x0..=x1 {
                    let dx = x as f64 - cx;
                    img[y * n + x] += amp * (-((dy * dy + dx * dx) * inv2s2)).exp() as f32;
                }
            }
        }
        // Vignetting + background + noise, clamped to [0, 2].
        let c = n as f64 / 2.0;
        let denom = 2.0 * c * c;
        for y in 0..n {
            let dy = y as f64 - c;
            for x in 0..n {
                let dx = x as f64 - c;
                let illum = 1.0 - self.vignette * ((dy * dy + dx * dx) / denom);
                let v = img[y * n + x] * illum as f32
                    + self.background
                    + (rng.normal() as f32) * self.noise_sd;
                img[y * n + x] = v.clamp(0.0, 2.0);
            }
        }
        img
    }

    /// Render a tile grid cut from one larger field, with `overlap` shared
    /// pixels between neighbours — ground truth for the stitch workload.
    pub fn render_tiles(
        &self,
        seed: u64,
        grid: usize,
        tile: usize,
        overlap: usize,
    ) -> Vec<Vec<f32>> {
        let side = grid * tile - (grid - 1) * overlap;
        let big = SynthImage {
            size: side,
            n_blobs: (self.n_blobs as usize * side * side / (self.size * self.size))
                .max(4) as u32,
            ..self.clone()
        }
        .render(seed);
        let step = tile - overlap;
        let mut tiles = Vec::with_capacity(grid * grid);
        for r in 0..grid {
            for c in 0..grid {
                let mut t = Vec::with_capacity(tile * tile);
                for y in 0..tile {
                    let row = (r * step + y) * side + c * step;
                    t.extend_from_slice(&big[row..row + tile]);
                }
                tiles.push(t);
            }
        }
        tiles
    }
}

/// Data shape of one job for the S3 data plane: `(input_bytes,
/// output_bytes)`.  Inputs draw log-normally around `mean_input_bytes`
/// (cv 0.35 — microscopy fields compress unevenly); outputs follow at
/// roughly an 8:1 reduction (cv 0.2) — the raw-images-in,
/// measurement-tables-out shape of a CellProfiler batch.  Deterministic
/// per seed, so a Job file built from it replays bit-identically.
pub fn job_data_shape(seed: u64, mean_input_bytes: u64) -> (u64, u64) {
    if mean_input_bytes == 0 {
        return (0, 0);
    }
    let mut rng = SimRng::new(seed ^ 0xDA7A_5EED);
    let input = rng.lognormal_mean_cv(mean_input_bytes as f64, 0.35).max(1.0);
    let output = rng.lognormal_mean_cv(input / 8.0, 0.2).max(1.0);
    (input.round() as u64, output.round() as u64)
}

/// f32 slice → little-endian bytes (S3 object body).
pub fn f32_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Little-endian bytes → f32 vec.
pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let gen = SynthImage::default();
        let a = gen.render(image_seed("P1", "A01", 0));
        let b = gen.render(image_seed("P1", "A01", 0));
        assert_eq!(a, b);
        let c = gen.render(image_seed("P1", "A01", 1));
        assert_ne!(a, c);
    }

    #[test]
    fn seeds_distinct_across_metadata() {
        let s1 = image_seed("P1", "A01", 0);
        let s2 = image_seed("P1", "A02", 0);
        let s3 = image_seed("P2", "A01", 0);
        // "P1","A01" vs "P1A","01" must differ too (separator byte).
        let s4 = image_seed("P1A", "01", 0);
        assert!(s1 != s2 && s1 != s3 && s1 != s4);
    }

    #[test]
    fn values_in_range_and_blobs_visible() {
        let gen = SynthImage {
            size: 128,
            ..Default::default()
        };
        let img = gen.render(42);
        assert_eq!(img.len(), 128 * 128);
        assert!(img.iter().all(|&v| (0.0..=2.0).contains(&v)));
        let max = img.iter().cloned().fold(0.0f32, f32::max);
        let mean = img.iter().sum::<f32>() / img.len() as f32;
        assert!(max > 0.3, "blobs should rise above background: {max}");
        assert!(mean < 0.5, "mostly background: {mean}");
    }

    #[test]
    fn tiles_share_overlap_pixels() {
        let gen = SynthImage {
            size: 128,
            noise_sd: 0.0,
            ..Default::default()
        };
        let (grid, tile, overlap) = (2, 64, 16);
        let tiles = gen.render_tiles(7, grid, tile, overlap);
        assert_eq!(tiles.len(), 4);
        // Right edge of tile (0,0) == left edge of tile (0,1).
        for y in 0..tile {
            for k in 0..overlap {
                let a = tiles[0][y * tile + (tile - overlap + k)];
                let b = tiles[1][y * tile + k];
                assert_eq!(a, b, "overlap mismatch at y={y} k={k}");
            }
        }
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&xs)), xs);
    }

    #[test]
    fn job_data_shape_distribution() {
        let mean = 64_000_000u64;
        let shapes: Vec<(u64, u64)> = (0..2_000u64).map(|i| job_data_shape(i, mean)).collect();
        // Deterministic per seed; zero mean means zero data.
        assert_eq!(shapes[7], job_data_shape(7, mean));
        assert_eq!(job_data_shape(1, 0), (0, 0));
        let in_mean = shapes.iter().map(|s| s.0 as f64).sum::<f64>() / shapes.len() as f64;
        assert!(
            (in_mean - mean as f64).abs() < mean as f64 * 0.05,
            "input mean {in_mean} should track {mean}"
        );
        for &(input, output) in &shapes {
            assert!(input >= 1 && output >= 1);
            assert!(output < input, "outputs are reductions of inputs");
        }
    }
}
