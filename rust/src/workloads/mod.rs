//! The "Something": workload data generation, drivers, and timing models.
//!
//! * [`synth`]    — deterministic synthetic microscopy images (the paper's
//!   input data, which we cannot download, simulated per DESIGN.md §2).
//! * [`drivers`]  — per-kind job drivers: turn a DS job message into PJRT
//!   inputs and the PJRT output into S3 objects (feature CSVs, stitched
//!   montages, zarr-like pyramid stores).
//! * [`duration`] — modeled job-duration distributions for scale
//!   experiments that simulate thousands of jobs without running PJRT.
//! * [`zarr`]     — minimal chunked, multiscale store layout (the
//!   Distributed-OmeZarrCreator output format).
//! * [`dag`]      — canonical DAG workflow shapes (diamond, fan-out/fan-in,
//!   Montage-shaped mosaic, linear pipeline) for the workflow scheduler.
//!
//! Demand models live elsewhere: flat Job files and DAG workflows fix
//! *what* runs, while `crate::traffic` fixes *when* it arrives — its
//! per-tenant generators feed the same executors and duration models
//! one SQS message per arrival, so every workload kind composes with
//! open-loop multi-tenant traffic unchanged.

pub mod dag;
pub mod drivers;
pub mod duration;
pub mod synth;
pub mod zarr;

pub use drivers::{JobExecutor, JobOutcome, ModeledExecutor, PjrtExecutor};
pub use duration::DurationModel;
pub use synth::SynthImage;
