//! Job executors: turn a DS job message into work.
//!
//! The event loop is executor-agnostic: [`ModeledExecutor`] draws
//! durations from a distribution and writes placeholder outputs (scale
//! experiments); [`PjrtExecutor`] runs the real AOT-compiled pipeline via
//! PJRT and writes real feature CSVs / montages / zarr pyramids
//! (end-to-end examples).  Both see the same message schema, S3, and
//! CHECK_IF_DONE logic, so coordination behaviour is identical.

use anyhow::Result;

use crate::aws::s3::{Body, S3};
use crate::json::Value;
use crate::runtime::{PjrtRuntime, WorkloadKind};
use crate::sim::clock::SimTime;
use crate::sim::SimRng;

use super::duration::{Attempt, DurationModel};
use super::synth::{f32_to_bytes, image_seed, SynthImage};
use super::zarr;

/// Feature names, mirroring python/compile/model.py::CP_FEATURE_NAMES.
pub const CP_FEATURE_NAMES: [&str; 16] = [
    "fg_mean",
    "fg_std",
    "fg_fraction",
    "fg_max",
    "fg_min",
    "bg_mean",
    "bg_std",
    "otsu_threshold",
    "edge_mean",
    "edge_max",
    "illum_scale",
    "raw_mean",
    "raw_std",
    "smooth_mean",
    "granularity",
    "object_count_proxy",
];

/// What one job attempt produced.
#[derive(Debug)]
pub enum JobOutcome {
    /// Success: outputs land in S3 at completion time; message deleted.
    Done {
        duration: SimTime,
        /// (key, body) pairs, written under the job's output bucket.
        outputs: Vec<(String, Body)>,
        log: String,
    },
    /// The tool exited non-zero: no outputs, message not deleted.
    Failed { duration: SimTime, log: String },
    /// Wedged: never returns; the message resurfaces via the visibility
    /// timeout and the idle machine trips the CPU alarm.
    Stalled,
}

/// Read-only job context handed to executors.
pub struct JobCtx<'a> {
    pub s3: &'a mut S3,
    pub rng: &'a mut SimRng,
    pub now: SimTime,
}

/// A job executor: the inside of the Docker container.
pub trait JobExecutor {
    fn execute(&mut self, msg: &Value, ctx: &mut JobCtx) -> JobOutcome;
}

// ---------------------------------------------------------------------------
// Message-schema helpers (shared with the worker's CHECK_IF_DONE).
// ---------------------------------------------------------------------------

/// Stable tag for a job: all `Metadata_*` values joined with '/', in the
/// order they appear in the message.
pub fn job_tag(msg: &Value) -> String {
    let mut parts = Vec::new();
    if let Some(fields) = msg.as_obj() {
        for (k, v) in fields {
            if let Some(stripped) = k.strip_prefix("Metadata_") {
                let _ = stripped;
                match v {
                    Value::Str(s) => parts.push(s.clone()),
                    Value::Num(n) => parts.push(crate::json::Value::Num(*n).pretty()),
                    _ => {}
                }
            }
        }
    }
    if parts.is_empty() {
        parts.push("job".to_string());
    }
    parts.join("/")
}

/// Output bucket for a job (shared key `output_bucket`).
pub fn output_bucket(msg: &Value) -> &str {
    msg.get("output_bucket")
        .and_then(Value::as_str)
        .unwrap_or("ds-data")
}

/// Output key prefix for a job: `{output_prefix}/{job_tag}`.
pub fn job_output_prefix(msg: &Value) -> String {
    let base = msg
        .get("output_prefix")
        .and_then(Value::as_str)
        .unwrap_or("output");
    format!("{}/{}", base, job_tag(msg))
}

/// Input object key for a job: `{input_prefix}/{job_tag}.f32` — shared
/// by the executor's fetch and the run driver's HeadObject size probe so
/// metering and data access can never address different objects.
pub fn input_key(msg: &Value) -> String {
    format!(
        "{}/{}.f32",
        msg.get("input_prefix").and_then(Value::as_str).unwrap_or("input"),
        job_tag(msg)
    )
}

fn is_poison(msg: &Value) -> bool {
    msg.get("poison").and_then(Value::as_bool).unwrap_or(false)
}

// ---------------------------------------------------------------------------
// Modeled executor
// ---------------------------------------------------------------------------

/// Draws durations from a [`DurationModel`]; writes `n_outputs`
/// placeholder objects of `output_size` bytes.
pub struct ModeledExecutor {
    pub model: DurationModel,
    pub n_outputs: u32,
    pub output_size: u64,
}

impl Default for ModeledExecutor {
    fn default() -> Self {
        Self {
            model: DurationModel::default(),
            n_outputs: 1,
            output_size: 4_096,
        }
    }
}

impl JobExecutor for ModeledExecutor {
    fn execute(&mut self, msg: &Value, ctx: &mut JobCtx) -> JobOutcome {
        if is_poison(msg) {
            // Poison pill: fails quickly, forever.
            return JobOutcome::Failed {
                duration: 5_000,
                log: format!("job {}: poison input, exit 1", job_tag(msg)),
            };
        }
        match self.model.sample(ctx.rng) {
            Attempt::Stalls => JobOutcome::Stalled,
            Attempt::Fails(d) => JobOutcome::Failed {
                duration: d,
                log: format!("job {}: exit 1 after {}ms", job_tag(msg), d),
            },
            Attempt::Completes(d) => {
                let prefix = job_output_prefix(msg);
                let outputs = (0..self.n_outputs)
                    .map(|i| {
                        (
                            format!("{prefix}/out_{i}.csv"),
                            Body::Synthetic {
                                size: self.output_size,
                            },
                        )
                    })
                    .collect();
                JobOutcome::Done {
                    duration: d,
                    outputs,
                    log: format!("job {}: ok in {}ms", job_tag(msg), d),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT executor
// ---------------------------------------------------------------------------

/// Runs the real AOT workload.  Inputs come from S3 if staged
/// (`{input_prefix}/{tag}.f32`, little-endian f32), else are synthesized
/// deterministically from the job metadata — both paths exercise the same
/// downstream code.
pub struct PjrtExecutor {
    pub runtime: PjrtRuntime,
    pub workload: String,
    pub synth: SynthImage,
    /// Multiply measured wall-clock before charging sim time (1.0 = as
    /// measured; >1 emulates the paper's minutes-long CellProfiler jobs
    /// with our milliseconds-long kernels without changing any behaviour).
    pub time_scale: f64,
}

impl PjrtExecutor {
    pub fn new(runtime: PjrtRuntime, workload: &str) -> Result<Self> {
        let info = runtime.info(workload)?;
        let size = info.param_usize("size").or(info.param_usize("tile")).unwrap_or(256);
        Ok(Self {
            runtime,
            workload: workload.to_string(),
            synth: SynthImage {
                size,
                ..Default::default()
            },
            time_scale: 1.0,
        })
    }

    fn fetch_or_synth(&self, ctx: &mut JobCtx, msg: &Value, seed: u64, len: usize) -> Vec<f32> {
        let bucket = msg
            .get("input_bucket")
            .and_then(Value::as_str)
            .unwrap_or("ds-data");
        let key = input_key(msg);
        if let Ok(obj) = ctx.s3.get(bucket, &key) {
            if let Some(bytes) = obj.body.bytes() {
                let vals = super::synth::bytes_to_f32(bytes);
                if vals.len() == len {
                    return vals;
                }
            }
        }
        let img = self.synth.render(seed);
        debug_assert_eq!(img.len(), self.synth.size * self.synth.size);
        img
    }

    fn run_cellprofiler(&mut self, msg: &Value, ctx: &mut JobCtx) -> Result<JobOutcome> {
        let info = self.runtime.info(&self.workload)?.clone();
        let batch = info.param_usize("batch").unwrap_or(1);
        let size = info.param_usize("size").unwrap_or(256);
        let plate = msg
            .get("Metadata_Plate")
            .and_then(Value::as_str)
            .unwrap_or("P0")
            .to_string();
        let well = msg
            .get("Metadata_Well")
            .and_then(Value::as_str)
            .unwrap_or("A01")
            .to_string();
        let site = msg
            .get("Metadata_Site")
            .and_then(Value::as_u64)
            .unwrap_or(0);
        // Batch b processes sites [site*b, site*b+b).
        let mut input = Vec::with_capacity(batch * size * size);
        for i in 0..batch {
            let seed = image_seed(&plate, &well, site * batch as u64 + i as u64);
            input.extend(self.fetch_or_synth(ctx, msg, seed, size * size));
        }
        let (out, ms) = self.runtime.execute(&self.workload, &[input])?;
        // CSV: header + one row per site in the batch.
        let mut csv = String::from("site,");
        csv.push_str(&CP_FEATURE_NAMES.join(","));
        csv.push('\n');
        for (i, row) in out.chunks(CP_FEATURE_NAMES.len()).enumerate() {
            csv.push_str(&format!("{}", site * batch as u64 + i as u64));
            for v in row {
                csv.push_str(&format!(",{v:.6}"));
            }
            csv.push('\n');
        }
        let prefix = job_output_prefix(msg);
        Ok(JobOutcome::Done {
            duration: ((ms * self.time_scale).max(1.0)) as SimTime,
            outputs: vec![(format!("{prefix}/measurements.csv"), Body::Bytes(csv.into_bytes()))],
            log: format!("cellprofiler {plate}/{well}/{site}: {batch} site(s) in {ms:.1}ms"),
        })
    }

    fn run_stitch(&mut self, msg: &Value, ctx: &mut JobCtx) -> Result<JobOutcome> {
        let info = self.runtime.info(&self.workload)?.clone();
        let grid = info.param_usize("grid").unwrap_or(2);
        let tile = info.param_usize("tile").unwrap_or(128);
        let overlap = info.param_usize("overlap").unwrap_or(16);
        let tag = job_tag(msg);
        let seed = image_seed("stitch", &tag, 0);
        let tiles = self.synth.render_tiles(seed, grid, tile, overlap);
        let mut input = Vec::with_capacity(grid * grid * tile * tile);
        for t in &tiles {
            input.extend_from_slice(t);
        }
        let _ = ctx;
        let (out, ms) = self.runtime.execute(&self.workload, &[input])?;
        let side = grid * tile - (grid - 1) * overlap;
        let montage = &out[..side * side];
        let scores = &out[side * side..];
        let mut csv = String::from("seam,ncc\n");
        for (i, s) in scores.iter().enumerate() {
            csv.push_str(&format!("{i},{s:.6}\n"));
        }
        let prefix = job_output_prefix(msg);
        Ok(JobOutcome::Done {
            duration: ((ms * self.time_scale).max(1.0)) as SimTime,
            outputs: vec![
                (
                    format!("{prefix}/montage_{side}x{side}.f32"),
                    Body::Bytes(f32_to_bytes(montage)),
                ),
                (format!("{prefix}/seam_scores.csv"), Body::Bytes(csv.into_bytes())),
            ],
            log: format!("stitch {tag}: {grid}x{grid} grid in {ms:.1}ms, {} seams", scores.len()),
        })
    }

    fn run_pyramid(&mut self, msg: &Value, ctx: &mut JobCtx) -> Result<JobOutcome> {
        let info = self.runtime.info(&self.workload)?.clone();
        let size = info.param_usize("size").unwrap_or(256);
        let levels = info.param_usize("levels").unwrap_or(4);
        let tag = job_tag(msg);
        let seed = image_seed("zarr", &tag, 0);
        let input = self.fetch_or_synth(ctx, msg, seed, size * size);
        let (out, ms) = self.runtime.execute(&self.workload, &[input])?;
        // Slice the flat pyramid into levels and chunk each into the store.
        let lvls = zarr::pyramid_levels(size, size, levels);
        let prefix = job_output_prefix(msg);
        let store = format!("{prefix}/image.zarr");
        let mut outputs = Vec::new();
        outputs.push((
            format!("{store}/.zattrs"),
            Body::Bytes(zarr::zattrs_metadata(&tag, &lvls).into_bytes()),
        ));
        let mut off = 0usize;
        for lvl in &lvls {
            let n = lvl.height * lvl.width;
            let data = &out[off..off + n];
            off += n;
            outputs.push((
                format!("{store}/{}/.zarray", lvl.index),
                Body::Bytes(zarr::zarray_metadata(lvl).into_bytes()),
            ));
            for (suffix, bytes) in zarr::chunk_level(lvl, data) {
                outputs.push((format!("{store}/{suffix}"), Body::Bytes(bytes)));
            }
        }
        let n_out = outputs.len();
        Ok(JobOutcome::Done {
            duration: ((ms * self.time_scale).max(1.0)) as SimTime,
            outputs,
            log: format!("omezarr {tag}: {levels} levels, {n_out} objects in {ms:.1}ms"),
        })
    }
}

impl JobExecutor for PjrtExecutor {
    fn execute(&mut self, msg: &Value, ctx: &mut JobCtx) -> JobOutcome {
        if is_poison(msg) {
            return JobOutcome::Failed {
                duration: 5_000,
                log: format!("job {}: poison input, exit 1", job_tag(msg)),
            };
        }
        let kind = match self.runtime.info(&self.workload) {
            Ok(i) => i.kind,
            Err(e) => {
                return JobOutcome::Failed {
                    duration: 1_000,
                    log: format!("unknown workload: {e}"),
                }
            }
        };
        let result = match kind {
            WorkloadKind::CellProfiler => self.run_cellprofiler(msg, ctx),
            WorkloadKind::Stitch => self.run_stitch(msg, ctx),
            WorkloadKind::Pyramid => self.run_pyramid(msg, ctx),
        };
        match result {
            Ok(outcome) => outcome,
            Err(e) => JobOutcome::Failed {
                duration: 1_000,
                log: format!("job {}: error: {e:#}", job_tag(msg)),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    fn msg(text: &str) -> Value {
        parse(text).unwrap()
    }

    #[test]
    fn job_tag_joins_metadata_in_order() {
        let m = msg(
            r#"{"output_prefix": "o", "Metadata_Plate": "P1",
                "Metadata_Well": "B03", "Metadata_Site": 2, "x": 1}"#,
        );
        assert_eq!(job_tag(&m), "P1/B03/2");
        assert_eq!(job_output_prefix(&m), "o/P1/B03/2");
        assert_eq!(output_bucket(&m), "ds-data");
        // The executor's fetch and the driver's HEAD probe share this.
        assert_eq!(input_key(&m), "input/P1/B03/2.f32");
        let with_prefix = msg(r#"{"input_prefix": "raw", "Metadata_Well": "A01"}"#);
        assert_eq!(input_key(&with_prefix), "raw/A01.f32");
    }

    #[test]
    fn job_tag_fallback() {
        assert_eq!(job_tag(&msg(r#"{"a": 1}"#)), "job");
    }

    #[test]
    fn modeled_executor_success_writes_outputs() {
        let mut ex = ModeledExecutor {
            model: DurationModel {
                mean_s: 10.0,
                cv: 0.0,
                ..Default::default()
            },
            n_outputs: 3,
            output_size: 100,
        };
        let mut s3 = S3::new();
        let mut rng = SimRng::new(1);
        let mut ctx = JobCtx {
            s3: &mut s3,
            rng: &mut rng,
            now: 0,
        };
        let m = msg(r#"{"Metadata_Well": "A01"}"#);
        match ex.execute(&m, &mut ctx) {
            JobOutcome::Done {
                duration, outputs, ..
            } => {
                assert_eq!(duration, 10_000);
                assert_eq!(outputs.len(), 3);
                assert!(outputs[0].0.starts_with("output/A01/"));
            }
            other => panic!("expected Done, got {other:?}"),
        }
    }

    #[test]
    fn poison_always_fails() {
        let mut ex = ModeledExecutor::default();
        let mut s3 = S3::new();
        let mut rng = SimRng::new(2);
        let mut ctx = JobCtx {
            s3: &mut s3,
            rng: &mut rng,
            now: 0,
        };
        let m = msg(r#"{"poison": true, "Metadata_Well": "A01"}"#);
        for _ in 0..5 {
            assert!(matches!(
                ex.execute(&m, &mut ctx),
                JobOutcome::Failed { .. }
            ));
        }
    }

    #[test]
    fn stall_prob_one_always_stalls() {
        let mut ex = ModeledExecutor {
            model: DurationModel {
                stall_prob: 1.0,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut s3 = S3::new();
        let mut rng = SimRng::new(3);
        let mut ctx = JobCtx {
            s3: &mut s3,
            rng: &mut rng,
            now: 0,
        };
        assert!(matches!(
            ex.execute(&msg("{}"), &mut ctx),
            JobOutcome::Stalled
        ));
    }
}
