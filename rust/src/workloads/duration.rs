//! Modeled job durations for at-scale simulation.
//!
//! Scale experiments (T1, T3–T8) simulate thousands of jobs across
//! hundreds of machines; running PJRT for each would make the benchmark
//! about CPU floor time, not coordination.  Instead durations draw from a
//! log-normal calibrated by (mean, cv) — the canonical heavy-ish-tailed
//! shape of bioimage batch jobs — optionally anchored to a *measured*
//! PJRT latency from the end-to-end example (see EXPERIMENTS.md).

use crate::sim::clock::{from_secs_f64, SimTime};
use crate::sim::SimRng;

/// Log-normal duration model with optional stall and failure modes.
#[derive(Debug, Clone)]
pub struct DurationModel {
    /// Mean job duration, seconds.
    pub mean_s: f64,
    /// Coefficient of variation (0 = constant).
    pub cv: f64,
    /// Probability a job stalls: it never completes; its message returns
    /// via the visibility timeout (models wedged software, T4).
    pub stall_prob: f64,
    /// Probability a job fails fast (non-zero exit): message not deleted.
    pub fail_prob: f64,
}

impl Default for DurationModel {
    fn default() -> Self {
        Self {
            mean_s: 90.0,
            cv: 0.3,
            stall_prob: 0.0,
            fail_prob: 0.0,
        }
    }
}

/// What the model decided for one job attempt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Attempt {
    /// Completes after the duration.
    Completes(SimTime),
    /// Runs for the duration, then fails (message left in flight).
    Fails(SimTime),
    /// Never completes (worker wedged until externally recovered).
    Stalls,
}

impl DurationModel {
    pub fn sample(&self, rng: &mut SimRng) -> Attempt {
        if rng.chance(self.stall_prob) {
            return Attempt::Stalls;
        }
        let d = from_secs_f64(rng.lognormal_mean_cv(self.mean_s, self.cv)).max(1);
        if rng.chance(self.fail_prob) {
            Attempt::Fails(d)
        } else {
            Attempt::Completes(d)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_tracks_parameter() {
        let m = DurationModel {
            mean_s: 120.0,
            cv: 0.25,
            ..Default::default()
        };
        let mut rng = SimRng::new(1);
        let n = 20_000;
        let total: f64 = (0..n)
            .map(|_| match m.sample(&mut rng) {
                Attempt::Completes(d) => d as f64 / 1000.0,
                _ => panic!("no failures configured"),
            })
            .sum();
        let mean = total / n as f64;
        assert!((mean - 120.0).abs() < 2.0, "mean={mean}");
    }

    #[test]
    fn zero_cv_constant() {
        let m = DurationModel {
            mean_s: 10.0,
            cv: 0.0,
            ..Default::default()
        };
        let mut rng = SimRng::new(2);
        assert_eq!(m.sample(&mut rng), Attempt::Completes(10_000));
    }

    #[test]
    fn stall_and_fail_rates_approximate() {
        let m = DurationModel {
            mean_s: 5.0,
            cv: 0.1,
            stall_prob: 0.1,
            fail_prob: 0.2,
            ..Default::default()
        };
        let mut rng = SimRng::new(3);
        let n = 50_000;
        let (mut stalls, mut fails) = (0, 0);
        for _ in 0..n {
            match m.sample(&mut rng) {
                Attempt::Stalls => stalls += 1,
                Attempt::Fails(_) => fails += 1,
                Attempt::Completes(_) => {}
            }
        }
        let stall_rate = stalls as f64 / n as f64;
        // fail applies to the non-stalled 90%
        let fail_rate = fails as f64 / n as f64;
        assert!((stall_rate - 0.1).abs() < 0.01, "{stall_rate}");
        assert!((fail_rate - 0.18).abs() < 0.01, "{fail_rate}");
    }

    #[test]
    fn duration_never_zero() {
        let m = DurationModel {
            mean_s: 0.0005,
            cv: 2.0,
            ..Default::default()
        };
        let mut rng = SimRng::new(4);
        for _ in 0..1000 {
            if let Attempt::Completes(d) = m.sample(&mut rng) {
                assert!(d >= 1);
            }
        }
    }
}
