//! Step 3: `python run.py startCluster files/fleet.json`.
//!
//! "it passes account-specific configuration from the Fleet file and the
//! number and size of EC2 instances you want from the Config to launch a
//! spot fleet of instances. … Once the spot fleet is ready, DS will
//! create the log groups (if they don't already exist)."

use anyhow::{Context, Result};

use crate::aws::ec2::{FleetId, SpotFleetSpec};
use crate::aws::AwsAccount;
use crate::config::{AppConfig, FleetSpec};
use crate::sim::SimTime;

/// Submit the spot fleet request and create log groups.  Instances are
/// fulfilled asynchronously by the event loop's market ticks.  Returns
/// the fleet request id (DS writes `APP_NAMESpotFleetRequestId.json`; the
/// same id is what the monitor command consumes).
pub fn start_cluster(
    acct: &mut AwsAccount,
    cfg: &AppConfig,
    fleet_file: &FleetSpec,
    now: SimTime,
) -> Result<FleetId> {
    fleet_file.validate().context("invalid Fleet file")?;
    cfg.validate().context("invalid Config file")?;
    let fleet = acct.ec2.request_spot_fleet(SpotFleetSpec {
        target_capacity: cfg.cluster_machines,
        bid_hourly: cfg.machine_price,
        allowed_types: cfg.machine_types.clone(),
    });
    acct.logs.create_group(&cfg.log_group_name);
    acct.logs.create_group(&cfg.instance_log_group());
    let _ = now;
    Ok(fleet)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::Volatility;

    #[test]
    fn start_cluster_requests_fleet_and_logs() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default();
        let fleet_file = FleetSpec::template("us-east-1").unwrap();
        let fid = start_cluster(&mut acct, &cfg, &fleet_file, 0).unwrap();
        assert!(acct.ec2.fleet_is_active(fid));
        assert_eq!(acct.ec2.fleet_target(fid), cfg.cluster_machines);
        assert!(acct.logs.group_exists(&cfg.log_group_name));
        assert!(acct.logs.group_exists(&cfg.instance_log_group()));
        // No instances until the event loop ticks the market.
        assert_eq!(acct.ec2.active_count(fid), 0);
    }

    #[test]
    fn invalid_fleet_file_rejected() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default();
        let mut fleet_file = FleetSpec::template("us-east-1").unwrap();
        fleet_file.key_name = "key.pem".into();
        assert!(start_cluster(&mut acct, &cfg, &fleet_file, 0).is_err());
    }
}
