//! Step 3: `python run.py startCluster files/fleet.json`.
//!
//! "it passes account-specific configuration from the Fleet file and the
//! number and size of EC2 instances you want from the Config to launch a
//! spot fleet of instances. … Once the spot fleet is ready, DS will
//! create the log groups (if they don't already exist)."
//!
//! The fleet request is built from both files: the Config contributes the
//! weighted capacity target (`CLUSTER_MACHINES`) and the per-unit bid
//! (`MACHINE_PRICE`); the Fleet file contributes the launch
//! specifications (`INSTANCE_TYPES`, falling back to the Config's
//! `MACHINE_TYPE` list at weight 1), the allocation strategy, and the
//! on-demand base.

use anyhow::{ensure, Context, Result};

use crate::aws::ec2::{FleetId, InstanceSlot, SpotFleetSpec};
use crate::aws::AwsAccount;
use crate::config::{AppConfig, FleetSpec};
use crate::sim::SimTime;

/// The launch specifications a (Config, Fleet-file) pair produces: the
/// Fleet file's `INSTANCE_TYPES` when given, else the Config's
/// `MACHINE_TYPE` list at weight 1.
pub fn fleet_slots(cfg: &AppConfig, fleet_file: &FleetSpec) -> Vec<InstanceSlot> {
    if fleet_file.instance_types.is_empty() {
        cfg.machine_types
            .iter()
            .map(|t| InstanceSlot::new(t.as_str()))
            .collect()
    } else {
        fleet_file.instance_types.clone()
    }
}

/// Submit the spot fleet request and create log groups.  Instances are
/// fulfilled asynchronously by the event loop's market ticks.  Returns
/// the fleet request id (DS writes `APP_NAMESpotFleetRequestId.json`; the
/// same id is what the monitor command consumes).
pub fn start_cluster(
    acct: &mut AwsAccount,
    cfg: &AppConfig,
    fleet_file: &FleetSpec,
    now: SimTime,
) -> Result<FleetId> {
    fleet_file.validate().context("invalid Fleet file")?;
    cfg.validate().context("invalid Config file")?;
    ensure!(
        fleet_file.on_demand_base <= cfg.cluster_machines,
        "ON_DEMAND_BASE ({}) exceeds CLUSTER_MACHINES ({})",
        fleet_file.on_demand_base,
        cfg.cluster_machines
    );
    let fleet = acct.ec2.request_spot_fleet(SpotFleetSpec {
        target_capacity: cfg.cluster_machines,
        bid_hourly: cfg.machine_price,
        slots: fleet_slots(cfg, fleet_file),
        allocation: fleet_file.allocation_strategy,
        on_demand_base: fleet_file.on_demand_base,
    });
    acct.logs.create_group(&cfg.log_group_name);
    acct.logs.create_group(&cfg.instance_log_group());
    let _ = now;
    Ok(fleet)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::{AllocationStrategy, InstanceState, Lifecycle, Volatility};

    #[test]
    fn start_cluster_requests_fleet_and_logs() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default();
        let fleet_file = FleetSpec::template("us-east-1").unwrap();
        let fid = start_cluster(&mut acct, &cfg, &fleet_file, 0).unwrap();
        assert!(acct.ec2.fleet_is_active(fid));
        assert_eq!(acct.ec2.fleet_target(fid), cfg.cluster_machines);
        assert!(acct.logs.group_exists(&cfg.log_group_name));
        assert!(acct.logs.group_exists(&cfg.instance_log_group()));
        // No instances until the event loop ticks the market.
        assert_eq!(acct.ec2.active_count(fid), 0);
    }

    #[test]
    fn invalid_fleet_file_rejected() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default();
        let mut fleet_file = FleetSpec::template("us-east-1").unwrap();
        fleet_file.key_name = "key.pem".into();
        assert!(start_cluster(&mut acct, &cfg, &fleet_file, 0).is_err());
    }

    #[test]
    fn fleet_file_instance_types_override_config() {
        let cfg = AppConfig::default(); // MACHINE_TYPE = [m5.xlarge]
        let mut fleet_file = FleetSpec::template("us-east-1").unwrap();
        assert_eq!(
            fleet_slots(&cfg, &fleet_file),
            vec![InstanceSlot::new("m5.xlarge")]
        );
        fleet_file.instance_types = vec![
            InstanceSlot::new("m5.large"),
            InstanceSlot {
                name: "c5.xlarge".into(),
                weight: 2,
            },
        ];
        assert_eq!(fleet_slots(&cfg, &fleet_file), fleet_file.instance_types);
    }

    #[test]
    fn heterogeneous_fleet_with_on_demand_base_fulfills() {
        let mut acct = AwsAccount::new(3, Volatility::Low);
        let mut cfg = AppConfig::default();
        cfg.cluster_machines = 6;
        cfg.machine_price = 0.20;
        let mut fleet_file = FleetSpec::template("us-east-1").unwrap();
        fleet_file.instance_types =
            vec![InstanceSlot::new("m5.large"), InstanceSlot::new("c5.xlarge")];
        fleet_file.allocation_strategy = AllocationStrategy::Diversified;
        fleet_file.on_demand_base = 2;
        let fid = start_cluster(&mut acct, &cfg, &fleet_file, 0).unwrap();
        acct.ec2.evaluate_fleets(0);
        assert_eq!(acct.ec2.active_weight(fid), 6);
        let od: Vec<_> = acct
            .ec2
            .instances_in_state(fid, InstanceState::Pending)
            .into_iter()
            .filter(|&id| acct.ec2.instance(id).unwrap().lifecycle == Lifecycle::OnDemand)
            .collect();
        assert_eq!(od.len(), 2, "ON_DEMAND_BASE floor honored");
    }

    #[test]
    fn on_demand_base_above_target_rejected() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default(); // 4 machines
        let mut fleet_file = FleetSpec::template("us-east-1").unwrap();
        fleet_file.on_demand_base = 5;
        let err = start_cluster(&mut acct, &cfg, &fleet_file, 0).unwrap_err();
        assert!(err.to_string().contains("ON_DEMAND_BASE"));
    }
}
