//! The paper's system: four single-line commands over five services.
//!
//! * [`setup`]   — `python run.py setup` (Step 1): task definition, SQS
//!   queue + dead-letter queue, ECS service.
//! * [`submit`]  — `python run.py submitJob files/job.json` (Step 2): one
//!   SQS message per group.
//! * [`cluster`] — `python run.py startCluster files/fleet.json` (Step 3):
//!   spot fleet request + log groups.
//! * [`monitor`] — `python run.py monitor …` (Step 4, optional): queue
//!   polling, alarm reaping, downscaling, cleanup, log export, cheapest
//!   mode.
//! * [`run`]     — the discrete-event loop that advances everything
//!   (boot, placement, worker polls, job completions, crashes,
//!   interruptions, alarms).
//! * [`sweep`]   — the parallel scenario-sweep engine: a configuration
//!   matrix of independent simulations on a thread pool, aggregated into
//!   a [`SweepReport`](crate::metrics::SweepReport).
//! * [`autoscale`] — the closed-loop elastic scaling control plane:
//!   typed [`ScalingPolicy`]s driven by CloudWatch alarms on SQS
//!   metrics, applied on the monitor tick.
//! * [`shard`]   — sharded sweep execution: a versioned JSON wire
//!   contract partitioning the scenario × seed matrix across worker
//!   processes (`ds shard-worker`), supervised with timeout + bounded
//!   retry, merging bit-identically to [`run_sweep`](sweep::run_sweep).

pub mod autoscale;
pub mod cluster;
pub mod monitor;
pub mod run;
pub mod setup;
pub mod shard;
pub mod submit;
pub mod sweep;

pub use autoscale::{ScalingBreakdown, ScalingMode, ScalingPolicy};
pub use run::{EngineOptions, RunOptions, Simulation};
pub use shard::{run_sweep_sharded, shard_plan, ShardAssignment, ShardOptions};
pub use sweep::{run_sweep, Scenario, ScenarioMatrix, SweepPlan, SweepRun};
