//! Closed-loop elastic autoscaling: alarm-driven scaling policies over
//! the SQS backlog (DESIGN.md §8).
//!
//! The paper's monitor only ever *shrinks* a fleet; this module closes
//! the loop in both directions, the way AWS Application Auto Scaling
//! does it:
//!
//! 1. Every monitor tick publishes the queue's SQS metrics — visible
//!    depth, in-flight count, oldest-message age, and the derived
//!    backlog-per-capacity-unit — to CloudWatch.
//! 2. Two CloudWatch alarms watch the backlog-per-unit series: a *high*
//!    alarm (backlog per unit above the policy target) whose action is
//!    [`AlarmAction::ScaleOut`], and a *low* alarm (below half the
//!    target) whose action is [`AlarmAction::ScaleIn`].  Scaling alarms
//!    re-fire on every breaching evaluation period, so a sustained
//!    breach keeps signalling; the controller's cooldowns decide how
//!    often the fleet actually moves.
//! 3. The per-minute alarm evaluation delivers those actions to the
//!    monitor, and on its tick the [`AutoscaleState`] controller turns
//!    the pending signals into one bounded, cooldown-gated capacity
//!    decision: [`Ec2::scale_out`](crate::aws::ec2::Ec2::scale_out)
//!    launches the deficit into the fleet's existing allocation
//!    strategy mid-run, and
//!    [`Ec2::scale_in`](crate::aws::ec2::Ec2::scale_in) terminates the
//!    surplus cheapest-pool-last, exactly like the queue-downscale
//!    path.
//!
//! Everything is a pure function of the queue counters and the policy,
//! so scaled runs replay bit-identically and sweeps over scaling axes
//! stay thread-count invariant (`rust/tests/autoscale.rs` pins both).
//!
//! The controller is demand-agnostic: it sees only the backlog, so it
//! composes unchanged with open-loop multi-tenant traffic
//! (`crate::traffic`), where a heavy-tailed tenant's bursts drive the
//! backlog up and down mid-run — the T17 experiment pairs exactly this
//! loop with fair-share queueing to bound the victim tenant's wait.

use crate::aws::cloudwatch::alarms::Alarms;
use crate::aws::cloudwatch::{AlarmAction, Comparison};
use crate::aws::cloudwatch::metrics::Metrics;
use crate::aws::ec2::{FleetEvent, FleetId};
use crate::aws::AwsAccount;
use crate::config::AppConfig;
use crate::sim::clock::{SimTime, HOUR, MINUTE};

/// Metric names the monitor publishes for the scaling alarms (the SQS
/// CloudWatch names, plus the derived backlog-per-unit series the
/// policies actually track).
pub const VISIBLE_METRIC: &str = "ApproximateNumberOfMessagesVisible";
pub const IN_FLIGHT_METRIC: &str = "ApproximateNumberOfMessagesNotVisible";
pub const OLDEST_AGE_METRIC: &str = "ApproximateAgeOfOldestMessage";
pub const BACKLOG_METRIC: &str = "QueueBacklogPerUnit";

/// Which scaling policy a scenario runs (the `--scaling` axis).  `None`
/// is the paper's fixed fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ScalingMode {
    #[default]
    None,
    TargetTracking,
    Step,
}

impl ScalingMode {
    /// All modes, in a stable order (the sweep axis iterates this).
    pub const ALL: [ScalingMode; 3] = [
        ScalingMode::None,
        ScalingMode::TargetTracking,
        ScalingMode::Step,
    ];

    /// Stable kebab-case name (config-file and CLI syntax).
    pub fn name(self) -> &'static str {
        match self {
            ScalingMode::None => "none",
            ScalingMode::TargetTracking => "target-tracking",
            ScalingMode::Step => "step",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// The canonical policy for this mode at a given backlog target
    /// (`None` for the fixed fleet).
    pub fn policy(self, target_per_unit: f64) -> Option<ScalingPolicy> {
        match self {
            ScalingMode::None => None,
            ScalingMode::TargetTracking => Some(ScalingPolicy::target_tracking(target_per_unit)),
            ScalingMode::Step => Some(ScalingPolicy::step(target_per_unit)),
        }
    }
}

/// Capacity bounds and rate limits shared by every policy kind.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingLimits {
    /// Lowest target capacity the controller will ever request, >= 1.
    pub min_capacity: u32,
    /// Highest target capacity; 0 means "inherit the fleet's initial
    /// target" (resolved when the controller engages).
    pub max_capacity: u32,
    /// Minimum spacing between two applied scale-outs.
    pub scale_out_cooldown: SimTime,
    /// Minimum spacing between two applied scale-ins.
    pub scale_in_cooldown: SimTime,
    /// No scale-in within this window after engagement or after a
    /// scale-out: freshly requested capacity gets a chance to chew the
    /// backlog before the controller shrinks it again.
    pub warmup: SimTime,
}

impl Default for ScalingLimits {
    fn default() -> Self {
        Self {
            min_capacity: 1,
            max_capacity: 0,
            scale_out_cooldown: 2 * MINUTE,
            scale_in_cooldown: 5 * MINUTE,
            warmup: 5 * MINUTE,
        }
    }
}

/// One step-scaling band: when the breach ratio (backlog-per-unit over
/// the target, for scale-out; under it, for scale-in) crosses `breach`,
/// adjust capacity by `delta` units.  The deepest crossed band wins.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepRule {
    /// Breach ratio threshold: multiples of the target for scale-out
    /// bands (>= 1.0), fractions of it for scale-in bands (<= 1.0).
    pub breach: f64,
    /// Capacity units added (scale-out) or removed (scale-in).
    pub delta: u32,
}

/// How the controller computes a new capacity from the backlog.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyKind {
    /// Hold backlog-per-unit near the target: on a scale-out signal the
    /// capacity jumps straight to `ceil(backlog / target)`; on a
    /// scale-in signal it drops straight to the same figure.  One
    /// decision per breach episode usually suffices.
    TargetTracking,
    /// Classic breach-band steps: ± a fixed unit delta per band, so
    /// capacity ramps instead of jumping.
    Step {
        steps_out: Vec<StepRule>,
        steps_in: Vec<StepRule>,
    },
}

/// A typed scaling policy: what `--scaling` / `RunOptions::scaling`
/// carries and the [`AutoscaleState`] controller executes.
///
/// ```
/// use ds_rs::coordinator::autoscale::ScalingPolicy;
///
/// // Hold ~4 queued jobs per capacity unit.
/// let p = ScalingPolicy::target_tracking(4.0);
/// // 40 jobs of backlog on 2 units -> jump to ceil(40/4) = 10 units.
/// assert_eq!(p.desired_out(2, 40), 10);
/// // Empty queue -> fall to the floor (min_capacity, default 1).
/// assert_eq!(p.desired_in(10, 0), 1);
///
/// // Step scaling ramps instead of jumping.
/// let p = ScalingPolicy::step(4.0);
/// assert_eq!(p.desired_out(2, 40), 6); // 5x breach: deepest band, +4
/// assert_eq!(p.desired_in(10, 0), 8); // deepest in-band, -2
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingPolicy {
    pub kind: PolicyKind,
    /// Desired backlog (visible + in-flight messages) per weighted
    /// capacity unit.  The scale-out alarm breaches above this; the
    /// scale-in alarm breaches below [`Self::scale_in_threshold`].
    pub target_per_unit: f64,
    pub limits: ScalingLimits,
}

/// Default `--scaling-target` when only `--scaling` is given.
pub const DEFAULT_TARGET_PER_UNIT: f64 = 4.0;

/// Evaluation periods before the high (scale-out) alarm fires.
const OUT_EVAL_PERIODS: u32 = 1;
/// Evaluation periods before the low (scale-in) alarm fires — scale-in
/// is deliberately more patient than scale-out.
const IN_EVAL_PERIODS: u32 = 3;

impl ScalingPolicy {
    /// Target-tracking with default limits.
    pub fn target_tracking(target_per_unit: f64) -> Self {
        Self {
            kind: PolicyKind::TargetTracking,
            target_per_unit,
            limits: ScalingLimits::default(),
        }
    }

    /// Step scaling with the canonical bands: +1 unit at 1x the target,
    /// +2 at 2x, +4 at 3x; -1 unit below 0.5x, -2 below 0.25x.
    pub fn step(target_per_unit: f64) -> Self {
        Self {
            kind: PolicyKind::Step {
                steps_out: vec![
                    StepRule { breach: 1.0, delta: 1 },
                    StepRule { breach: 2.0, delta: 2 },
                    StepRule { breach: 3.0, delta: 4 },
                ],
                steps_in: vec![
                    StepRule { breach: 0.5, delta: 1 },
                    StepRule { breach: 0.25, delta: 2 },
                ],
            },
            target_per_unit,
            limits: ScalingLimits::default(),
        }
    }

    /// The mode this policy implements.
    pub fn mode(&self) -> ScalingMode {
        match self.kind {
            PolicyKind::TargetTracking => ScalingMode::TargetTracking,
            PolicyKind::Step { .. } => ScalingMode::Step,
        }
    }

    /// Stable policy name (reports, labels).
    pub fn name(&self) -> &'static str {
        self.mode().name()
    }

    /// Backlog-per-unit below which the scale-in alarm breaches.
    pub fn scale_in_threshold(&self) -> f64 {
        self.target_per_unit * 0.5
    }

    fn effective_max(&self) -> u32 {
        if self.limits.max_capacity == 0 {
            u32::MAX
        } else {
            self.limits.max_capacity
        }
    }

    fn clamp(&self, cap: u32) -> u32 {
        // A floor above the ceiling (possible on a hand-built policy
        // before the controller normalizes it) collapses to the ceiling
        // rather than panicking in `u32::clamp`.
        let hi = self.effective_max();
        cap.clamp(self.limits.min_capacity.max(1).min(hi), hi)
    }

    /// Capacity a scale-out signal requests, given the current target
    /// and the queue backlog.  Never below `current`, always within
    /// `[min_capacity, max_capacity]`.
    pub fn desired_out(&self, current: u32, backlog: u64) -> u32 {
        let raw = match &self.kind {
            PolicyKind::TargetTracking => units_for(backlog, self.target_per_unit),
            PolicyKind::Step { steps_out, .. } => {
                let ratio = backlog_per_unit(backlog, current)
                    / self.target_per_unit.max(f64::MIN_POSITIVE);
                let delta = steps_out
                    .iter()
                    .filter(|r| ratio >= r.breach)
                    .map(|r| r.delta)
                    .max()
                    .unwrap_or(0);
                current.saturating_add(delta)
            }
        };
        self.clamp(raw.max(current.min(self.effective_max())))
    }

    /// Capacity a scale-in signal requests.  Never above `current`,
    /// always within `[min_capacity, max_capacity]`.
    pub fn desired_in(&self, current: u32, backlog: u64) -> u32 {
        let raw = match &self.kind {
            PolicyKind::TargetTracking => units_for(backlog, self.target_per_unit),
            PolicyKind::Step { steps_in, .. } => {
                let ratio = backlog_per_unit(backlog, current)
                    / self.target_per_unit.max(f64::MIN_POSITIVE);
                let delta = steps_in
                    .iter()
                    .filter(|r| ratio <= r.breach)
                    .map(|r| r.delta)
                    .max()
                    .unwrap_or(0);
                current.saturating_sub(delta)
            }
        };
        self.clamp(raw.min(current.max(self.limits.min_capacity)))
    }
}

/// Tear down the account-side residue of terminated instances: ECS
/// registration and their CloudWatch metric series.  Shared by every
/// scale-in authority (the autoscale controller here, the monitor's
/// queue-downscale), so what a terminated machine leaves behind cannot
/// diverge between paths.
pub(crate) fn deregister_killed(acct: &mut AwsAccount, killed: &[crate::aws::ec2::InstanceId]) {
    for id in killed {
        acct.ecs.deregister_instance(*id);
        acct.metrics.drop_dimension(&format!("i-{id}"));
    }
}

/// Units needed to hold `backlog` at `target` backlog-per-unit
/// (`ceil(backlog / target)`, at least 1-unit granularity).
fn units_for(backlog: u64, target: f64) -> u32 {
    if backlog == 0 {
        return 0;
    }
    let units = (backlog as f64 / target.max(f64::MIN_POSITIVE)).ceil();
    if units >= u32::MAX as f64 {
        u32::MAX
    } else {
        units as u32
    }
}

fn backlog_per_unit(backlog: u64, units: u32) -> f64 {
    backlog as f64 / f64::from(units.max(1))
}

/// One applied capacity mutation.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingDecision {
    pub at: SimTime,
    /// Target capacity before and after (weighted units).
    pub from: u32,
    pub to: u32,
    /// Queue backlog (visible + in-flight) at decision time.
    pub backlog: u64,
}

/// The scaling slice of a run report, the elasticity analog of
/// [`PoolBreakdown`](crate::aws::ec2::PoolBreakdown) /
/// [`DataBreakdown`](crate::aws::billing::DataBreakdown): what the
/// control loop decided and what it cost in capacity.  Threads
/// `RunReport` → `ScenarioSummary` → sweep JSON.  Cross-seed summaries
/// sum the counters and drop the per-decision `timeline` (it is
/// per-run evidence, not an aggregate).
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingBreakdown {
    /// Policy name: `"none"` (fixed fleet), `"target-tracking"`, or
    /// `"step"`.
    pub policy: String,
    /// Applied capacity mutations (scale-outs + scale-ins).
    pub decisions: u64,
    pub scale_outs: u64,
    pub scale_ins: u64,
    /// Weighted units of capacity added by scale-outs (target deltas).
    pub units_launched: u64,
    /// Weighted units released by scale-ins (target deltas).
    pub units_terminated: u64,
    /// Highest target capacity held.
    pub peak_capacity: u32,
    /// Lowest target capacity held.
    pub floor_capacity: u32,
    /// Time-at-capacity: the integral of the target capacity over the
    /// engaged window, in unit-hours — what elasticity actually saves.
    pub capacity_unit_hours: f64,
    /// The capacity timeline, one entry per applied decision.  Empty in
    /// cross-seed summaries.
    pub timeline: Vec<ScalingDecision>,
}

impl Default for ScalingBreakdown {
    fn default() -> Self {
        Self {
            policy: "none".to_string(),
            decisions: 0,
            scale_outs: 0,
            scale_ins: 0,
            units_launched: 0,
            units_terminated: 0,
            peak_capacity: 0,
            floor_capacity: 0,
            capacity_unit_hours: 0.0,
            timeline: Vec::new(),
        }
    }
}

/// The controller: owns one policy, one fleet, the pending alarm
/// signals, and the decision accounting.  Lives inside
/// [`MonitorState`](super::monitor::MonitorState).
#[derive(Debug)]
pub struct AutoscaleState {
    pub policy: ScalingPolicy,
    fleet: FleetId,
    engaged_at: SimTime,
    last_out: Option<SimTime>,
    last_in: Option<SimTime>,
    pending_out: bool,
    pending_in: bool,
    timeline: Vec<ScalingDecision>,
    units_launched: u64,
    units_terminated: u64,
    peak: u32,
    floor: u32,
    /// Capacity integral bookkeeping: target held since `cap_since`.
    cap_now: u32,
    cap_since: SimTime,
    unit_ms: f64,
}

impl AutoscaleState {
    /// Engage a policy on a fleet whose current requested capacity is
    /// `initial_capacity`.  A zero `max_capacity` resolves to it, so
    /// the config's `CLUSTER_MACHINES` doubles as the elastic ceiling.
    pub fn new(
        mut policy: ScalingPolicy,
        fleet: FleetId,
        initial_capacity: u32,
        now: SimTime,
    ) -> Self {
        if policy.limits.max_capacity == 0 {
            policy.limits.max_capacity = initial_capacity.max(1);
        }
        policy.limits.min_capacity = policy
            .limits
            .min_capacity
            .max(1)
            .min(policy.limits.max_capacity);
        Self {
            policy,
            fleet,
            engaged_at: now,
            last_out: None,
            last_in: None,
            pending_out: false,
            pending_in: false,
            timeline: Vec::new(),
            units_launched: 0,
            units_terminated: 0,
            peak: initial_capacity,
            floor: initial_capacity,
            cap_now: initial_capacity,
            cap_since: now,
            unit_ms: 0.0,
        }
    }

    /// The two alarm names this controller owns.
    pub fn alarm_names(cfg: &AppConfig) -> (String, String) {
        (
            format!("{}_backlog_high", cfg.app_name),
            format!("{}_backlog_low", cfg.app_name),
        )
    }

    fn queue_dimension(cfg: &AppConfig) -> String {
        format!("queue:{}", cfg.sqs_queue_name)
    }

    /// Place the high/low backlog alarms (idempotent by name).
    pub fn arm(&self, alarms: &mut Alarms, cfg: &AppConfig, now: SimTime) {
        let (high, low) = Self::alarm_names(cfg);
        let dim = Self::queue_dimension(cfg);
        alarms.put_alarm(
            &high,
            BACKLOG_METRIC,
            &dim,
            Comparison::GreaterThan,
            self.policy.target_per_unit,
            MINUTE,
            OUT_EVAL_PERIODS,
            AlarmAction::ScaleOut(self.fleet),
            now,
        );
        alarms.put_alarm(
            &low,
            BACKLOG_METRIC,
            &dim,
            Comparison::LessThan,
            self.policy.scale_in_threshold(),
            MINUTE,
            IN_EVAL_PERIODS,
            AlarmAction::ScaleIn(self.fleet),
            now,
        );
    }

    /// Record an alarm action addressed to this controller's fleet.
    /// Returns whether the action was consumed.
    pub fn signal(&mut self, action: &AlarmAction) -> bool {
        match *action {
            AlarmAction::ScaleOut(f) if f == self.fleet => {
                self.pending_out = true;
                true
            }
            AlarmAction::ScaleIn(f) if f == self.fleet => {
                self.pending_in = true;
                true
            }
            _ => false,
        }
    }

    /// Publish the queue's SQS metrics (and the derived backlog-per-unit
    /// series the alarms watch) for this tick.
    #[allow(clippy::too_many_arguments)]
    pub fn observe(
        &self,
        metrics: &mut Metrics,
        cfg: &AppConfig,
        visible: u64,
        in_flight: u64,
        oldest_age: SimTime,
        capacity: u32,
        now: SimTime,
    ) {
        let dim = Self::queue_dimension(cfg);
        metrics.put(VISIBLE_METRIC, &dim, now, visible as f64);
        metrics.put(IN_FLIGHT_METRIC, &dim, now, in_flight as f64);
        metrics.put(OLDEST_AGE_METRIC, &dim, now, oldest_age as f64 / 1000.0);
        metrics.put(
            BACKLOG_METRIC,
            &dim,
            now,
            backlog_per_unit(visible + in_flight, capacity),
        );
    }

    /// Turn the pending alarm signals into at most one applied capacity
    /// decision, respecting bounds, cooldowns, and warmup.  Returns the
    /// fleet events of an immediate scale-out launch (the caller
    /// schedules their `InstanceReady`s).
    pub fn react(
        &mut self,
        acct: &mut AwsAccount,
        cfg: &AppConfig,
        now: SimTime,
    ) -> Vec<FleetEvent> {
        let out_signal = std::mem::take(&mut self.pending_out);
        let in_signal = std::mem::take(&mut self.pending_in);
        if !out_signal && !in_signal {
            return Vec::new();
        }
        let (visible, in_flight) = acct.sqs.approximate_counts(&cfg.sqs_queue_name, now);
        let backlog = (visible + in_flight) as u64;
        let current = acct.ec2.fleet_target(self.fleet);
        let mut events = Vec::new();

        // Scale-out wins when both alarms somehow signalled (a backlog
        // spike right after a drain): growing is the safe direction.
        if out_signal && self.cooled(self.last_out, self.policy.limits.scale_out_cooldown, now) {
            let desired = self.policy.desired_out(current, backlog);
            if desired > current {
                events = acct.ec2.scale_out(self.fleet, desired, now);
                self.record(now, current, desired, backlog);
                self.units_launched += u64::from(desired - current);
                self.last_out = Some(now);
                acct.logs.put(
                    &cfg.log_group_name,
                    "monitor",
                    now,
                    format!(
                        "autoscale[{}]: backlog {backlog} -> scale out {current} -> {desired} units",
                        self.policy.name()
                    ),
                );
                return events;
            }
        }
        if in_signal
            && self.cooled(self.last_in, self.policy.limits.scale_in_cooldown, now)
            && self.warmed(now)
        {
            let desired = self.policy.desired_in(current, backlog);
            if desired < current {
                let killed = acct.ec2.scale_in(self.fleet, desired, now);
                deregister_killed(acct, &killed);
                self.record(now, current, desired, backlog);
                self.units_terminated += u64::from(current - desired);
                self.last_in = Some(now);
                acct.logs.put(
                    &cfg.log_group_name,
                    "monitor",
                    now,
                    format!(
                        "autoscale[{}]: backlog {backlog} -> scale in {current} -> {desired} units ({} terminated)",
                        self.policy.name(),
                        killed.len()
                    ),
                );
            }
        }
        events
    }

    fn cooled(&self, last: Option<SimTime>, cooldown: SimTime, now: SimTime) -> bool {
        last.map(|t| now.saturating_sub(t) >= cooldown).unwrap_or(true)
    }

    /// Scale-in is held back within the warmup window after engagement
    /// or after a scale-out.
    fn warmed(&self, now: SimTime) -> bool {
        let w = self.policy.limits.warmup;
        now.saturating_sub(self.engaged_at) >= w
            && self
                .last_out
                .map(|t| now.saturating_sub(t) >= w)
                .unwrap_or(true)
    }

    fn record(&mut self, now: SimTime, from: u32, to: u32, backlog: u64) {
        self.unit_ms += (now.saturating_sub(self.cap_since)) as f64 * f64::from(self.cap_now);
        self.cap_now = to;
        self.cap_since = now;
        self.peak = self.peak.max(to);
        self.floor = self.floor.min(to);
        self.timeline.push(ScalingDecision {
            at: now,
            from,
            to,
            backlog,
        });
    }

    /// Finalize the accounting into the report slice.
    pub fn breakdown(&self, now: SimTime) -> ScalingBreakdown {
        let unit_ms =
            self.unit_ms + (now.saturating_sub(self.cap_since)) as f64 * f64::from(self.cap_now);
        let outs = self.timeline.iter().filter(|d| d.to > d.from).count() as u64;
        ScalingBreakdown {
            policy: self.policy.name().to_string(),
            decisions: self.timeline.len() as u64,
            scale_outs: outs,
            scale_ins: self.timeline.len() as u64 - outs,
            units_launched: self.units_launched,
            units_terminated: self.units_terminated,
            peak_capacity: self.peak,
            floor_capacity: self.floor,
            capacity_unit_hours: unit_ms / HOUR as f64,
            timeline: self.timeline.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_names_roundtrip() {
        for m in ScalingMode::ALL {
            assert_eq!(ScalingMode::parse(m.name()), Some(m));
        }
        assert_eq!(ScalingMode::parse("bogus"), None);
        assert!(ScalingMode::None.policy(4.0).is_none());
        assert_eq!(
            ScalingMode::Step.policy(4.0).unwrap().mode(),
            ScalingMode::Step
        );
    }

    #[test]
    fn target_tracking_desired_jumps_to_backlog() {
        let mut p = ScalingPolicy::target_tracking(4.0);
        p.limits.max_capacity = 16;
        assert_eq!(p.desired_out(1, 100), 16, "clamped at max");
        assert_eq!(p.desired_out(1, 10), 3, "ceil(10/4)");
        assert_eq!(p.desired_out(8, 10), 8, "never below current");
        assert_eq!(p.desired_in(8, 10), 3);
        assert_eq!(p.desired_in(2, 100), 2, "never above current");
        assert_eq!(p.desired_in(8, 0), 1, "floor at min");
    }

    #[test]
    fn step_desired_uses_deepest_band() {
        let mut p = ScalingPolicy::step(4.0);
        p.limits.max_capacity = 16;
        // backlog/unit = 40 on 2 units = 20/unit; ratio 5x -> +4.
        assert_eq!(p.desired_out(2, 40), 6);
        // ratio exactly 1x -> +1.
        assert_eq!(p.desired_out(2, 8), 3);
        // below every band -> no-op.
        assert_eq!(p.desired_out(4, 2), 4);
        // empty queue -> deepest in-band, -2.
        assert_eq!(p.desired_in(10, 0), 8);
        // half target -> -1.
        assert_eq!(p.desired_in(10, 20), 9);
        assert_eq!(p.desired_in(1, 0), 1, "floor");
    }

    #[test]
    fn limits_resolve_on_engagement() {
        let s = AutoscaleState::new(ScalingPolicy::target_tracking(4.0), 1, 8, 0);
        assert_eq!(s.policy.limits.max_capacity, 8);
        assert_eq!(s.policy.limits.min_capacity, 1);
        // Explicit max survives; min clamps to max.
        let mut p = ScalingPolicy::target_tracking(4.0);
        p.limits.max_capacity = 4;
        p.limits.min_capacity = 9;
        let s = AutoscaleState::new(p, 1, 8, 0);
        assert_eq!(s.policy.limits.max_capacity, 4);
        assert_eq!(s.policy.limits.min_capacity, 4);
    }

    #[test]
    fn signals_only_consume_matching_fleet() {
        let mut s = AutoscaleState::new(ScalingPolicy::target_tracking(4.0), 7, 4, 0);
        assert!(!s.signal(&AlarmAction::ScaleOut(8)));
        assert!(!s.pending_out);
        assert!(s.signal(&AlarmAction::ScaleOut(7)));
        assert!(s.pending_out);
        assert!(s.signal(&AlarmAction::ScaleIn(7)));
        assert!(s.pending_in);
        assert!(!s.signal(&AlarmAction::TerminateInstance(7)));
    }

    #[test]
    fn breakdown_integrates_time_at_capacity() {
        let mut s = AutoscaleState::new(ScalingPolicy::target_tracking(4.0), 1, 4, 0);
        // 1h at 4 units, then scale in to 1 for 2h.
        s.record(HOUR, 4, 1, 0);
        let b = s.breakdown(3 * HOUR);
        assert_eq!(b.decisions, 1);
        assert_eq!(b.scale_ins, 1);
        assert_eq!(b.peak_capacity, 4);
        assert_eq!(b.floor_capacity, 1);
        assert!((b.capacity_unit_hours - 6.0).abs() < 1e-9, "{b:?}");
        assert_eq!(b.timeline.len(), 1);
    }

    #[test]
    fn default_breakdown_is_the_fixed_fleet() {
        let b = ScalingBreakdown::default();
        assert_eq!(b.policy, "none");
        assert_eq!(b.decisions, 0);
        assert!(b.timeline.is_empty());
    }
}
