//! Step 2: `python run.py submitJob files/job.json`.
//!
//! "it adds that list of tasks to the queue in SQS (which you made in the
//! previous step)."
//!
//! This is the closed-batch path: the whole Job file becomes SQS
//! messages at once.  Open-loop traffic runs
//! ([`Simulation::submit_traffic`](super::run::Simulation::submit_traffic))
//! bypass it — each tenant's generator enqueues one message per arrival
//! event instead, against the same queue and message schema.

use anyhow::{bail, Context, Result};

use crate::aws::AwsAccount;
use crate::config::{AppConfig, JobSpec};
use crate::sim::SimTime;

/// Expand the Job file into one SQS message per group.  Returns the
/// number of jobs enqueued.
pub fn submit_job(
    acct: &mut AwsAccount,
    cfg: &AppConfig,
    jobs: &JobSpec,
    now: SimTime,
) -> Result<u64> {
    if !acct.sqs.queue_exists(&cfg.sqs_queue_name) {
        bail!(
            "queue '{}' does not exist — run setup first",
            cfg.sqs_queue_name
        );
    }
    let msgs = jobs.to_messages();
    let n = msgs.len() as u64;
    for m in msgs {
        acct.sqs
            .send(&cfg.sqs_queue_name, m, now)
            .context("sending job message")?;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::Volatility;
    use crate::coordinator::setup::setup;

    #[test]
    fn submit_enqueues_one_per_group() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default();
        setup(&mut acct, &cfg, 0).unwrap();
        let jobs = JobSpec::plate("P1", 8, 4, vec![]);
        let n = submit_job(&mut acct, &cfg, &jobs, 0).unwrap();
        assert_eq!(n, 32);
        assert_eq!(acct.sqs.approximate_counts(&cfg.sqs_queue_name, 0), (32, 0));
    }

    #[test]
    fn submit_requires_setup() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default();
        let jobs = JobSpec::plate("P1", 1, 1, vec![]);
        assert!(submit_job(&mut acct, &cfg, &jobs, 0).is_err());
    }
}
