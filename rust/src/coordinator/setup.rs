//! Step 1: `python run.py setup`.
//!
//! "When you run 'python3 run.py setup' to execute the Config, it does
//! three major things: 1) Creates task definitions in ECS … 2) Makes a
//! queue in SQS (it is empty at this point) and sets a dead-letter
//! queue.  3) Makes a service in ECS which defines how many Dockers you
//! want."

use anyhow::{Context, Result};

use crate::aws::ecs::{Service, TaskDefinition};
use crate::aws::sqs::RedrivePolicy;
use crate::aws::AwsAccount;
use crate::config::AppConfig;
use crate::sim::SimTime;

/// Execute the Config: task definition + queues + service.
pub fn setup(acct: &mut AwsAccount, cfg: &AppConfig, now: SimTime) -> Result<()> {
    cfg.validate().context("invalid Config file")?;

    // 1) Task definition: Docker shape + the whole Config as env (DS
    //    passes CHECK_IF_DONE_BOOL, DOCKER_CORES, EXPECTED_NUMBER_FILES,
    //    MEMORY and user VARIABLEs into the container).
    let mut env = vec![
        ("APP_NAME".to_string(), cfg.app_name.clone()),
        ("WORKLOAD_ID".to_string(), cfg.workload_id.clone()),
        ("SQS_QUEUE_NAME".to_string(), cfg.sqs_queue_name.clone()),
        (
            "CHECK_IF_DONE_BOOL".to_string(),
            cfg.check_if_done.enabled.to_string(),
        ),
        (
            "EXPECTED_NUMBER_FILES".to_string(),
            cfg.check_if_done.expected_number_files.to_string(),
        ),
        ("DOCKER_CORES".to_string(), cfg.docker_cores.to_string()),
        ("MEMORY".to_string(), cfg.memory_mb.to_string()),
    ];
    env.extend(cfg.variables.iter().cloned());
    acct.ecs.register_task_definition(TaskDefinition {
        family: cfg.task_family(),
        cpu_shares: cfg.cpu_shares,
        memory_mb: cfg.memory_mb,
        env,
    });

    // 2) Queue + DLQ with redrive.
    acct.sqs
        .create_queue(&cfg.sqs_queue_name, cfg.sqs_message_visibility);
    acct.sqs
        .create_queue(&cfg.sqs_dead_letter_queue, cfg.sqs_message_visibility);
    acct.sqs
        .set_redrive(
            &cfg.sqs_queue_name,
            &cfg.sqs_dead_letter_queue,
            RedrivePolicy {
                max_receive_count: cfg.max_receive_count,
            },
        )
        .context("setting redrive policy")?;

    // 3) Service: how many Dockers.
    acct.ecs.create_cluster(&cfg.ecs_cluster);
    acct.ecs
        .create_service(Service {
            name: cfg.service_name(),
            cluster: cfg.ecs_cluster.clone(),
            task_family: cfg.task_family(),
            desired_count: cfg.cluster_machines * cfg.tasks_per_machine,
        })
        .context("creating ECS service")?;

    let _ = now;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::Volatility;

    #[test]
    fn setup_creates_all_three() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default();
        setup(&mut acct, &cfg, 0).unwrap();
        assert!(acct.ecs.task_definition(&cfg.task_family()).is_some());
        assert!(acct.sqs.queue_exists(&cfg.sqs_queue_name));
        assert!(acct.sqs.queue_exists(&cfg.sqs_dead_letter_queue));
        let svc = acct.ecs.service(&cfg.service_name()).unwrap();
        assert_eq!(
            svc.desired_count,
            cfg.cluster_machines * cfg.tasks_per_machine
        );
    }

    #[test]
    fn setup_idempotent() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default();
        setup(&mut acct, &cfg, 0).unwrap();
        setup(&mut acct, &cfg, 10).unwrap();
        assert!(acct.ecs.service(&cfg.service_name()).is_some());
    }

    #[test]
    fn env_carries_config() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let mut cfg = AppConfig::default();
        cfg.variables = vec![("MY_VAR".into(), "7".into())];
        setup(&mut acct, &cfg, 0).unwrap();
        let td = acct.ecs.task_definition(&cfg.task_family()).unwrap();
        assert!(td.env.iter().any(|(k, v)| k == "MY_VAR" && v == "7"));
        assert!(td.env.iter().any(|(k, _)| k == "CHECK_IF_DONE_BOOL"));
    }

    #[test]
    fn invalid_config_rejected() {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let mut cfg = AppConfig::default();
        cfg.cluster_machines = 0;
        assert!(setup(&mut acct, &cfg, 0).is_err());
    }
}
