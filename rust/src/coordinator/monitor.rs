//! Step 4 (optional): `python run.py monitor files/…SpotFleetRequestId.json [True]`.
//!
//! "While your analysis is running, monitor checks your queue once per
//! minute … Once per hour, it deletes the alarms for any instances that
//! have been terminated in the last 24 hours … When the number of jobs in
//! your queue goes to 0, monitor downscales the ECS service … deletes all
//! the alarms … shuts down your spot fleet … gets rid of the queue,
//! service, and task definition … exports all the logs … onto your S3
//! bucket."
//!
//! Cheapest mode: "downscale the number of requested machines (but not
//! RUNNING machines) to one 15 minutes after the monitor is engaged."
//!
//! Queue-downscale mode (opt-in, beyond the paper): once the queue holds
//! less work than the fleet can chew, the monitor *actively* scales the
//! fleet in to match — terminating surplus machines from the
//! most-expensive pool first, so the cheapest pool is downscaled last
//! (see [`crate::aws::ec2::Ec2::scale_in_to_machines`]).  Any in-flight
//! job on a terminated machine redelivers via the SQS visibility
//! timeout, so accounting invariants hold.  Mutually exclusive with
//! cheapest mode, whose contract is to never terminate running machines
//! — the run driver rejects the combination.
//!
//! Autoscale mode (opt-in, DESIGN.md §8): the monitor hosts a
//! [`AutoscaleState`] controller that closes the loop in *both*
//! directions.  Each tick publishes the queue's SQS metrics; CloudWatch
//! alarms on the backlog-per-unit series deliver
//! [`AlarmAction::ScaleOut`]/[`AlarmAction::ScaleIn`] signals through
//! the per-minute alarm evaluation; the controller turns them into
//! bounded, cooldown-gated fleet mutations on the monitor tick.
//! Mutually exclusive with both cheapest mode and queue-downscale (one
//! scale-in authority at a time).

use crate::aws::cloudwatch::AlarmAction;
use crate::aws::ec2::{FleetEvent, FleetId, InstanceState};
use crate::aws::ecs::containers_that_fit;
use crate::aws::AwsAccount;
use crate::config::AppConfig;
use crate::sim::clock::{SimTime, HOUR, MINUTE};

use super::autoscale::{AutoscaleState, ScalingBreakdown};

/// Monitor state machine, ticked once per simulated minute.
#[derive(Debug)]
pub struct MonitorState {
    pub fleet: FleetId,
    pub cheapest: bool,
    /// Scale the fleet in as the queue drains (cheapest pool last).
    pub queue_downscale: bool,
    /// Closed-loop elastic scaling (see [`super::autoscale`]).
    autoscale: Option<AutoscaleState>,
    engaged_at: SimTime,
    last_alarm_reap: SimTime,
    cheapest_downscaled: bool,
    pub cleanup_done: bool,
    /// Where to export logs at cleanup.
    pub export_bucket: String,
}

/// What one monitor tick did: whether cleanup ran (run is over) and any
/// fleet events an autoscale decision produced (the run driver
/// schedules their `InstanceReady`s).
#[derive(Debug)]
pub struct MonitorTick {
    pub done: bool,
    pub fleet_events: Vec<FleetEvent>,
}

/// Time after engagement at which cheapest mode downsizes the fleet.
pub const CHEAPEST_DELAY: SimTime = 15 * MINUTE;

impl MonitorState {
    pub fn new(fleet: FleetId, cheapest: bool, export_bucket: &str, now: SimTime) -> Self {
        Self {
            fleet,
            cheapest,
            queue_downscale: false,
            autoscale: None,
            engaged_at: now,
            last_alarm_reap: now,
            cheapest_downscaled: false,
            cleanup_done: false,
            export_bucket: export_bucket.to_string(),
        }
    }

    /// Enable queue-proportional scale-in (see module docs).
    pub fn with_queue_downscale(mut self) -> Self {
        self.queue_downscale = true;
        self
    }

    /// Attach a closed-loop scaling controller (see module docs).
    pub fn with_autoscale(mut self, state: AutoscaleState) -> Self {
        self.autoscale = Some(state);
        self
    }

    /// Deliver a fired scaling alarm action to the controller (called
    /// from the run driver's per-minute alarm evaluation).  Ignored
    /// without a controller or for a foreign fleet.
    pub fn scale_signal(&mut self, action: &AlarmAction) {
        if let Some(ctl) = &mut self.autoscale {
            ctl.signal(action);
        }
    }

    /// The scaling slice of the run report, if a controller is engaged.
    pub fn scaling_breakdown(&self, now: SimTime) -> Option<ScalingBreakdown> {
        self.autoscale.as_ref().map(|ctl| ctl.breakdown(now))
    }

    /// One monitor tick.  `hold_cleanup` defers end-of-run teardown even
    /// on an empty queue — the run driver sets it while the workload is
    /// still pending: scheduled mid-run submissions, unreleased workflow
    /// nodes, or traffic generators with future arrivals drawn.  A quiet
    /// gap between a tenant's arrival bursts therefore cannot tear the
    /// cluster down mid-run (the `submit_at` drain race, DESIGN.md §13).
    pub fn tick(
        &mut self,
        acct: &mut AwsAccount,
        cfg: &AppConfig,
        now: SimTime,
        hold_cleanup: bool,
    ) -> MonitorTick {
        if self.cleanup_done {
            return MonitorTick {
                done: true,
                fleet_events: Vec::new(),
            };
        }

        // Cheapest mode: downscale *requested* capacity to 1 after 15 min.
        if self.cheapest && !self.cheapest_downscaled && now >= self.engaged_at + CHEAPEST_DELAY
        {
            acct.ec2.modify_target(self.fleet, 1);
            self.cheapest_downscaled = true;
            acct.logs.put(
                &cfg.log_group_name,
                "monitor",
                now,
                "cheapest mode: fleet target -> 1 (running machines kept)",
            );
        }

        // Hourly: delete alarms of instances terminated in the last 24 h.
        if now >= self.last_alarm_reap + HOUR {
            self.last_alarm_reap = now;
            let dead: Vec<String> = acct
                .ec2
                .all_instances()
                .iter()
                .filter(|i| {
                    i.state == InstanceState::Terminated
                        && i.terminated_at
                            .map(|t| now.saturating_sub(t) <= 24 * HOUR)
                            .unwrap_or(false)
                })
                .map(|i| format!("i-{}", i.id))
                .collect();
            let mut reaped = 0;
            for d in dead {
                reaped += acct.alarms.delete_for_dimension(&d);
            }
            if reaped > 0 {
                acct.logs.put(
                    &cfg.log_group_name,
                    "monitor",
                    now,
                    format!("reaped {reaped} alarms of terminated instances"),
                );
            }
        }

        // Per-minute queue check.
        let (visible, in_flight) = acct.sqs.approximate_counts(&cfg.sqs_queue_name, now);
        acct.logs.put(
            &cfg.log_group_name,
            "monitor",
            now,
            format!("queue: {visible} waiting, {in_flight} in process"),
        );

        // Autoscale: publish the queue's SQS metrics for the scaling
        // alarms (only when a controller is engaged, so unscaled runs
        // keep their exact pre-autoscale CloudWatch bills).
        if let Some(ctl) = &self.autoscale {
            let oldest = acct.sqs.oldest_message_age(&cfg.sqs_queue_name, now);
            let capacity = acct.ec2.fleet_target(self.fleet);
            ctl.observe(
                &mut acct.metrics,
                cfg,
                visible as u64,
                in_flight as u64,
                oldest,
                capacity,
                now,
            );
        }

        if visible == 0 && in_flight == 0 && !hold_cleanup {
            self.cleanup(acct, cfg, now);
            return MonitorTick {
                done: true,
                fleet_events: Vec::new(),
            };
        }

        // Queue-downscale mode: shrink the fleet to the *machines* the
        // remaining work can keep busy, cheapest pool last.  The budget
        // is in machines, not weighted units — a weight-3 machine still
        // runs one machine's worth of containers — so this goes through
        // `scale_in_to_machines`, which also lowers the requested
        // capacity to the surviving weight.
        if self.queue_downscale && !self.cheapest {
            // Per-machine throughput from what actually PACKS, not the
            // TASKS_PER_MACHINE intent: on a heterogeneous fleet a small
            // machine may fit fewer containers than configured.  Use the
            // smallest packing among the fleet's active types —
            // conservative, so surplus machines are only killed when
            // even the weakest survivor shape covers the queue.
            let fit = acct
                .ec2
                .all_instances()
                .iter()
                .filter(|i| i.fleet == self.fleet && i.is_active())
                .map(|i| {
                    containers_that_fit(cfg.cpu_shares, cfg.memory_mb, i.itype)
                        .min(cfg.tasks_per_machine)
                })
                .min()
                .unwrap_or(cfg.tasks_per_machine);
            let per_machine = u64::from((fit * cfg.docker_cores).max(1));
            let remaining = (visible + in_flight) as u64;
            let machines_worth = remaining.saturating_add(per_machine - 1) / per_machine;
            let needed = u32::try_from(machines_worth).unwrap_or(u32::MAX).max(1);
            let current = acct.ec2.active_count(self.fleet);
            if needed < current {
                let killed = acct.ec2.scale_in_to_machines(self.fleet, needed, now);
                super::autoscale::deregister_killed(acct, &killed);
                if !killed.is_empty() {
                    acct.logs.put(
                        &cfg.log_group_name,
                        "monitor",
                        now,
                        format!(
                            "queue downscale: {current} -> {needed} machines ({} terminated)",
                            killed.len()
                        ),
                    );
                }
            }
        }

        // Autoscale: turn pending alarm signals into at most one
        // bounded, cooldown-gated capacity decision.
        let fleet_events = match &mut self.autoscale {
            Some(ctl) => ctl.react(acct, cfg, now),
            None => Vec::new(),
        };
        MonitorTick {
            done: false,
            fleet_events,
        }
    }

    /// End-of-run teardown, in the paper's order.
    fn cleanup(&mut self, acct: &mut AwsAccount, cfg: &AppConfig, now: SimTime) {
        // Downscale the ECS service.
        let _ = acct.ecs.set_desired_count(&cfg.service_name(), 0);
        // Delete all alarms associated with the fleet.
        acct.alarms.delete_all();
        // Shut down the spot fleet.
        let killed = acct.ec2.cancel_fleet(self.fleet, now);
        for id in &killed {
            acct.ecs.deregister_instance(*id);
        }
        // Get rid of the queue, service, and task definition.
        acct.sqs.delete_queue(&cfg.sqs_queue_name);
        acct.ecs.delete_service(&cfg.service_name());
        acct.ecs.deregister_task_definition(&cfg.task_family());
        // Export all logs to S3.
        acct.s3.create_bucket(&self.export_bucket);
        acct.logs.put(
            &cfg.log_group_name,
            "monitor",
            now,
            format!("cleanup: terminated {} instances, exporting logs", killed.len()),
        );
        acct.logs.export_to_s3(
            &cfg.log_group_name,
            &mut acct.s3,
            &self.export_bucket,
            "exportedlogs",
            now,
        );
        acct.logs.export_to_s3(
            &cfg.instance_log_group(),
            &mut acct.s3,
            &self.export_bucket,
            "exportedlogs",
            now,
        );
        self.cleanup_done = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::Volatility;
    use crate::config::FleetSpec;
    use crate::coordinator::cluster::start_cluster;
    use crate::coordinator::setup::setup;

    fn rig() -> (AwsAccount, AppConfig, MonitorState) {
        let mut acct = AwsAccount::new(1, Volatility::Low);
        let cfg = AppConfig::default();
        setup(&mut acct, &cfg, 0).unwrap();
        let fleet =
            start_cluster(&mut acct, &cfg, &FleetSpec::template("us-east-1").unwrap(), 0)
                .unwrap();
        acct.s3.create_bucket("ds-data");
        let mon = MonitorState::new(fleet, false, "ds-data", 0);
        (acct, cfg, mon)
    }

    #[test]
    fn empty_queue_triggers_cleanup() {
        let (mut acct, cfg, mut mon) = rig();
        acct.ec2.evaluate_fleets(0);
        assert!(acct.ec2.active_count(mon.fleet) > 0);
        let done = mon.tick(&mut acct, &cfg, MINUTE, false);
        assert!(done.done);
        assert!(mon.cleanup_done);
        assert_eq!(acct.ec2.active_count(mon.fleet), 0);
        assert!(!acct.sqs.queue_exists(&cfg.sqs_queue_name));
        assert!(acct.ecs.is_clean(&cfg.service_name(), &cfg.task_family()));
        assert!(acct.alarms.is_empty());
        // Logs exported.
        assert!(!acct.s3.list_prefix("ds-data", "exportedlogs/").is_empty());
    }

    #[test]
    fn nonempty_queue_keeps_running() {
        let (mut acct, cfg, mut mon) = rig();
        acct.sqs.send(&cfg.sqs_queue_name, "{}", 0).unwrap();
        assert!(!mon.tick(&mut acct, &cfg, MINUTE, false).done);
        assert!(acct.sqs.queue_exists(&cfg.sqs_queue_name));
    }

    #[test]
    fn cheapest_downscales_after_15m_only() {
        let (mut acct, cfg, _) = rig();
        acct.sqs.send(&cfg.sqs_queue_name, "{}", 0).unwrap();
        let fleet = 1;
        let mut mon = MonitorState::new(fleet, true, "ds-data", 0);
        mon.tick(&mut acct, &cfg, 5 * MINUTE, false);
        assert_eq!(acct.ec2.fleet_target(fleet), AppConfig::default().cluster_machines);
        mon.tick(&mut acct, &cfg, 16 * MINUTE, false);
        assert_eq!(acct.ec2.fleet_target(fleet), 1);
    }

    #[test]
    fn in_flight_messages_defer_cleanup() {
        let (mut acct, cfg, mut mon) = rig();
        acct.sqs.send(&cfg.sqs_queue_name, "{}", 0).unwrap();
        let _ = acct.sqs.receive(&cfg.sqs_queue_name, MINUTE).unwrap();
        // visible=0 but in_flight=1 -> not done.
        assert!(!mon.tick(&mut acct, &cfg, 2 * MINUTE, false).done);
    }

    #[test]
    fn queue_downscale_shrinks_fleet_to_remaining_work() {
        let (mut acct, cfg, _) = rig(); // 4 machines, 2 tasks x 2 cores
        // 5 jobs left: one machine's worth (4/machine) rounds up to 2.
        for _ in 0..5 {
            acct.sqs.send(&cfg.sqs_queue_name, "{}", 0).unwrap();
        }
        acct.ec2.evaluate_fleets(0);
        for id in acct.ec2.instances_in_state(1, InstanceState::Pending) {
            acct.ec2.mark_running(id, MINUTE);
        }
        assert_eq!(acct.ec2.active_count(1), 4);
        let mut mon = MonitorState::new(1, false, "ds-data", 0).with_queue_downscale();
        assert!(!mon.tick(&mut acct, &cfg, 2 * MINUTE, false).done);
        assert_eq!(acct.ec2.fleet_target(1), 2);
        assert_eq!(acct.ec2.active_weight(1), 2);
        // And it never scales back *up*: target only moves down.
        assert!(!mon.tick(&mut acct, &cfg, 3 * MINUTE, false).done);
        assert_eq!(acct.ec2.fleet_target(1), 2);
    }

    #[test]
    fn autoscale_closed_loop_scales_out_and_in_through_alarms() {
        use crate::coordinator::autoscale::{AutoscaleState, ScalingPolicy};
        let (mut acct, cfg, _) = rig(); // fleet target 4
        // Shrink the fleet to 1 unit first so there is room to grow.
        acct.ec2.evaluate_fleets(0);
        for id in acct.ec2.instances_in_state(1, InstanceState::Pending) {
            acct.ec2.mark_running(id, 1);
        }
        acct.ec2.scale_in(1, 1, 1);
        let mut policy = ScalingPolicy::target_tracking(2.0);
        policy.limits.max_capacity = 4;
        policy.limits.scale_in_cooldown = MINUTE;
        policy.limits.warmup = MINUTE;
        let ctl = AutoscaleState::new(policy, 1, 1, 0);
        ctl.arm(&mut acct.alarms, &cfg, 0);
        let mut mon = MonitorState::new(1, false, "ds-data", 0).with_autoscale(ctl);
        // 10 jobs queued: backlog/unit = 10 > 2 target.
        for _ in 0..10 {
            acct.sqs.send(&cfg.sqs_queue_name, "{}", 0).unwrap();
        }
        // Tick 1 publishes metrics; alarm evaluation then fires ScaleOut.
        assert!(!mon.tick(&mut acct, &cfg, MINUTE, false).done);
        let fired = acct.alarms.evaluate(&acct.metrics, 2 * MINUTE);
        assert!(
            fired.contains(&crate::aws::cloudwatch::AlarmAction::ScaleOut(1)),
            "{fired:?}"
        );
        for a in &fired {
            mon.scale_signal(a);
        }
        // Tick 2 applies the decision: capacity jumps to ceil(10/2) = 5,
        // clamped to max 4, and the launches come back as fleet events.
        let out = mon.tick(&mut acct, &cfg, 2 * MINUTE, false);
        assert!(!out.done);
        assert!(!out.fleet_events.is_empty());
        assert_eq!(acct.ec2.fleet_target(1), 4);
        let b = mon.scaling_breakdown(2 * MINUTE).unwrap();
        assert_eq!(b.scale_outs, 1);
        assert_eq!(b.units_launched, 3);
        assert_eq!(b.peak_capacity, 4);

        // Drain the queue; the low alarm eventually signals scale-in.
        let t = 3 * MINUTE;
        while let Some((_, h)) = acct.sqs.receive(&cfg.sqs_queue_name, t).unwrap() {
            acct.sqs.delete(&cfg.sqs_queue_name, h, t).unwrap();
        }
        // Keep the run alive (hold_cleanup) and let the low alarm breach
        // for its 3 evaluation periods.
        let mut scaled_in = false;
        for k in 0..12u64 {
            let now = t + k * MINUTE;
            mon.tick(&mut acct, &cfg, now, true);
            for a in acct.alarms.evaluate(&acct.metrics, now + MINUTE / 2) {
                mon.scale_signal(&a);
            }
            if acct.ec2.fleet_target(1) < 4 {
                scaled_in = true;
                break;
            }
        }
        assert!(scaled_in, "low-backlog alarm never shrank the fleet");
        let b = mon.scaling_breakdown(t + 12 * MINUTE).unwrap();
        assert!(b.scale_ins >= 1, "{b:?}");
        assert!(b.floor_capacity < 4, "{b:?}");
    }

    #[test]
    fn hold_cleanup_defers_teardown_between_bursts() {
        let (mut acct, cfg, mut mon) = rig();
        acct.ec2.evaluate_fleets(0);
        // Queue empty but more work is scheduled: no teardown.
        assert!(!mon.tick(&mut acct, &cfg, MINUTE, true).done);
        assert!(acct.sqs.queue_exists(&cfg.sqs_queue_name));
        assert!(!mon.cleanup_done);
        // Once nothing is pending, the empty queue tears down as before.
        assert!(mon.tick(&mut acct, &cfg, 2 * MINUTE, false).done);
        assert!(mon.cleanup_done);
    }

    #[test]
    fn queue_downscale_disabled_by_default() {
        let (mut acct, cfg, mut mon) = rig();
        acct.sqs.send(&cfg.sqs_queue_name, "{}", 0).unwrap();
        acct.ec2.evaluate_fleets(0);
        assert!(!mon.tick(&mut acct, &cfg, 2 * MINUTE, false).done);
        assert_eq!(
            acct.ec2.fleet_target(1),
            AppConfig::default().cluster_machines
        );
    }
}
