//! Parallel scenario-sweep engine (DESIGN.md §5).
//!
//! The paper's promise is scale: "neither computing power nor data
//! storage are limited by local availability."  The serial [`run_full`]
//! driver evaluates one configuration at a time; this module evaluates a
//! whole configuration *matrix* — the cartesian product of the typed
//! axes registered in [`crate::scenario`] (seeds × volatility ×
//! visibility × machines × allocation × instance set × input MB × net
//! profile × scaling policy × scaling target × duration model) — on a
//! pool of OS threads, one independent
//! [`Simulation`](super::Simulation) per cell.
//!
//! The types describing *what* to sweep — [`Scenario`],
//! [`ScenarioMatrix`], [`SweepPlan`], and the axis registry they hang
//! off — live in [`crate::scenario`] and are re-exported here; this
//! module owns *executing* the plan.  Each axis overlays its own knob
//! on the cell's config, fleet file, job file, or run options
//! ([`Scenario::cell_inputs`]), so adding an axis never touches this
//! file.
//!
//! Determinism is the load-bearing property: each cell is a pure function
//! of `(scenario, seed)` — it owns its account, event heap, and
//! [`SimRng`](crate::sim::SimRng); threads share *nothing mutable* except
//! the work counter and the result slots, and results land in
//! cell-index order regardless of which thread ran them.  A sweep
//! therefore produces a bit-identical [`SweepReport`] at any worker
//! count, which is what lets experiment tables double as regression
//! gates (see `rust/tests/determinism.rs`).
//!
//! # Example: a two-scenario sweep on two threads
//!
//! ```
//! use ds_rs::config::{AppConfig, JobSpec};
//! use ds_rs::coordinator::sweep::{run_sweep, ScenarioMatrix, SweepPlan};
//!
//! let cfg = AppConfig::default();
//! let jobs = JobSpec::plate("P", 2, 1, vec![]); // 2 tiny jobs per cell
//! let matrix = ScenarioMatrix {
//!     seeds: vec![1],
//!     cluster_machines: vec![1, 2],
//!     ..Default::default()
//! };
//! let run = run_sweep(&SweepPlan::new(cfg, jobs, matrix), 2).unwrap();
//! assert_eq!(run.report.scenarios.len(), 2);
//! assert_eq!(run.report.total_completed(), 4);
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;

use anyhow::{anyhow, ensure, Context, Result};

use crate::json::Value;
use crate::metrics::{RunReport, SweepReport};
use crate::workloads::ModeledExecutor;

pub use crate::scenario::{volatility_name, Scenario, ScenarioMatrix, SweepPlan};

use super::run::run_full;

/// Default worker count for a sweep: one per available core, falling
/// back to 4 when parallelism cannot be queried.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// One finished cell, tagged by its scenario index and seed.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    /// Index into [`SweepRun::scenarios`].
    pub scenario: usize,
    pub seed: u64,
    pub report: RunReport,
}

/// A completed sweep: the expanded scenario list, every cell's full
/// report (scenario-major, seed order within a scenario), and the
/// cross-seed aggregation.
#[derive(Debug, Clone)]
pub struct SweepRun {
    pub scenarios: Vec<Scenario>,
    pub cells: Vec<CellResult>,
    pub report: SweepReport,
}

/// Run one `(scenario, seed)` cell: every registered axis overlays its
/// knob on the base config, fleet file, and run options
/// ([`Scenario::cell_inputs`]), and a fresh, fully independent
/// simulation replays the plan's jobs.  A non-zero input-MB axis value
/// overlays a per-job data shape on the plan's Job file (re-drawn per
/// seed, like a fresh dataset).
pub fn run_cell(plan: &SweepPlan, scenario: &Scenario, seed: u64) -> Result<RunReport> {
    let mut cell = scenario.cell_inputs(&plan.base_cfg, &plan.fleet, &plan.base_opts);
    cell.cfg.validate()?;
    cell.opts.seed = seed;
    let mut ex = ModeledExecutor {
        model: cell.model.clone(),
        ..Default::default()
    };
    if cell.input_mb > 0.0 {
        let jobs = plan
            .jobs
            .clone()
            .with_data_shape((cell.input_mb * 1e6) as u64, seed);
        run_full(&cell.cfg, &jobs, &cell.fleet, &mut ex, cell.opts)
    } else {
        run_full(&cell.cfg, &plan.jobs, &cell.fleet, &mut ex, cell.opts)
    }
}

/// Expand the plan's matrix and fail fast on invalid scenarios: one bad
/// cell config must not cost a full sweep's worth of simulation before
/// its error surfaces.  Shared by [`run_sweep`] and the sharded parent
/// and worker (`super::shard`), so both sides of the wire agree on what
/// a runnable plan is.
pub fn expand_and_validate(plan: &SweepPlan) -> Result<Vec<Scenario>> {
    let scenarios = plan.matrix.scenarios();
    ensure!(!scenarios.is_empty(), "sweep matrix has no scenarios");
    ensure!(!plan.matrix.seeds.is_empty(), "sweep matrix has no seeds");
    for sc in &scenarios {
        let cell = sc.cell_inputs(&plan.base_cfg, &plan.fleet, &plan.base_opts);
        cell.cfg
            .validate()
            .with_context(|| format!("invalid scenario '{}'", sc.label()))?;
        cell.fleet
            .validate()
            .with_context(|| format!("invalid scenario '{}'", sc.label()))?;
        ensure!(
            plan.fleet.on_demand_base <= sc.machines,
            "invalid scenario '{}': ON_DEMAND_BASE ({}) exceeds machines ({})",
            sc.label(),
            plan.fleet.on_demand_base,
            sc.machines
        );
    }
    Ok(scenarios)
}

/// Assemble a [`SweepRun`] from canonically-ordered cell results
/// (scenario-major, seed order) via the pure order-insensitive fold in
/// [`SweepReport::from_cells`] — the single report-assembly path shared
/// with the sharded parent.
pub(crate) fn assemble_run(
    scenarios: Vec<Scenario>,
    results: Vec<CellResult>,
    nseeds: usize,
) -> SweepRun {
    // The label and the machine-readable axis coordinates both come
    // from the registry — aggregation never hand-formats a scenario
    // identity.
    let ids: Vec<(String, Value)> = scenarios
        .iter()
        .map(|sc| (sc.label(), sc.axis_json()))
        .collect();
    let tagged: Vec<(usize, usize, &RunReport)> = results
        .iter()
        .enumerate()
        .map(|(i, c)| (c.scenario, i % nseeds, &c.report))
        .collect();
    let report = SweepReport::from_cells(&ids, &tagged);
    SweepRun {
        scenarios,
        cells: results,
        report,
    }
}

/// Run the whole matrix on `threads` worker threads (clamped to
/// `[1, cells]`).  Cells are claimed from a shared atomic counter —
/// classic work stealing, no per-thread partitioning imbalance — and each
/// result is written to its cell's slot, so the output order (and every
/// aggregate computed from it) is independent of scheduling.
pub fn run_sweep(plan: &SweepPlan, threads: usize) -> Result<SweepRun> {
    let scenarios = expand_and_validate(plan)?;

    let cells: Vec<(usize, u64)> = scenarios
        .iter()
        .enumerate()
        .flat_map(|(i, _)| plan.matrix.seeds.iter().map(move |&s| (i, s)))
        .collect();
    let threads = threads.max(1).min(cells.len());

    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<Result<RunReport>>>> =
        Mutex::new((0..cells.len()).map(|_| None).collect());
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let (scenario, seed) = cells[i];
                let report = run_cell(plan, &scenarios[scenario], seed);
                slots.lock().unwrap()[i] = Some(report);
            });
        }
    });

    let slots = slots.into_inner().unwrap();
    let mut results = Vec::with_capacity(cells.len());
    for (&(scenario, seed), slot) in cells.iter().zip(slots) {
        let report = slot
            .ok_or_else(|| anyhow!("sweep cell never ran (worker died?)"))?
            .with_context(|| {
                format!("sweep cell '{}' seed={seed}", scenarios[scenario].label())
            })?;
        results.push(CellResult {
            scenario,
            seed,
            report,
        });
    }

    Ok(assemble_run(scenarios, results, plan.matrix.seeds.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::{AllocationStrategy, InstanceSlot, Volatility};
    use crate::aws::s3::dataplane::NetProfile;
    use crate::config::JobSpec;
    use crate::json::Value;
    use crate::sim::MINUTE;
    use crate::workloads::DurationModel;

    fn small_plan() -> SweepPlan {
        let cfg = crate::testutil::fixtures::quick_cfg(2);
        let jobs = JobSpec::plate("P", 4, 2, vec![]);
        let matrix = ScenarioMatrix {
            seeds: vec![1, 2],
            cluster_machines: vec![1, 2],
            models: vec![DurationModel {
                mean_s: 30.0,
                cv: 0.2,
                ..Default::default()
            }],
            ..Default::default()
        };
        SweepPlan::new(cfg, jobs, matrix)
    }

    #[test]
    fn matrix_cartesian_product_order() {
        let m = ScenarioMatrix {
            seeds: vec![0, 1, 2],
            volatilities: vec![Volatility::Low, Volatility::High],
            visibilities: vec![MINUTE],
            cluster_machines: vec![1, 4],
            ..Default::default()
        };
        let scs = m.scenarios();
        assert_eq!(scs.len(), 4);
        assert_eq!(m.cell_count(), 12);
        // Machines outermost, then volatility.
        assert_eq!(scs[0].machines, 1);
        assert_eq!(scs[0].volatility, Volatility::Low);
        assert_eq!(scs[1].volatility, Volatility::High);
        assert_eq!(scs[2].machines, 4);
    }

    #[test]
    fn allocation_and_instance_set_axes_expand() {
        let m = ScenarioMatrix {
            allocations: AllocationStrategy::ALL.to_vec(),
            instance_sets: vec![
                Vec::new(),
                vec![InstanceSlot::new("m5.large"), InstanceSlot::new("c5.xlarge")],
            ],
            ..Default::default()
        };
        let scs = m.scenarios();
        assert_eq!(scs.len(), 6);
        // Allocation is the outer of the two new axes.
        assert_eq!(scs[0].allocation, AllocationStrategy::LowestPrice);
        assert!(scs[0].instance_set.is_empty());
        assert_eq!(scs[1].instance_set.len(), 2);
        assert_eq!(scs[2].allocation, AllocationStrategy::Diversified);
        // Labels stay distinct per scenario.
        let mut labels: Vec<String> = scs.iter().map(Scenario::label).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 6);
    }

    #[test]
    fn allocation_sweep_runs_and_reports_pools() {
        let mut plan = small_plan();
        plan.base_cfg.machine_price = 0.20;
        plan.matrix.seeds = vec![1];
        plan.matrix.cluster_machines = vec![2];
        plan.matrix.allocations =
            vec![AllocationStrategy::LowestPrice, AllocationStrategy::Diversified];
        plan.matrix.instance_sets = vec![vec![
            InstanceSlot::new("m5.large"),
            InstanceSlot::new("c5.xlarge"),
        ]];
        let run = run_sweep(&plan, 2).unwrap();
        assert_eq!(run.report.scenarios.len(), 2);
        // Diversified touches both pools; lowest-price concentrates in
        // the cheaper one (quiet market, both fit the bid).
        let lowest = &run.report.scenarios[0];
        let diversified = &run.report.scenarios[1];
        assert!(
            diversified.pools.iter().filter(|p| p.launched > 0).count() >= 2,
            "{:?}",
            diversified.pools
        );
        let launched = |s: &crate::metrics::ScenarioSummary, pool: &str| {
            s.pools
                .iter()
                .find(|p| p.pool == pool)
                .map(|p| p.launched)
                .unwrap_or(0)
        };
        assert!(
            launched(lowest, "m5.large") >= 2,
            "lowest-price should favor the cheap pool: {:?}",
            lowest.pools
        );
        assert!(launched(lowest, "m5.large") >= launched(lowest, "c5.xlarge"));
    }

    #[test]
    fn unknown_type_in_instance_set_fails_fast() {
        let mut plan = small_plan();
        plan.matrix.instance_sets = vec![vec![InstanceSlot::new("quantum.9000xl")]];
        let err = run_sweep(&plan, 1).unwrap_err();
        assert!(format!("{err:#}").contains("quantum.9000xl"), "{err:#}");
    }

    #[test]
    fn sweep_runs_every_cell_and_aggregates() {
        let plan = small_plan();
        let run = run_sweep(&plan, 2).unwrap();
        assert_eq!(run.cells.len(), 4);
        assert_eq!(run.report.scenarios.len(), 2);
        for s in &run.report.scenarios {
            assert_eq!(s.cells, 2);
            // 8 jobs per cell, 2 cells per scenario, all accounted for
            // (redeliveries can add skipped-done on top).
            assert!(s.completed + s.skipped_done + s.dead_lettered >= 16);
        }
        // Cells are scenario-major, seed order preserved.
        assert_eq!(
            run.cells.iter().map(|c| (c.scenario, c.seed)).collect::<Vec<_>>(),
            vec![(0, 1), (0, 2), (1, 1), (1, 2)]
        );
        // Every summary carries its registry-keyed axis coordinates.
        for (s, sc) in run.report.scenarios.iter().zip(&run.scenarios) {
            assert_eq!(
                s.axes.get("MACHINES").and_then(Value::as_u64),
                Some(u64::from(sc.machines))
            );
        }
    }

    #[test]
    fn sweep_identical_across_thread_counts() {
        let plan = small_plan();
        let one = run_sweep(&plan, 1).unwrap();
        let four = run_sweep(&plan, 4).unwrap();
        assert_eq!(one.report, four.report);
        assert_eq!(one.cells, four.cells);
    }

    #[test]
    fn oversized_thread_count_clamps() {
        let plan = small_plan();
        let run = run_sweep(&plan, 64).unwrap();
        assert_eq!(run.cells.len(), 4);
    }

    #[test]
    fn empty_matrix_rejected() {
        let mut plan = small_plan();
        plan.matrix.cluster_machines.clear();
        assert!(run_sweep(&plan, 1).is_err());
        let mut plan = small_plan();
        plan.matrix.seeds.clear();
        assert!(run_sweep(&plan, 1).is_err());
    }

    #[test]
    fn invalid_scenario_config_surfaces_label() {
        let mut plan = small_plan();
        plan.matrix.cluster_machines = vec![0]; // CLUSTER_MACHINES must be >= 1
        let err = run_sweep(&plan, 1).unwrap_err();
        assert!(format!("{err:#}").contains("m=0"), "{err:#}");
    }

    #[test]
    fn scenario_labels_are_stable() {
        let mut sc = Scenario {
            volatility: Volatility::Medium,
            visibility: 5 * MINUTE,
            machines: 8,
            allocation: AllocationStrategy::Diversified,
            instance_set: Vec::new(),
            input_mb: 0.0,
            net: NetProfile::default(),
            scaling: crate::coordinator::autoscale::ScalingMode::None,
            scaling_target: 4.0,
            model: DurationModel {
                mean_s: 120.0,
                ..Default::default()
            },
            workflow: None,
            sharing: crate::workflow::SharingMode::S3Staging,
            topology: None,
            placement: crate::topology::Placement::Pack,
            traffic: None,
            queueing: crate::traffic::QueueingPolicy::Fifo,
        };
        assert_eq!(sc.label(), "m=8 vis=5.0m vol=medium mean=120s alloc=diversified");
        sc.instance_set = vec![
            InstanceSlot::new("m5.large"),
            InstanceSlot {
                name: "m5.xlarge".into(),
                weight: 2,
            },
        ];
        assert_eq!(
            sc.label(),
            "m=8 vis=5.0m vol=medium mean=120s alloc=diversified set=m5.large+m5.xlarge:2"
        );
        // Data axes only show up when used — zero-data labels unchanged.
        sc.instance_set = Vec::new();
        sc.input_mb = 64.0;
        sc.net = NetProfile::narrow();
        assert_eq!(
            sc.label(),
            "m=8 vis=5.0m vol=medium mean=120s alloc=diversified in=64MB net=narrow"
        );
        // Scaling axes label only when a policy is engaged, at the end
        // of the fragment order — fixed-fleet labels stay byte-stable.
        sc.input_mb = 0.0;
        sc.net = NetProfile::default();
        sc.scaling = crate::coordinator::autoscale::ScalingMode::TargetTracking;
        sc.scaling_target = 3.0;
        assert_eq!(
            sc.label(),
            "m=8 vis=5.0m vol=medium mean=120s alloc=diversified scale=target-tracking tgt=3"
        );
    }

    #[test]
    fn scaling_axis_sweep_reports_breakdowns() {
        use crate::coordinator::autoscale::ScalingMode;
        let mut plan = small_plan();
        plan.matrix.seeds = vec![1];
        plan.matrix.cluster_machines = vec![2];
        plan.matrix.scalings = vec![ScalingMode::None, ScalingMode::TargetTracking];
        plan.matrix.scaling_targets = vec![1.0];
        let run = run_sweep(&plan, 2).unwrap();
        assert_eq!(run.report.scenarios.len(), 2);
        let fixed = &run.report.scenarios[0];
        let elastic = &run.report.scenarios[1];
        assert_eq!(fixed.scaling.policy, "none");
        assert_eq!(fixed.scaling.decisions, 0);
        assert_eq!(elastic.scaling.policy, "target-tracking");
        // Elasticity never loses work.
        assert_eq!(elastic.completed, 8);
        // The axes object carries the policy only when engaged, like
        // the label.
        assert!(fixed.axes.get("SCALING").is_none());
        assert_eq!(
            elastic.axes.get("SCALING").and_then(Value::as_str),
            Some("target-tracking")
        );
        assert_eq!(
            elastic.axes.get("SCALING_TARGET").and_then(Value::as_f64),
            Some(1.0)
        );
    }

    #[test]
    fn data_axes_expand_and_label_distinctly() {
        let m = ScenarioMatrix {
            input_mbs: vec![0.0, 64.0],
            net_profiles: vec![NetProfile::standard(), NetProfile::narrow()],
            ..Default::default()
        };
        let scs = m.scenarios();
        assert_eq!(scs.len(), 4);
        // input_mb is the outer of the two data axes.
        assert_eq!(scs[0].input_mb, 0.0);
        assert_eq!(scs[0].net, NetProfile::standard());
        assert_eq!(scs[1].net, NetProfile::narrow());
        assert_eq!(scs[2].input_mb, 64.0);
        let mut labels: Vec<String> = scs.iter().map(Scenario::label).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 4);
    }

    #[test]
    fn data_sweep_runs_and_reports_bytes() {
        let mut plan = small_plan();
        plan.matrix.seeds = vec![1];
        plan.matrix.cluster_machines = vec![2];
        plan.matrix.input_mbs = vec![0.0, 32.0];
        let run = run_sweep(&plan, 2).unwrap();
        assert_eq!(run.report.scenarios.len(), 2);
        let zero = &run.report.scenarios[0];
        let data = &run.report.scenarios[1];
        assert_eq!(zero.data.bytes_downloaded, 0);
        assert!(data.data.bytes_downloaded > 0, "{:?}", data.data);
        assert!(data.data.egress_usd > 0.0);
        // All 8 jobs still complete; moving bytes costs makespan.
        assert_eq!(data.completed, 8);
        assert!(data.makespan_s.mean > zero.makespan_s.mean);
    }
}
