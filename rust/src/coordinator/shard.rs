//! Sharded sweep execution: a typed wire contract and Lambda-style
//! parent/child dispatch (DESIGN.md §10).
//!
//! The in-process sweep engine ([`run_sweep`](super::sweep::run_sweep))
//! fans a plan out on one OS-thread pool; this module generalizes the
//! fan-out across *worker processes*, which is the paper's actual shape
//! — a coordinator handing chunks of a job matrix to disposable workers
//! and merging whatever comes back:
//!
//! * [`shard_plan`] deterministically partitions the scenario × seed
//!   matrix into balanced [`ShardAssignment`]s (every cell exactly once,
//!   sizes within ±1, round-robin striped so scenario-major cost
//!   gradients spread across shards).
//! * [`SweepShardRequest`] / [`ShardResult`] are the versioned JSON
//!   envelopes.  The plan travels as the self-contained Sweep file
//!   (`SweepFile::render`, already gated for bit-identical replay);
//!   the base run options the Sweep file does not carry (monitor mode,
//!   crash MTTF, engine selection, …) ride in a `base_opts` object, and
//!   per-cell results carry the *exact* [`RunReport`] — times as
//!   integer milliseconds, f64s through the shortest-round-trip
//!   formatter — so the parent can re-run the same pure fold the
//!   single-process engine uses.
//! * [`shard_worker`] is the child half: decode request → run assigned
//!   cells on a small thread pool → encode result.  `ds shard-worker`
//!   (hidden subcommand) wires it to stdin/stdout.
//! * [`run_sweep_sharded`] is the parent half: dispatch every shard
//!   through a [`ShardExecutor`] (separate process, or in-process for
//!   tests), supervise with bounded retry — each retry is a fresh
//!   dispatch — validate that every result matches its assignment
//!   exactly, and merge via [`SweepReport::from_cells`].
//!
//! The contract's load-bearing property is *bit identity*: for any
//! shard count, any thread count per shard, and any completion order,
//! the merged [`SweepReport`] equals the single-process one byte for
//! byte (table bytes and JSON bytes — `tests/sharding.rs` pins this
//! differentially).  Failures are structured, never silent: a shard
//! that exhausts its retries fails the sweep with a typed
//! [`ShardError`] carrying the child's stderr, and a result whose cell
//! set deviates from its assignment is rejected before it can poison
//! the merge.

use std::io::{Read, Write};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};
use thiserror::Error;

use crate::aws::billing::CostReport;
use crate::json::Value;
use crate::metrics::{
    DataBreakdown, PoolBreakdown, RunReport, RunStats, ScalingBreakdown, ScalingDecision,
    StageSpan, SweepReport, TenantBreakdown, TenantSlice, WorkflowBreakdown,
};
use crate::scenario::SweepFile;
use crate::sim::{QueueKind, SimTime, StoreKind};
use crate::topology::{DomainSlice, OutageWindow, TopologyBreakdown};

use super::run::{EngineOptions, RunOptions};
use super::sweep::{assemble_run, expand_and_validate, run_cell, CellResult, SweepRun};
pub use super::sweep::SweepPlan;

/// Version stamped on both envelopes.  Bump on any breaking change to
/// the field sets (the golden snapshots in `tests/golden/` pin them);
/// both the worker and the parent reject mismatched envelopes with a
/// typed error instead of guessing.
///
/// v2: the result envelope's per-cell reports grew the `workflow`
/// object (DAG breakdown, DESIGN.md §11) and the embedded Sweep file
/// learned the WORKFLOW/SHARING axes.
///
/// v3: the per-cell reports grew the `topology` object (per-domain
/// slices, cross-region egress, outage timelines, DESIGN.md §12) and
/// the embedded Sweep file learned the TOPOLOGY/PLACEMENT axes.
///
/// v4: the per-cell reports grew the `traffic` object (per-tenant job
/// counters, wait percentiles, SLO attainment, billed dollar share,
/// DESIGN.md §13) and the embedded Sweep file learned the
/// TRAFFIC/QUEUEING axes.
pub const WIRE_VERSION: u64 = 4;

const REQUEST_KIND: &str = "sweep-shard-request";
const RESULT_KIND: &str = "shard-result";

// ---------------------------------------------------------------------
// Shard plan
// ---------------------------------------------------------------------

/// One shard's slice of the sweep: which global cell indices it runs.
/// Cell `i` of a plan is scenario `i / seeds` at seed slot `i % seeds`
/// — the same scenario-major order the single-process engine uses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardAssignment {
    /// This shard's index in `0..count`.
    pub index: usize,
    /// Total shards in the plan.
    pub count: usize,
    /// Global cell indices assigned to this shard, ascending.
    pub cells: Vec<usize>,
}

/// Deterministically partition `cell_count` cells into at most `shards`
/// balanced shards (a pure function: re-invoking with the same inputs
/// yields the same plan).  Cells are striped round-robin, so shard
/// sizes differ by at most one and the expensive end of a scenario-major
/// matrix (big-machine scenarios cluster at high indices) spreads
/// across all workers instead of landing on the last one.
pub fn shard_plan(cell_count: usize, shards: usize) -> Vec<ShardAssignment> {
    let count = shards.clamp(1, cell_count.max(1));
    let mut plans: Vec<ShardAssignment> = (0..count)
        .map(|index| ShardAssignment {
            index,
            count,
            cells: Vec::with_capacity(cell_count / count + 1),
        })
        .collect();
    for cell in 0..cell_count {
        plans[cell % count].cells.push(cell);
    }
    plans
}

// ---------------------------------------------------------------------
// Wire codec
// ---------------------------------------------------------------------

fn field<'a>(v: &'a Value, key: &str) -> Result<&'a Value> {
    v.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
}

fn u64_field(v: &Value, key: &str) -> Result<u64> {
    field(v, key)?
        .as_u64()
        .ok_or_else(|| anyhow!("field '{key}' is not an unsigned integer"))
}

fn u32_field(v: &Value, key: &str) -> Result<u32> {
    u32::try_from(u64_field(v, key)?).with_context(|| format!("field '{key}' overflows u32"))
}

fn usize_field(v: &Value, key: &str) -> Result<usize> {
    usize::try_from(u64_field(v, key)?).with_context(|| format!("field '{key}' overflows usize"))
}

fn f64_field(v: &Value, key: &str) -> Result<f64> {
    field(v, key)?
        .as_f64()
        .ok_or_else(|| anyhow!("field '{key}' is not a number"))
}

fn bool_field(v: &Value, key: &str) -> Result<bool> {
    field(v, key)?
        .as_bool()
        .ok_or_else(|| anyhow!("field '{key}' is not a bool"))
}

fn str_field<'a>(v: &'a Value, key: &str) -> Result<&'a str> {
    field(v, key)?
        .as_str()
        .ok_or_else(|| anyhow!("field '{key}' is not a string"))
}

fn arr_field<'a>(v: &'a Value, key: &str) -> Result<&'a [Value]> {
    field(v, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("field '{key}' is not an array"))
}

/// Optional-SimTime field: `null` ⇔ `None`, integer milliseconds
/// otherwise.
fn opt_ms_field(v: &Value, key: &str) -> Result<Option<SimTime>> {
    match field(v, key)? {
        Value::Null => Ok(None),
        val => val
            .as_u64()
            .map(Some)
            .ok_or_else(|| anyhow!("field '{key}' is neither null nor integer ms")),
    }
}

fn opt_ms_json(t: Option<SimTime>) -> Value {
    match t {
        Some(ms) => Value::from(ms),
        None => Value::Null,
    }
}

/// The slice of [`RunOptions`] the Sweep file does *not* carry and no
/// axis overlays per cell: execution-mode knobs that must survive the
/// wire for the child to reproduce the parent's cells exactly.  The
/// axis-owned knobs (seed, volatility, net profile, scaling policy) are
/// deliberately absent — `Scenario::cell_inputs` overwrites them per
/// cell from the plan's matrix, which does travel.
fn opts_to_json(o: &RunOptions) -> Value {
    Value::obj()
        .with("monitor", o.monitor)
        .with("cheapest", o.cheapest)
        .with("queue_downscale", o.queue_downscale)
        .with("crash_mttf_ms", opt_ms_json(o.crash_mttf))
        .with("max_sim_time_ms", o.max_sim_time)
        .with("overrun_after_drain_ms", o.overrun_after_drain)
        .with("data_bucket", o.data_bucket.as_str())
        .with(
            "engine",
            Value::obj()
                .with(
                    "queue",
                    match o.engine.queue {
                        QueueKind::Heap => "heap",
                        QueueKind::Calendar => "calendar",
                    },
                )
                .with(
                    "store",
                    match o.engine.store {
                        StoreKind::Map => "map",
                        StoreKind::Dense => "dense",
                    },
                ),
        )
}

fn opts_from_json(v: &Value) -> Result<RunOptions> {
    let engine = field(v, "engine")?;
    let queue = match str_field(engine, "queue")? {
        "heap" => QueueKind::Heap,
        "calendar" => QueueKind::Calendar,
        other => bail!("unknown engine queue '{other}'"),
    };
    let store = match str_field(engine, "store")? {
        "map" => StoreKind::Map,
        "dense" => StoreKind::Dense,
        other => bail!("unknown engine store '{other}'"),
    };
    Ok(RunOptions {
        monitor: bool_field(v, "monitor")?,
        cheapest: bool_field(v, "cheapest")?,
        queue_downscale: bool_field(v, "queue_downscale")?,
        crash_mttf: opt_ms_field(v, "crash_mttf_ms")?,
        max_sim_time: u64_field(v, "max_sim_time_ms")?,
        overrun_after_drain: u64_field(v, "overrun_after_drain_ms")?,
        data_bucket: str_field(v, "data_bucket")?.to_string(),
        engine: EngineOptions { queue, store },
        ..RunOptions::default()
    })
}

/// Exact wire shape of a [`RunReport`].  Unlike `RunReport::to_json`
/// (a human-facing export that renders times as fractional seconds),
/// this codec keeps every `SimTime` as integer milliseconds and every
/// f64 as the shortest-round-trip decimal the repo's JSON layer
/// guarantees to parse back bit-exactly — a report must survive the
/// hop to the parent without losing a single bit, or the merged sweep
/// stops being byte-identical to the single-process one.
///
/// Struct fields are enumerated exhaustively (no `..Default::default()`
/// on decode), so adding a field to any report struct breaks this
/// module's compile instead of silently dropping data on the wire; the
/// golden snapshot `tests/golden/shard_result.keys` pins the emitted
/// field set.
pub fn report_to_wire(r: &RunReport) -> Value {
    let s = &r.stats;
    let stats = Value::obj()
        .with("completed", s.completed)
        .with("skipped_done", s.skipped_done)
        .with("duplicates", s.duplicates)
        .with("failed_attempts", s.failed_attempts)
        .with("stalled", s.stalled)
        .with("lost_to_death", s.lost_to_death)
        .with("dead_lettered", s.dead_lettered)
        .with("instances_launched", s.instances_launched)
        .with("interruptions", s.interruptions)
        .with("crashes", s.crashes)
        .with("alarm_terminations", s.alarm_terminations)
        .with("self_shutdowns", s.self_shutdowns)
        .with("events_processed", s.events_processed);
    let c = &r.cost;
    let cost = Value::obj()
        .with("ec2_usd", c.ec2_usd)
        .with("sqs_usd", c.sqs_usd)
        .with("s3_usd", c.s3_usd)
        .with("s3_egress_usd", c.s3_egress_usd)
        .with("cloudwatch_usd", c.cloudwatch_usd)
        .with("machine_hours", c.machine_hours)
        .with("on_demand_equivalent_usd", c.on_demand_equivalent_usd);
    let d = &r.data;
    let data = Value::obj()
        .with("bytes_downloaded", d.bytes_downloaded)
        .with("bytes_uploaded", d.bytes_uploaded)
        .with("bytes_wasted", d.bytes_wasted)
        .with("get_requests", d.get_requests)
        .with("put_requests", d.put_requests)
        .with("head_requests", d.head_requests)
        .with("list_requests", d.list_requests)
        .with("request_usd", d.request_usd)
        .with("egress_usd", d.egress_usd)
        .with("bucket_bound_ms", d.bucket_bound_ms)
        .with("nic_bound_ms", d.nic_bound_ms)
        .with("first_byte_wait_ms", d.first_byte_wait_ms);
    let sc = &r.scaling;
    let scaling = Value::obj()
        .with("policy", sc.policy.as_str())
        .with("decisions", sc.decisions)
        .with("scale_outs", sc.scale_outs)
        .with("scale_ins", sc.scale_ins)
        .with("units_launched", sc.units_launched)
        .with("units_terminated", sc.units_terminated)
        .with("peak_capacity", sc.peak_capacity)
        .with("floor_capacity", sc.floor_capacity)
        .with("capacity_unit_hours", sc.capacity_unit_hours)
        .with(
            "timeline",
            Value::Arr(
                sc.timeline
                    .iter()
                    .map(|dec| {
                        Value::obj()
                            .with("at_ms", dec.at)
                            .with("from", dec.from)
                            .with("to", dec.to)
                            .with("backlog", dec.backlog)
                    })
                    .collect(),
            ),
        );
    let w = &r.workflow;
    let workflow = Value::obj()
        .with("workflow", w.workflow.as_str())
        .with("sharing", w.sharing.as_str())
        .with("nodes", w.nodes)
        .with("edges", w.edges)
        .with("critical_path_len", w.critical_path_len)
        .with("releases", w.releases)
        .with("artifact_bytes_staged", w.artifact_bytes_staged)
        .with("stall_ms", w.stall_ms)
        .with(
            "stages",
            Value::Arr(
                w.stages
                    .iter()
                    .map(|st| {
                        Value::obj()
                            .with("depth", st.depth)
                            .with("released_ms", st.released_ms)
                            .with("committed_ms", st.committed_ms)
                    })
                    .collect(),
            ),
        );
    let t = &r.topology;
    let topology = Value::obj()
        .with("topology", t.topology.as_str())
        .with("placement", t.placement.as_str())
        .with(
            "domains",
            Value::Arr(
                t.domains
                    .iter()
                    .map(|d| {
                        Value::obj()
                            .with("domain", d.domain.as_str())
                            .with("region", d.region.as_str())
                            .with("launched", d.launched)
                            .with("interrupted", d.interrupted)
                            .with("jobs_completed", d.jobs_completed)
                            .with("cost_usd", d.cost_usd)
                    })
                    .collect(),
            ),
        )
        .with("xregion_bytes", t.xregion_bytes)
        .with("xregion_usd", t.xregion_usd)
        .with(
            "outages",
            Value::Arr(
                t.outages
                    .iter()
                    .map(|o| {
                        Value::obj()
                            .with("domain", o.domain.as_str())
                            .with("kind", o.kind.as_str())
                            .with("start_ms", o.start_ms)
                            .with("end_ms", o.end_ms)
                    })
                    .collect(),
            ),
        );
    let tr = &r.traffic;
    let traffic = Value::obj()
        .with("traffic", tr.traffic.as_str())
        .with("queueing", tr.queueing.as_str())
        .with(
            "tenants",
            Value::Arr(
                tr.tenants
                    .iter()
                    .map(|t| {
                        Value::obj()
                            .with("tenant", t.tenant.as_str())
                            .with("weight", t.weight)
                            .with("priority", t.priority)
                            .with("submitted", t.submitted)
                            .with("completed", t.completed)
                            .with("wait_p50_ms", t.wait_p50_ms)
                            .with("wait_p95_ms", t.wait_p95_ms)
                            .with("slo_target_ms", t.slo_target_ms)
                            .with("slo_attained", t.slo_attained)
                            .with("billed_usd", t.billed_usd)
                    })
                    .collect(),
            ),
        );
    Value::obj()
        .with("stats", stats)
        .with("drained_at_ms", opt_ms_json(r.drained_at))
        .with("ended_at_ms", r.ended_at)
        .with("cleaned_up", r.cleaned_up)
        .with("cost", cost)
        .with(
            "pools",
            Value::Arr(
                r.pools
                    .iter()
                    .map(|p| {
                        Value::obj()
                            .with("pool", p.pool.as_str())
                            .with("launched", p.launched)
                            .with("interrupted", p.interrupted)
                            .with("machine_hours", p.machine_hours)
                            .with("cost_usd", p.cost_usd)
                    })
                    .collect(),
            ),
        )
        .with("data", data)
        .with("scaling", scaling)
        .with("workflow", workflow)
        .with("topology", topology)
        .with("traffic", traffic)
        .with("jobs_submitted", r.jobs_submitted)
}

/// Inverse of [`report_to_wire`]; bit-exact (pinned by the round-trip
/// tests in `tests/sharding.rs`).
pub fn report_from_wire(v: &Value) -> Result<RunReport> {
    let sv = field(v, "stats")?;
    let stats = RunStats {
        completed: u64_field(sv, "completed")?,
        skipped_done: u64_field(sv, "skipped_done")?,
        duplicates: u64_field(sv, "duplicates")?,
        failed_attempts: u64_field(sv, "failed_attempts")?,
        stalled: u64_field(sv, "stalled")?,
        lost_to_death: u64_field(sv, "lost_to_death")?,
        dead_lettered: u64_field(sv, "dead_lettered")?,
        instances_launched: u64_field(sv, "instances_launched")?,
        interruptions: u64_field(sv, "interruptions")?,
        crashes: u64_field(sv, "crashes")?,
        alarm_terminations: u64_field(sv, "alarm_terminations")?,
        self_shutdowns: u64_field(sv, "self_shutdowns")?,
        events_processed: u64_field(sv, "events_processed")?,
    };
    let cv = field(v, "cost")?;
    let cost = CostReport {
        ec2_usd: f64_field(cv, "ec2_usd")?,
        sqs_usd: f64_field(cv, "sqs_usd")?,
        s3_usd: f64_field(cv, "s3_usd")?,
        s3_egress_usd: f64_field(cv, "s3_egress_usd")?,
        cloudwatch_usd: f64_field(cv, "cloudwatch_usd")?,
        machine_hours: f64_field(cv, "machine_hours")?,
        on_demand_equivalent_usd: f64_field(cv, "on_demand_equivalent_usd")?,
    };
    let pools = arr_field(v, "pools")?
        .iter()
        .map(|p| {
            Ok(PoolBreakdown {
                pool: str_field(p, "pool")?.to_string(),
                launched: u64_field(p, "launched")?,
                interrupted: u64_field(p, "interrupted")?,
                machine_hours: f64_field(p, "machine_hours")?,
                cost_usd: f64_field(p, "cost_usd")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let dv = field(v, "data")?;
    let data = DataBreakdown {
        bytes_downloaded: u64_field(dv, "bytes_downloaded")?,
        bytes_uploaded: u64_field(dv, "bytes_uploaded")?,
        bytes_wasted: u64_field(dv, "bytes_wasted")?,
        get_requests: u64_field(dv, "get_requests")?,
        put_requests: u64_field(dv, "put_requests")?,
        head_requests: u64_field(dv, "head_requests")?,
        list_requests: u64_field(dv, "list_requests")?,
        request_usd: f64_field(dv, "request_usd")?,
        egress_usd: f64_field(dv, "egress_usd")?,
        bucket_bound_ms: u64_field(dv, "bucket_bound_ms")?,
        nic_bound_ms: u64_field(dv, "nic_bound_ms")?,
        first_byte_wait_ms: u64_field(dv, "first_byte_wait_ms")?,
    };
    let scv = field(v, "scaling")?;
    let timeline = arr_field(scv, "timeline")?
        .iter()
        .map(|dec| {
            Ok(ScalingDecision {
                at: u64_field(dec, "at_ms")?,
                from: u32_field(dec, "from")?,
                to: u32_field(dec, "to")?,
                backlog: u64_field(dec, "backlog")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let scaling = ScalingBreakdown {
        policy: str_field(scv, "policy")?.to_string(),
        decisions: u64_field(scv, "decisions")?,
        scale_outs: u64_field(scv, "scale_outs")?,
        scale_ins: u64_field(scv, "scale_ins")?,
        units_launched: u64_field(scv, "units_launched")?,
        units_terminated: u64_field(scv, "units_terminated")?,
        peak_capacity: u32_field(scv, "peak_capacity")?,
        floor_capacity: u32_field(scv, "floor_capacity")?,
        capacity_unit_hours: f64_field(scv, "capacity_unit_hours")?,
        timeline,
    };
    let wv = field(v, "workflow")?;
    let stages = arr_field(wv, "stages")?
        .iter()
        .map(|st| {
            Ok(StageSpan {
                depth: u32_field(st, "depth")?,
                released_ms: u64_field(st, "released_ms")?,
                committed_ms: u64_field(st, "committed_ms")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let workflow = WorkflowBreakdown {
        workflow: str_field(wv, "workflow")?.to_string(),
        sharing: str_field(wv, "sharing")?.to_string(),
        nodes: u64_field(wv, "nodes")?,
        edges: u64_field(wv, "edges")?,
        critical_path_len: u64_field(wv, "critical_path_len")?,
        releases: u64_field(wv, "releases")?,
        artifact_bytes_staged: u64_field(wv, "artifact_bytes_staged")?,
        stall_ms: u64_field(wv, "stall_ms")?,
        stages,
    };
    let tv = field(v, "topology")?;
    let domains = arr_field(tv, "domains")?
        .iter()
        .map(|d| {
            Ok(DomainSlice {
                domain: str_field(d, "domain")?.to_string(),
                region: str_field(d, "region")?.to_string(),
                launched: u64_field(d, "launched")?,
                interrupted: u64_field(d, "interrupted")?,
                jobs_completed: u64_field(d, "jobs_completed")?,
                cost_usd: f64_field(d, "cost_usd")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let outages = arr_field(tv, "outages")?
        .iter()
        .map(|o| {
            Ok(OutageWindow {
                domain: str_field(o, "domain")?.to_string(),
                kind: str_field(o, "kind")?.to_string(),
                start_ms: u64_field(o, "start_ms")?,
                end_ms: u64_field(o, "end_ms")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let topology = TopologyBreakdown {
        topology: str_field(tv, "topology")?.to_string(),
        placement: str_field(tv, "placement")?.to_string(),
        domains,
        xregion_bytes: u64_field(tv, "xregion_bytes")?,
        xregion_usd: f64_field(tv, "xregion_usd")?,
        outages,
    };
    let trv = field(v, "traffic")?;
    let tenants = arr_field(trv, "tenants")?
        .iter()
        .map(|t| {
            Ok(TenantSlice {
                tenant: str_field(t, "tenant")?.to_string(),
                weight: u64_field(t, "weight")?,
                priority: u32_field(t, "priority")?,
                submitted: u64_field(t, "submitted")?,
                completed: u64_field(t, "completed")?,
                wait_p50_ms: u64_field(t, "wait_p50_ms")?,
                wait_p95_ms: u64_field(t, "wait_p95_ms")?,
                slo_target_ms: u64_field(t, "slo_target_ms")?,
                slo_attained: u64_field(t, "slo_attained")?,
                billed_usd: f64_field(t, "billed_usd")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let traffic = TenantBreakdown {
        traffic: str_field(trv, "traffic")?.to_string(),
        queueing: str_field(trv, "queueing")?.to_string(),
        tenants,
    };
    Ok(RunReport {
        stats,
        drained_at: opt_ms_field(v, "drained_at_ms")?,
        ended_at: u64_field(v, "ended_at_ms")?,
        cleaned_up: bool_field(v, "cleaned_up")?,
        cost,
        pools,
        data,
        scaling,
        workflow,
        topology,
        traffic,
        jobs_submitted: u64_field(v, "jobs_submitted")?,
    })
}

// ---------------------------------------------------------------------
// Envelopes
// ---------------------------------------------------------------------

/// Parent → child: one shard's work order.  The plan travels as the
/// self-contained Sweep file plus the non-axis `base_opts` slice, so a
/// fresh process with no shared memory reproduces the parent's cells
/// bit-identically.  Seeds ride through JSON numbers and are exact only
/// up to 2^53, the same documented bound as the Sweep file's `SEEDS`.
#[derive(Debug, Clone)]
pub struct SweepShardRequest {
    pub plan: SweepPlan,
    /// Worker threads the child should use for its cells.
    pub threads: usize,
    pub assignment: ShardAssignment,
}

impl SweepShardRequest {
    pub fn to_json(&self) -> Value {
        let plan_text = SweepFile::render(&self.plan);
        let plan_json =
            crate::json::parse(&plan_text).expect("rendered Sweep file is valid JSON");
        Value::obj()
            .with("kind", REQUEST_KIND)
            .with("version", WIRE_VERSION)
            .with("plan", plan_json)
            .with("base_opts", opts_to_json(&self.plan.base_opts))
            .with("threads", self.threads)
            .with(
                "assignment",
                Value::obj()
                    .with("index", self.assignment.index)
                    .with("count", self.assignment.count)
                    .with(
                        "cells",
                        Value::Arr(
                            self.assignment.cells.iter().map(|&c| Value::from(c)).collect(),
                        ),
                    ),
            )
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let kind = str_field(v, "kind")?;
        ensure!(kind == REQUEST_KIND, "unexpected envelope kind '{kind}'");
        let version = u64_field(v, "version")?;
        ensure!(
            version == WIRE_VERSION,
            "wire version mismatch: request carries v{version}, this worker speaks v{WIRE_VERSION}"
        );
        let plan_v = field(v, "plan")?;
        let mut plan = SweepFile::from_text(&plan_v.pretty())
            .context("decoding embedded Sweep file")?
            .to_plan()
            .context("expanding embedded Sweep file")?;
        plan.base_opts = opts_from_json(field(v, "base_opts")?).context("decoding base_opts")?;
        let av = field(v, "assignment")?;
        let cells = arr_field(av, "cells")?
            .iter()
            .map(|c| {
                c.as_u64()
                    .and_then(|n| usize::try_from(n).ok())
                    .ok_or_else(|| anyhow!("assignment cell is not an index"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            plan,
            threads: usize_field(v, "threads")?,
            assignment: ShardAssignment {
                index: usize_field(av, "index")?,
                count: usize_field(av, "count")?,
                cells,
            },
        })
    }
}

/// One finished cell on the wire: its global index plus the tagged
/// result.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardCell {
    /// Global cell index (matches the request's assignment).
    pub cell: usize,
    pub result: CellResult,
}

/// Why a result envelope failed to decode.  Version mismatches are
/// split out so the parent can surface them as the typed
/// [`ShardError::VersionMismatch`] instead of a generic parse failure.
#[derive(Debug, Error)]
pub enum WireError {
    #[error("wire version mismatch: got v{got}, expected v{want}")]
    Version { got: u64, want: u64 },
    #[error("{0}")]
    Malformed(String),
}

/// Child → parent: every assigned cell's exact report.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardResult {
    /// Which shard produced this (echoes the request's index).
    pub shard: usize,
    pub cells: Vec<ShardCell>,
}

impl ShardResult {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("kind", RESULT_KIND)
            .with("version", WIRE_VERSION)
            .with("shard", self.shard)
            .with(
                "cells",
                Value::Arr(
                    self.cells
                        .iter()
                        .map(|c| {
                            Value::obj()
                                .with("cell", c.cell)
                                .with("scenario", c.result.scenario)
                                .with("seed", c.result.seed)
                                .with("report", report_to_wire(&c.result.report))
                        })
                        .collect(),
                ),
            )
    }

    pub fn from_json(v: &Value) -> Result<Self, WireError> {
        let malformed = |msg: String| WireError::Malformed(msg);
        let kind = v
            .get("kind")
            .and_then(Value::as_str)
            .ok_or_else(|| malformed("missing 'kind'".into()))?;
        if kind != RESULT_KIND {
            return Err(malformed(format!("unexpected envelope kind '{kind}'")));
        }
        let got = v
            .get("version")
            .and_then(Value::as_u64)
            .ok_or_else(|| malformed("missing 'version'".into()))?;
        if got != WIRE_VERSION {
            return Err(WireError::Version {
                got,
                want: WIRE_VERSION,
            });
        }
        let decode = || -> Result<Self> {
            let cells = arr_field(v, "cells")?
                .iter()
                .map(|c| {
                    Ok(ShardCell {
                        cell: usize_field(c, "cell")?,
                        result: CellResult {
                            scenario: usize_field(c, "scenario")?,
                            seed: u64_field(c, "seed")?,
                            report: report_from_wire(field(c, "report")?)
                                .context("decoding cell report")?,
                        },
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(Self {
                shard: usize_field(v, "shard")?,
                cells,
            })
        };
        decode().map_err(|e| malformed(format!("{e:#}")))
    }
}

// ---------------------------------------------------------------------
// The child half
// ---------------------------------------------------------------------

/// The shard worker's whole body, pure text → text: decode a
/// [`SweepShardRequest`], run its assigned cells on a small
/// work-stealing thread pool (same claim-by-counter scheme as
/// `run_sweep`, so per-cell determinism is untouched), and encode the
/// [`ShardResult`].  `ds shard-worker` pipes stdin/stdout through this;
/// [`InProcExecutor`] calls it directly, which is what lets the fault
/// tests exercise the parent without process overhead.
pub fn shard_worker(input: &str) -> Result<String> {
    let v = crate::json::parse(input.trim()).context("parsing shard request")?;
    let req = SweepShardRequest::from_json(&v)?;
    let scenarios = expand_and_validate(&req.plan)?;
    let nseeds = req.plan.matrix.seeds.len();
    let cell_count = scenarios.len() * nseeds;
    for &cell in &req.assignment.cells {
        ensure!(
            cell < cell_count,
            "assignment references cell {cell} of a {cell_count}-cell sweep"
        );
    }
    let assigned = &req.assignment.cells;
    let threads = req.threads.clamp(1, assigned.len().max(1));
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<Result<RunReport>>>> =
        Mutex::new((0..assigned.len()).map(|_| None).collect());
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= assigned.len() {
                    break;
                }
                let cell = assigned[i];
                let seed = req.plan.matrix.seeds[cell % nseeds];
                let report = run_cell(&req.plan, &scenarios[cell / nseeds], seed);
                slots.lock().unwrap()[i] = Some(report);
            });
        }
    });
    let slots = slots.into_inner().unwrap();
    let mut cells = Vec::with_capacity(assigned.len());
    for (&cell, slot) in assigned.iter().zip(slots) {
        let scenario = cell / nseeds;
        let seed = req.plan.matrix.seeds[cell % nseeds];
        let report = slot
            .ok_or_else(|| anyhow!("shard cell never ran (worker died?)"))?
            .with_context(|| {
                format!("shard cell '{}' seed={seed}", scenarios[scenario].label())
            })?;
        cells.push(ShardCell {
            cell,
            result: CellResult {
                scenario,
                seed,
                report,
            },
        });
    }
    let result = ShardResult {
        shard: req.assignment.index,
        cells,
    };
    Ok(result.to_json().pretty())
}

// ---------------------------------------------------------------------
// Executors
// ---------------------------------------------------------------------

/// Why one dispatch attempt failed, before the retry policy weighs in.
#[derive(Debug, Error)]
pub enum ExecFailure {
    #[error("worker timed out after {0:?}")]
    Timeout(Duration),
    #[error("worker failed ({status}): {stderr}")]
    Crashed { status: String, stderr: String },
    #[error("spawning worker: {0}")]
    Spawn(String),
}

/// How the parent runs one shard attempt: hand over the request
/// envelope, get back the child's raw stdout.  `Sync` because the
/// parent dispatches shards from scoped threads.  Implementations:
/// [`ProcessExecutor`] (real child processes — production),
/// [`InProcExecutor`] (same-process — fast differential tests), and the
/// fault-injecting double in [`crate::testutil::shard_exec`].
pub trait ShardExecutor: Sync {
    fn run_shard(&self, request_json: &str) -> Result<String, ExecFailure>;
}

/// Runs the shard in-process by calling [`shard_worker`] directly.
/// Same code path as a real child minus the OS process, so the
/// differential tests can sweep shard × thread matrices cheaply.
pub struct InProcExecutor;

impl ShardExecutor for InProcExecutor {
    fn run_shard(&self, request_json: &str) -> Result<String, ExecFailure> {
        shard_worker(request_json).map_err(|e| ExecFailure::Crashed {
            status: "in-process worker error".to_string(),
            stderr: format!("{e:#}"),
        })
    }
}

/// Spawns `<exe> shard-worker` per attempt, feeds the request on stdin,
/// and enforces a wall-clock timeout (poll + kill — a hung child must
/// not hang the sweep).
pub struct ProcessExecutor {
    /// Binary to spawn (the `ds` binary itself in production).
    pub exe: PathBuf,
    /// Per-attempt wall-clock budget.
    pub timeout: Duration,
    /// Extra environment for the child.  Tests use this to arm the
    /// hidden `DS_SHARD_FAULT*` hooks without polluting the parent's
    /// own environment (env vars are process-global; test threads are
    /// not).
    pub envs: Vec<(String, String)>,
}

impl ProcessExecutor {
    pub fn new(exe: impl Into<PathBuf>, timeout: Duration) -> Self {
        Self {
            exe: exe.into(),
            timeout,
            envs: Vec::new(),
        }
    }

    /// The running binary itself: `ds sweep --shards N` re-invokes
    /// itself as `ds shard-worker`.
    pub fn current_exe(timeout: Duration) -> std::io::Result<Self> {
        Ok(Self::new(std::env::current_exe()?, timeout))
    }
}

impl ShardExecutor for ProcessExecutor {
    fn run_shard(&self, request_json: &str) -> Result<String, ExecFailure> {
        let mut cmd = Command::new(&self.exe);
        cmd.arg("shard-worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped());
        for (k, val) in &self.envs {
            cmd.env(k, val);
        }
        let mut child = cmd.spawn().map_err(|e| ExecFailure::Spawn(e.to_string()))?;
        // Feed the request and close stdin so the child sees EOF.  A
        // child that died before reading (EPIPE) surfaces through its
        // exit status below, not here.
        let mut stdin = child.stdin.take().expect("piped stdin");
        let fed = stdin.write_all(request_json.as_bytes()).is_ok();
        drop(stdin);
        // Drain stdout/stderr on their own threads: a shard result can
        // exceed the pipe buffer, and a child blocked on a full pipe
        // would be indistinguishable from a hang.
        let mut stdout = child.stdout.take().expect("piped stdout");
        let mut stderr = child.stderr.take().expect("piped stderr");
        let out_thread = thread::spawn(move || {
            let mut buf = Vec::new();
            stdout.read_to_end(&mut buf).ok();
            buf
        });
        let err_thread = thread::spawn(move || {
            let mut buf = Vec::new();
            stderr.read_to_end(&mut buf).ok();
            buf
        });
        let deadline = Instant::now() + self.timeout;
        let status = loop {
            match child.try_wait() {
                Ok(Some(status)) => break status,
                Ok(None) => {
                    if Instant::now() >= deadline {
                        child.kill().ok();
                        child.wait().ok();
                        out_thread.join().ok();
                        err_thread.join().ok();
                        return Err(ExecFailure::Timeout(self.timeout));
                    }
                    thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    child.kill().ok();
                    child.wait().ok();
                    return Err(ExecFailure::Spawn(format!("waiting on worker: {e}")));
                }
            }
        };
        let out = String::from_utf8_lossy(&out_thread.join().unwrap_or_default()).into_owned();
        let err = String::from_utf8_lossy(&err_thread.join().unwrap_or_default()).into_owned();
        if !status.success() {
            return Err(ExecFailure::Crashed {
                status: status.to_string(),
                stderr: err.trim().to_string(),
            });
        }
        if !fed {
            return Err(ExecFailure::Crashed {
                status: "exited 0 without reading its request".to_string(),
                stderr: err.trim().to_string(),
            });
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// The parent half
// ---------------------------------------------------------------------

/// Structured shard-level failure.  `Exhausted` is what callers see
/// when a shard burns through its retries; the boxed `last` error
/// preserves the final cause (including the child's stderr for crashes)
/// through anyhow's chain, and tests downcast to assert on the exact
/// variant.
#[derive(Debug, Error)]
pub enum ShardError {
    #[error("shard {shard}: {failure}")]
    Exec {
        shard: usize,
        failure: ExecFailure,
    },
    #[error("shard {shard}: result version mismatch (got v{got}, expected v{want})")]
    VersionMismatch { shard: usize, got: u64, want: u64 },
    #[error("shard {shard}: malformed result: {detail}")]
    Malformed { shard: usize, detail: String },
    #[error("shard {shard}: result does not match its assignment: {detail}")]
    AssignmentMismatch { shard: usize, detail: String },
    #[error("shard {shard} failed after {attempts} attempts; last error: {last}")]
    Exhausted {
        shard: usize,
        attempts: usize,
        last: Box<ShardError>,
    },
}

/// Parent-side knobs for a sharded sweep.
#[derive(Debug, Clone)]
pub struct ShardOptions {
    /// Worker shards (clamped to `[1, cells]` by the shard plan).
    pub shards: usize,
    /// Worker threads per shard.
    pub threads: usize,
    /// Extra attempts after a shard's first failure, each a fresh
    /// dispatch (for [`ProcessExecutor`]: a fresh process).
    pub retries: usize,
}

impl Default for ShardOptions {
    fn default() -> Self {
        Self {
            shards: 2,
            threads: 1,
            retries: 2,
        }
    }
}

/// One dispatch attempt: execute, decode, and hold the result to its
/// assignment — the returned cell set must equal the assigned set
/// exactly (no hole, no duplicate, no borrowed cell) and every cell's
/// scenario/seed tags must match its index, or the attempt fails before
/// anything reaches the merge.
fn attempt_shard(
    executor: &dyn ShardExecutor,
    assignment: &ShardAssignment,
    request_json: &str,
    nseeds: usize,
    seeds: &[u64],
) -> Result<ShardResult, ShardError> {
    let shard = assignment.index;
    let stdout = executor
        .run_shard(request_json)
        .map_err(|failure| ShardError::Exec { shard, failure })?;
    let v = crate::json::parse(stdout.trim()).map_err(|e| ShardError::Malformed {
        shard,
        detail: format!("invalid JSON: {e}"),
    })?;
    let result = ShardResult::from_json(&v).map_err(|e| match e {
        WireError::Version { got, want } => ShardError::VersionMismatch { shard, got, want },
        WireError::Malformed(detail) => ShardError::Malformed { shard, detail },
    })?;
    if result.shard != shard {
        return Err(ShardError::Malformed {
            shard,
            detail: format!("result labeled shard {}", result.shard),
        });
    }
    let mut got: Vec<usize> = result.cells.iter().map(|c| c.cell).collect();
    got.sort_unstable();
    let mut want = assignment.cells.clone();
    want.sort_unstable();
    if got != want {
        let missing: Vec<usize> = want.iter().copied().filter(|c| !got.contains(c)).collect();
        let extra: Vec<usize> = got
            .iter()
            .copied()
            .enumerate()
            .filter(|&(i, c)| !want.contains(&c) || (i > 0 && got[i - 1] == c))
            .map(|(_, c)| c)
            .collect();
        return Err(ShardError::AssignmentMismatch {
            shard,
            detail: format!("missing cells {missing:?}, unexpected or duplicated {extra:?}"),
        });
    }
    for c in &result.cells {
        let (scenario, seed) = (c.cell / nseeds, seeds[c.cell % nseeds]);
        if c.result.scenario != scenario || c.result.seed != seed {
            return Err(ShardError::AssignmentMismatch {
                shard,
                detail: format!(
                    "cell {} tagged (scenario {}, seed {}) but the plan says (scenario {scenario}, seed {seed})",
                    c.cell, c.result.scenario, c.result.seed
                ),
            });
        }
    }
    Ok(result)
}

/// Supervise one shard: bounded retry, each attempt a fresh dispatch.
fn supervise_shard(
    executor: &dyn ShardExecutor,
    assignment: &ShardAssignment,
    request_json: &str,
    retries: usize,
    nseeds: usize,
    seeds: &[u64],
) -> Result<ShardResult, ShardError> {
    let attempts = retries + 1;
    let mut last = None;
    for _ in 0..attempts {
        match attempt_shard(executor, assignment, request_json, nseeds, seeds) {
            Ok(r) => return Ok(r),
            Err(e) => last = Some(e),
        }
    }
    Err(ShardError::Exhausted {
        shard: assignment.index,
        attempts,
        last: Box::new(last.expect("at least one attempt ran")),
    })
}

/// The parent half: partition the plan with [`shard_plan`], dispatch
/// every shard through `executor` on its own supervisor thread (bounded
/// retry per shard), and fold the validated partial results back into a
/// [`SweepRun`] that is bit-identical to `run_sweep(plan, …)` — same
/// report, same table bytes, same JSON bytes, regardless of shard
/// count, per-shard thread count, or completion order.
///
/// Failure is structured: if any shard exhausts its retries the whole
/// sweep fails with that shard's typed [`ShardError`] (lowest shard
/// index wins when several fail), never a report with holes.
pub fn run_sweep_sharded(
    plan: &SweepPlan,
    opts: &ShardOptions,
    executor: &dyn ShardExecutor,
) -> Result<SweepRun> {
    let scenarios = expand_and_validate(plan)?;
    let nseeds = plan.matrix.seeds.len();
    let cell_count = scenarios.len() * nseeds;
    let assignments = shard_plan(cell_count, opts.shards);

    let requests: Vec<String> = assignments
        .iter()
        .map(|a| {
            SweepShardRequest {
                plan: plan.clone(),
                threads: opts.threads,
                assignment: a.clone(),
            }
            .to_json()
            .pretty()
        })
        .collect();

    let slots: Mutex<Vec<Option<Result<ShardResult, ShardError>>>> =
        Mutex::new((0..assignments.len()).map(|_| None).collect());
    thread::scope(|s| {
        let slots = &slots;
        let seeds = &plan.matrix.seeds;
        for a in &assignments {
            let request = &requests[a.index];
            s.spawn(move || {
                let res = supervise_shard(executor, a, request, opts.retries, nseeds, seeds);
                slots.lock().unwrap()[a.index] = Some(res);
            });
        }
    });

    // Merge in canonical cell order.  Assignments partition the cell
    // range and every result was validated against its assignment, so
    // the slot table fills exactly once; anything else is a bug worth a
    // loud panic, not a quietly wrong report.
    let mut collected: Vec<Option<CellResult>> = (0..cell_count).map(|_| None).collect();
    for slot in slots.into_inner().unwrap() {
        let result = slot.expect("every shard was supervised")?;
        for c in result.cells {
            let target = &mut collected[c.cell];
            assert!(target.is_none(), "cell {} produced by two shards", c.cell);
            *target = Some(c.result);
        }
    }
    let results: Vec<CellResult> = collected
        .into_iter()
        .enumerate()
        .map(|(i, slot)| slot.unwrap_or_else(|| panic!("cell {i} missing after merge")))
        .collect();
    Ok(assemble_run(scenarios, results, nseeds))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobSpec;
    use crate::coordinator::sweep::ScenarioMatrix;
    use crate::sim::HOUR;
    use crate::workloads::DurationModel;

    fn tiny_plan() -> SweepPlan {
        let cfg = crate::testutil::fixtures::quick_cfg(2);
        let jobs = JobSpec::plate("P", 2, 1, vec![]);
        let matrix = ScenarioMatrix {
            seeds: vec![1, 2],
            cluster_machines: vec![1, 2],
            models: vec![DurationModel {
                mean_s: 30.0,
                cv: 0.2,
                ..Default::default()
            }],
            ..Default::default()
        };
        SweepPlan::new(cfg, jobs, matrix)
    }

    #[test]
    fn shard_plan_is_balanced_and_exact() {
        let plans = shard_plan(10, 3);
        assert_eq!(plans.len(), 3);
        let mut all: Vec<usize> = plans.iter().flat_map(|p| p.cells.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        let sizes: Vec<usize> = plans.iter().map(|p| p.cells.len()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
    }

    #[test]
    fn shard_plan_clamps_shard_count_to_cells() {
        let plans = shard_plan(2, 8);
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].count, 2);
    }

    #[test]
    fn opts_round_trip_preserves_the_non_axis_slice() {
        let mut opts = RunOptions {
            monitor: false,
            cheapest: true,
            queue_downscale: true,
            crash_mttf: Some(40 * 60 * 1000),
            max_sim_time: 3 * HOUR,
            overrun_after_drain: 1234,
            data_bucket: "elsewhere".into(),
            engine: EngineOptions {
                queue: QueueKind::Heap,
                store: StoreKind::Map,
            },
            ..Default::default()
        };
        let back = opts_from_json(&opts_to_json(&opts)).unwrap();
        assert_eq!(back.monitor, opts.monitor);
        assert_eq!(back.cheapest, opts.cheapest);
        assert_eq!(back.queue_downscale, opts.queue_downscale);
        assert_eq!(back.crash_mttf, opts.crash_mttf);
        assert_eq!(back.max_sim_time, opts.max_sim_time);
        assert_eq!(back.overrun_after_drain, opts.overrun_after_drain);
        assert_eq!(back.data_bucket, opts.data_bucket);
        assert_eq!(back.engine, opts.engine);
        opts.crash_mttf = None;
        assert_eq!(opts_from_json(&opts_to_json(&opts)).unwrap().crash_mttf, None);
    }

    #[test]
    fn request_round_trips_and_runs_identically() {
        let plan = tiny_plan();
        let req = SweepShardRequest {
            plan: plan.clone(),
            threads: 2,
            assignment: shard_plan(4, 2)[1].clone(),
        };
        let v = crate::json::parse(&req.to_json().pretty()).unwrap();
        let back = SweepShardRequest::from_json(&v).unwrap();
        assert_eq!(back.threads, 2);
        assert_eq!(back.assignment, req.assignment);
        let a = crate::coordinator::sweep::run_sweep(&plan, 2).unwrap();
        let b = crate::coordinator::sweep::run_sweep(&back.plan, 2).unwrap();
        assert_eq!(a.cells, b.cells);
        assert_eq!(a.report.to_json().pretty(), b.report.to_json().pretty());
    }

    #[test]
    fn worker_rejects_version_mismatched_requests() {
        let req = SweepShardRequest {
            plan: tiny_plan(),
            threads: 1,
            assignment: shard_plan(4, 1)[0].clone(),
        };
        let bumped = match req.to_json() {
            Value::Obj(fields) => Value::Obj(
                fields
                    .into_iter()
                    .map(|(k, val)| {
                        if k == "version" {
                            (k, Value::from(WIRE_VERSION + 1))
                        } else {
                            (k, val)
                        }
                    })
                    .collect(),
            ),
            other => other,
        };
        let err = shard_worker(&bumped.pretty()).unwrap_err();
        assert!(format!("{err:#}").contains("version mismatch"), "{err:#}");
    }

    #[test]
    fn worker_rejects_out_of_range_assignments() {
        let req = SweepShardRequest {
            plan: tiny_plan(),
            threads: 1,
            assignment: ShardAssignment {
                index: 0,
                count: 1,
                cells: vec![99],
            },
        };
        let err = shard_worker(&req.to_json().pretty()).unwrap_err();
        assert!(format!("{err:#}").contains("cell 99"), "{err:#}");
    }
}
