//! The discrete-event run driver: everything that "happens automatically"
//! in Figure 1's orange text, plus the optional monitor.
//!
//! One [`Simulation`] owns the AWS account and an event queue.  Events:
//!
//! * `MarketTick`    (1/min) — spot prices move, fleets fulfill/interrupt,
//!   ECS places containers, instances publish CPU metrics.
//! * `InstanceReady` — boot finished: register with ECS, arm the crash
//!   clock.
//! * `CoreWake`      — one worker core polls SQS: CHECK_IF_DONE → run →
//!   (empty queue → instance self-shutdown).
//! * `JobDone`       — a job attempt finished: upload outputs, delete the
//!   message, next poll.
//! * `NetTick`       — the S3 data plane's next flow boundary: collect
//!   finished downloads/uploads, re-plan shared bandwidth.
//! * `InstanceCrash` — machine wedges: stops working, keeps billing,
//!   stops publishing CPU (the alarm reaper's prey).
//! * `AlarmEval`     (1/min) — CloudWatch alarm evaluation + actions.
//! * `MonitorTick`   (1/min, optional) — the paper's Step 4.
//!
//! Jobs whose message carries `input_bytes`/`output_bytes` are
//! **three-phase**: download (a timed flow on the data plane) → compute
//! (the executor) → upload (another flow); the message is only deleted
//! once the output bytes have flowed.  A core moving bytes is *not*
//! compute-busy, so its CPU metric stays low — big-enough transfers can
//! trip the paper's CPU-flatline reaper, exactly the failure mode real
//! storage-bound fleets hit.  Zero-data jobs take the duration-model
//! path unchanged (same events, same RNG draws), so pre-data-plane
//! experiments replay bit-identically.
//!
//! All randomness flows from one seeded RNG: identical runs replay
//! bit-identically.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::aws::billing::{data_breakdown, S3_XREGION_PER_GB};
use crate::aws::ec2::{
    FleetEvent, FleetId, InstanceId, InstanceState, MarketFault, MarketFaultKind,
    TerminationReason, Volatility,
};
use crate::aws::ecs::ContainerId;
use crate::aws::s3::dataplane::{Direction, FlowEnd, FlowId, NetProfile};
use crate::aws::s3::Body;
use crate::aws::sqs::ReceiptHandle;
use crate::aws::AwsAccount;
use crate::aws::cloudwatch::{AlarmAction, Comparison};
use crate::config::{AppConfig, FleetSpec, JobSpec};
use crate::json::Value;
use crate::metrics::{RunReport, RunStats};
use crate::sim::clock::{SimTime, HOUR, MINUTE};
use crate::sim::{Arena, EventQueue, QueueKind, SimRng, SlotId, StoreKind};
use crate::topology::{
    ClusterTopology, DomainSlice, FaultKind, OutageWindow, Placement, TopologyBreakdown,
};
use crate::traffic::{
    wait_percentile, DispatchState, QueueingPolicy, TenantBreakdown, TenantSlice, TrafficSpec,
};
use crate::worker::{check_if_done, parse_message};
use crate::workflow::{SharingMode, StageSpan, WorkflowBreakdown, WorkflowSpec};
use crate::workloads::drivers::{
    job_output_prefix, job_tag, output_bucket, JobCtx, JobExecutor, JobOutcome,
};

use super::autoscale::{AutoscaleState, ScalingPolicy};
use super::monitor::MonitorState;
use super::{cluster, setup, submit};

/// Which hot-path engine implementations a run uses.  The defaults are
/// the fast paths (calendar event queue, dense id-indexed entity
/// stores); the reference implementations (binary heap, hash maps) stay
/// selectable so the A/B equivalence gate in `tests/determinism.rs` can
/// prove the fast paths bit-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EngineOptions {
    /// Priority-queue backend for the event loop.
    pub queue: QueueKind,
    /// Entity-storage backend for EC2 instances / ECS containers.
    pub store: StoreKind,
}

/// Knobs for one simulated run.
#[derive(Debug, Clone)]
pub struct RunOptions {
    pub seed: u64,
    pub volatility: Volatility,
    /// Run the optional Step-4 monitor.
    pub monitor: bool,
    /// Cheapest mode (monitor's optional `True` flag).
    pub cheapest: bool,
    /// Monitor scales the fleet in as the queue drains (cheapest pool
    /// last).  Ignored without the monitor.
    pub queue_downscale: bool,
    /// Closed-loop elastic scaling policy (requires the monitor;
    /// mutually exclusive with cheapest mode and queue-downscale — one
    /// scale-in authority at a time).  See [`super::autoscale`].
    pub scaling: Option<ScalingPolicy>,
    /// Mean time to instance crash (None = reliable machines).
    pub crash_mttf: Option<SimTime>,
    /// Hard stop for the simulation.
    pub max_sim_time: SimTime,
    /// Without a monitor, keep simulating this long after the queue
    /// drains — measures the paper's "keep incurring charges" leak.
    pub overrun_after_drain: SimTime,
    /// Bucket that receives outputs and exported logs.
    pub data_bucket: String,
    /// S3 side of the data plane: per-bucket aggregate throughput and
    /// first-byte latency (only matters for jobs that declare bytes).
    pub net: NetProfile,
    /// Event-core engine selection (queue + entity-storage backends).
    pub engine: EngineOptions,
    /// DAG workflow replacing the flat job list: each job becomes
    /// SQS-visible only once every parent artifact has committed to the
    /// data plane (DESIGN.md §11).  `None` = flat submission.
    pub workflow: Option<WorkflowSpec>,
    /// Where intermediate workflow artifacts live and what moving them
    /// costs.  Only consulted for workflow runs.
    pub sharing: SharingMode,
    /// Failure-domain layout (regions → AZs) plus any scripted
    /// correlated faults (DESIGN.md §12).  `None` = the legacy
    /// single-domain world: every topology code path is skipped and the
    /// run replays bit-identically to pre-topology builds.
    pub topology: Option<ClusterTopology>,
    /// How the fleet spreads capacity across the topology's domains.
    /// Ignored without a topology.
    pub placement: Placement,
    /// Multi-tenant open-loop traffic replacing the flat job list: each
    /// tenant's jobs arrive over time on its declared arrival process
    /// (DESIGN.md §13).  `None` = the legacy closed batch: every traffic
    /// code path is skipped and the run replays bit-identically to
    /// pre-traffic builds.
    pub traffic: Option<TrafficSpec>,
    /// How the workers pick among tenants' queued messages.  FIFO is the
    /// legacy tenant-blind order (and the only policy consulted without
    /// a traffic spec).
    pub queueing: QueueingPolicy,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            seed: 42,
            volatility: Volatility::Low,
            monitor: true,
            cheapest: false,
            queue_downscale: false,
            scaling: None,
            crash_mttf: None,
            max_sim_time: 7 * 24 * HOUR,
            overrun_after_drain: 0,
            data_bucket: "ds-data".into(),
            net: NetProfile::default(),
            engine: EngineOptions::default(),
            workflow: None,
            sharing: SharingMode::default(),
            topology: None,
            placement: Placement::default(),
            traffic: None,
            queueing: QueueingPolicy::default(),
        }
    }
}

/// Extra first-byte latency (ms) a cross-region machine pays on every
/// bucket request: the inter-region round trip in front of S3's own
/// time-to-first-byte.
const XREGION_FIRST_BYTE_MS: SimTime = 60;

#[derive(Debug)]
enum Event {
    MarketTick,
    InstanceReady(InstanceId),
    CoreWake {
        container: ContainerId,
        core: u32,
    },
    JobDone {
        container: ContainerId,
        core: u32,
        receipt: ReceiptHandle,
        success: bool,
        bucket: String,
        outputs: Vec<(String, Body)>,
        log: String,
        /// Declared output footprint: non-zero routes the finish through
        /// an upload flow before the message is deleted.
        output_bytes: u64,
    },
    /// The data plane's next flow boundary.  `epoch` invalidates ticks
    /// scheduled before the flow set last changed.
    NetTick {
        epoch: u64,
    },
    InstanceCrash(InstanceId),
    AlarmEval,
    MonitorTick,
    /// A scheduled mid-run submission lands on the queue (bursty
    /// arrival patterns; see [`Simulation::submit_at`]).
    SubmitJobs(JobSpec),
    /// A tenant's open-loop generator fires: enqueue one job and draw
    /// the delay to the tenant's next arrival (index into the traffic
    /// spec's tenant table).
    TrafficArrival(usize),
    /// A scripted correlated fault opens (index into the topology's
    /// fault list): AZ outages kill everything running in the domain,
    /// bucket throttles squeeze the home bucket's aggregate budget.
    FaultStart(usize),
    /// The fault's window closes: restore whatever `FaultStart` took
    /// away (market-side pricing/capacity overlays clear on their own).
    FaultEnd(usize),
}

/// A job waiting on a data-plane flow (the state between phases).
#[derive(Debug)]
enum Xfer {
    /// Phase 1: the input download; compute starts when it lands.
    Download {
        container: ContainerId,
        core: u32,
        receipt: ReceiptHandle,
        bucket: String,
        msg: Value,
    },
    /// Phase 3: the output upload; the message is deleted (and the job
    /// counted) only once the bytes have flowed.
    Upload {
        container: ContainerId,
        core: u32,
        receipt: ReceiptHandle,
        bucket: String,
        outputs: Vec<(String, Body)>,
        log: String,
    },
}

/// Per-container core bookkeeping, stored in one arena slot for the
/// container's whole lifetime (placed → stopped).
#[derive(Debug)]
struct WorkerState {
    /// Cores currently in *compute* (a core moving bytes is not
    /// CPU-busy — that's what the reaper sees).
    busy: u32,
    /// Cores that saw an empty queue and exited.
    cores_done: u32,
}

/// Per-node scheduling state for a DAG workflow run.
#[derive(Debug)]
struct WfNode {
    parents: Vec<usize>,
    children: Vec<usize>,
    output_bytes: u64,
    depth: u32,
    /// Parents whose artifact has not committed yet; the node is
    /// released to SQS when this hits zero.
    unmet: usize,
    released_at: Option<SimTime>,
    committed_at: Option<SimTime>,
}

/// The readiness scheduler layered on the queue/worker loop: roots are
/// enqueued up front, everything else is held back until its parents'
/// artifacts commit.  While any node is unreleased, an empty queue is a
/// gap between stages, not the end of the workload — the monitor and the
/// no-monitor drain window both consult [`Simulation::workload_pending`].
#[derive(Debug)]
struct WorkflowState {
    spec: WorkflowSpec,
    nodes: Vec<WfNode>,
    /// Receipt of the delivery currently working each node (overwritten
    /// per redelivery): maps a finishing receipt back to its node.
    by_receipt: BTreeMap<ReceiptHandle, usize>,
    /// Nodes not yet released to SQS.
    pending_releases: usize,
    /// Artifact bytes moved through the sharing medium (staged uploads
    /// plus consumer downloads).
    bytes_staged: u64,
    /// Total time released children spent waiting on their remaining
    /// parents, measured from each node's first-committed parent.
    stall_ms: u64,
    /// Dependency-triggered releases (every node except the roots).
    releases: u64,
}

impl WorkflowState {
    fn new(spec: &WorkflowSpec) -> Self {
        let children = spec.children();
        let depths = spec.depths();
        let mut nodes = Vec::with_capacity(spec.node_count());
        for (i, (parents, children)) in spec.parents().into_iter().zip(children).enumerate() {
            nodes.push(WfNode {
                unmet: parents.len(),
                parents,
                children,
                output_bytes: spec.jobs[i].output_bytes,
                depth: depths[i],
                released_at: None,
                committed_at: None,
            });
        }
        let pending_releases = nodes.iter().filter(|n| n.unmet > 0).count();
        Self {
            spec: spec.clone(),
            nodes,
            by_receipt: BTreeMap::new(),
            pending_releases,
            bytes_staged: 0,
            stall_ms: 0,
            releases: 0,
        }
    }

    /// The SQS message body for node `i` — the same schema flat jobs
    /// use (`Metadata_*` tag, declared byte footprints), so the worker
    /// loop, CHECK_IF_DONE and the executors need no workflow awareness.
    fn message(&self, i: usize, bucket: &str) -> String {
        Value::obj()
            .with("Metadata_Task", self.spec.jobs[i].name.as_str())
            .with("input_bucket", bucket)
            .with("output_bucket", bucket)
            .with("input_bytes", self.spec.input_bytes(i))
            .with("output_bytes", self.spec.jobs[i].output_bytes)
            .pretty()
    }

    /// Node index for a delivered message, by its `Metadata_*` tag.
    fn node_of(&self, msg: &Value) -> Option<usize> {
        self.spec.index_of(&job_tag(msg))
    }
}

/// Per-tenant generator state for an open-loop traffic run.
#[derive(Debug)]
struct TenantState {
    /// The tenant's private arrival RNG, forked from a dedicated root so
    /// the schedule never interleaves with the main run RNG — arrival
    /// times are engine-invariant by construction.
    rng: SimRng,
    /// Jobs the generator has not enqueued yet.
    remaining: u64,
    submitted: u64,
    completed: u64,
    /// Queue wait (first enqueue → dispatch) of each completed job.
    waits_ms: Vec<u64>,
    /// Completed jobs whose wait met the tenant's SLO target.
    slo_attained: u64,
}

/// The open-loop generators plus the tenant-aware dispatch layer.  One
/// `TrafficArrival` event per tenant is in flight at a time: each firing
/// enqueues a job and draws the delay to the next, so quiet gaps are
/// real — while any tenant still has arrivals scheduled, an empty queue
/// is a gap in the workload, not its end (see
/// [`Simulation::workload_pending`]).
#[derive(Debug)]
struct TrafficState {
    spec: TrafficSpec,
    dispatch: DispatchState,
    tenants: Vec<TenantState>,
    /// Total jobs not yet enqueued across all tenants; while non-zero
    /// the monitor holds off end-of-run cleanup on an empty queue.
    pending_arrivals: u64,
    /// Receipt of each delivery in flight → (tenant index, queue wait at
    /// dispatch): resolved when the delete lands, dropped on skips and
    /// stale receipts.
    by_receipt: BTreeMap<ReceiptHandle, (usize, u64)>,
}

impl TrafficState {
    fn new(spec: &TrafficSpec, policy: QueueingPolicy, seed: u64) -> Self {
        let mut root = SimRng::new(seed ^ 0x7AF1C);
        let tenants = spec
            .tenants
            .iter()
            .enumerate()
            .map(|(i, t)| TenantState {
                rng: root.fork(i as u64 + 1),
                remaining: t.jobs,
                submitted: 0,
                completed: 0,
                waits_ms: Vec::new(),
                slo_attained: 0,
            })
            .collect();
        Self {
            spec: spec.clone(),
            dispatch: DispatchState::new(spec, policy),
            tenants,
            pending_arrivals: spec.total_jobs(),
            by_receipt: BTreeMap::new(),
        }
    }

    /// The SQS message body for tenant `i`'s `seq`-th job — the same
    /// schema flat jobs use (`Metadata_*` tag parts, output bucket), plus
    /// an explicit `tenant` key the dispatch layer and the accounting
    /// read back.  The seq makes each job's tag (and output prefix)
    /// unique, so CHECK_IF_DONE never false-skips a sibling.
    fn message(&self, i: usize, seq: u64, bucket: &str) -> String {
        let name = self.spec.tenants[i].name.as_str();
        Value::obj()
            .with("Metadata_Tenant", name)
            .with("Metadata_Seq", format!("{seq:04}"))
            .with("output_bucket", bucket)
            .with("tenant", name)
            .pretty()
    }

    /// Tenant index for a delivered message, by its `tenant` key.
    fn tenant_of(&self, msg: &Value) -> Option<usize> {
        msg.get("tenant")
            .and_then(Value::as_str)
            .and_then(|n| self.spec.index_of(n))
    }

    /// The dispatch chooser handed to [`crate::aws::sqs::Sqs::receive_choose`]:
    /// map each tenant to its head-of-line position in the visible queue,
    /// then let the policy pick.  Untagged messages (none in practice)
    /// degrade to FIFO.
    fn choose(&mut self, msgs: &[crate::aws::sqs::Message]) -> Option<usize> {
        let mut heads: Vec<Option<usize>> = vec![None; self.spec.tenant_count()];
        let mut tagged = false;
        for (pos, m) in msgs.iter().enumerate() {
            let Ok(v) = crate::json::parse(&m.body) else {
                continue;
            };
            if let Some(t) = self.tenant_of(&v) {
                tagged = true;
                if heads[t].is_none() {
                    heads[t] = Some(pos);
                }
            }
        }
        if !tagged {
            return Some(0);
        }
        self.dispatch.choose(&heads)
    }
}

/// A full DS run over the simulated account.
pub struct Simulation {
    pub acct: AwsAccount,
    pub cfg: AppConfig,
    opts: RunOptions,
    events: EventQueue<Event>,
    rng: SimRng,
    fleet: Option<FleetId>,
    monitor: Option<MonitorState>,
    stats: RunStats,
    jobs_submitted: u64,
    /// Scheduled `SubmitJobs` events not yet delivered; while non-zero
    /// the monitor holds off end-of-run cleanup on an empty queue.
    pending_submits: usize,
    /// Readiness scheduler for DAG runs (`opts.workflow`).
    workflow: Option<WorkflowState>,
    /// Open-loop arrival generators + tenant dispatch (`opts.traffic`).
    traffic: Option<TrafficState>,
    /// Per-container worker bookkeeping, one arena slot per live
    /// container (busy cores + exited cores together; the old design
    /// kept them in two parallel maps).
    workers: Arena<WorkerState>,
    /// Container id → arena slot, dense by raw id (container ids are
    /// sequential and never reused).
    container_slot: Vec<Option<SlotId>>,
    /// Jobs parked on a data-plane flow, dense by raw flow id (flow ids
    /// are sequential and never reused).
    flow_job: Vec<Option<Xfer>>,
    /// Bumped whenever the flow set changes; stale `NetTick`s no-op.
    net_epoch: u64,
    /// Jobs completed per failure domain (empty without a topology).
    domain_jobs: Vec<u64>,
    /// Bytes completed downloads moved across a region boundary.
    xregion_bytes: u64,
    /// Fault windows that actually opened during the run.
    outages: Vec<OutageWindow>,
    /// Scratch for `on_net_tick`'s finished-flow sweep: reused every
    /// tick so the steady-state event loop allocates nothing.
    net_done: Vec<(FlowId, FlowEnd)>,
    /// Scratch for `on_monitor_tick`'s stranded-transfer sweep.
    net_busy: Vec<InstanceId>,
    drained_at: Option<SimTime>,
    finished: bool,
}

impl Simulation {
    /// Create the account and run Step 1 (`setup`).
    pub fn new(cfg: AppConfig, opts: RunOptions) -> Result<Self> {
        let mut acct = AwsAccount::with_store(opts.seed, opts.volatility, opts.engine.store);
        acct.s3.create_bucket(&opts.data_bucket);
        acct.net.set_profile(opts.net.clone());
        setup::setup(&mut acct, &cfg, 0)?;
        // Install the failure-domain layout before any price path is
        // materialized: the market re-keys its walks per (domain, type)
        // and overlays the scripted pricing/capacity faults.  Without a
        // topology none of this runs and the account is bit-identical
        // to the legacy single-domain build.
        let mut domain_jobs = Vec::new();
        if let Some(topo) = &opts.topology {
            topo.validate().map_err(|e| anyhow::anyhow!("topology: {e}"))?;
            acct.ec2.install_topology(
                topo.domains.iter().map(|d| d.name.clone()).collect(),
                opts.placement,
            );
            for f in &topo.faults {
                let (start, end) = f.window_ms();
                let domain = topo.index_of(&f.domain).unwrap() as u32;
                let kind = match f.kind {
                    FaultKind::AzOutage => Some(MarketFaultKind::Outage),
                    FaultKind::PriceStorm => Some(MarketFaultKind::PriceStorm),
                    FaultKind::BucketThrottle => None, // data-plane side only
                };
                if let Some(kind) = kind {
                    acct.ec2.market.install_fault(MarketFault {
                        domain,
                        kind,
                        start,
                        end,
                        magnitude: f.magnitude,
                    });
                }
            }
            domain_jobs = vec![0; topo.domain_count()];
        }
        let rng = SimRng::new(opts.seed ^ 0xD15C);
        let engine = opts.engine;
        let workflow = opts.workflow.as_ref().map(WorkflowState::new);
        let traffic = match &opts.traffic {
            Some(spec) => {
                spec.validate().map_err(|e| anyhow::anyhow!("traffic: {e}"))?;
                ensure!(
                    opts.workflow.is_none(),
                    "traffic conflicts with a workflow (one workload generator at a time)"
                );
                Some(TrafficState::new(spec, opts.queueing, opts.seed))
            }
            None => None,
        };
        Ok(Self {
            acct,
            cfg,
            opts,
            events: EventQueue::with_kind(engine.queue),
            rng,
            fleet: None,
            monitor: None,
            stats: RunStats::default(),
            jobs_submitted: 0,
            pending_submits: 0,
            workflow,
            traffic,
            workers: Arena::new(),
            container_slot: Vec::new(),
            flow_job: Vec::new(),
            net_epoch: 0,
            domain_jobs,
            xregion_bytes: 0,
            outages: Vec::new(),
            net_done: Vec::new(),
            net_busy: Vec::new(),
            drained_at: None,
            finished: false,
        })
    }

    /// Stage data or otherwise mutate the account before the run (e.g.
    /// upload input images to S3).
    pub fn stage(&mut self, f: impl FnOnce(&mut AwsAccount)) {
        f(&mut self.acct);
    }

    /// Step 2: `submitJob`.
    pub fn submit(&mut self, jobs: &JobSpec) -> Result<u64> {
        let n = submit::submit_job(&mut self.acct, &self.cfg, jobs, self.events.now())?;
        self.jobs_submitted += n;
        Ok(n)
    }

    /// Schedule a submission `delay` after the current simulated time:
    /// the messages land on the queue mid-run (bursty arrival
    /// patterns).  The monitor defers end-of-run cleanup while
    /// scheduled submissions are outstanding, so a gap between bursts
    /// does not tear the cluster down.
    pub fn submit_at(&mut self, delay: SimTime, jobs: JobSpec) {
        self.pending_submits += 1;
        self.events.schedule_in(delay, Event::SubmitJobs(jobs));
    }

    /// Step 2 for a DAG run: enqueue the workflow's root jobs.  Every
    /// other node is released by the commit hook as its parents'
    /// artifacts land.  Returns the number of roots enqueued.
    pub fn submit_workflow(&mut self) -> Result<u64> {
        ensure!(
            self.workflow.is_some(),
            "run options carry no workflow — use submit() for flat job lists"
        );
        let now = self.events.now();
        let wf = self.workflow.as_mut().unwrap();
        let roots: Vec<usize> = (0..wf.nodes.len())
            .filter(|&i| wf.nodes[i].unmet == 0)
            .collect();
        for &i in &roots {
            let body = wf.message(i, &self.opts.data_bucket);
            self.acct
                .sqs
                .send(&self.cfg.sqs_queue_name, body, now)
                .map_err(|e| anyhow::anyhow!("sending workflow root: {e}"))?;
            wf.nodes[i].released_at = Some(now);
            self.jobs_submitted += 1;
        }
        Ok(roots.len() as u64)
    }

    /// Step 2 for an open-loop traffic run: arm each tenant's generator
    /// with its first arrival.  Nothing lands on the queue yet — every
    /// job is enqueued by its own `TrafficArrival` event, one scheduled
    /// draw per tenant at a time.  Returns the total jobs the generators
    /// will submit over the run.
    pub fn submit_traffic(&mut self) -> Result<u64> {
        ensure!(
            self.traffic.is_some(),
            "run options carry no traffic spec — use submit() for flat job lists"
        );
        let now = self.events.now();
        let tr = self.traffic.as_mut().unwrap();
        for i in 0..tr.spec.tenant_count() {
            let delay = tr.spec.process_of(i).next_delay_ms(&mut tr.tenants[i].rng, now);
            self.events.schedule_in(delay, Event::TrafficArrival(i));
        }
        Ok(tr.spec.total_jobs())
    }

    /// Step 3 (+4): `startCluster` and optionally `monitor`.
    pub fn start(&mut self, fleet_file: &FleetSpec) -> Result<()> {
        ensure!(
            self.jobs_submitted > 0
                || self.pending_submits > 0
                || self.traffic.as_ref().is_some_and(|t| t.pending_arrivals > 0),
            "submit jobs before starting the cluster"
        );
        ensure!(
            !(self.opts.cheapest && self.opts.queue_downscale),
            "queue_downscale conflicts with cheapest mode (cheapest never terminates running machines)"
        );
        if self.opts.scaling.is_some() {
            ensure!(
                self.opts.monitor,
                "scaling requires the monitor (the control loop lives on its tick)"
            );
            ensure!(
                !self.opts.cheapest && !self.opts.queue_downscale,
                "scaling conflicts with cheapest mode and queue-downscale (one scale-in authority at a time)"
            );
        }
        let fleet =
            cluster::start_cluster(&mut self.acct, &self.cfg, fleet_file, self.events.now())?;
        self.fleet = Some(fleet);
        self.events.schedule_in(0, Event::MarketTick);
        self.events.schedule_in(0, Event::AlarmEval);
        // Scripted fault windows become first-class events.  The
        // market-side overlays (pricing, capacity) are time-gated inside
        // the market itself, so ordering against the tick at the same
        // instant cannot change what fulfillment sees.
        if let Some(topo) = &self.opts.topology {
            for (idx, f) in topo.faults.iter().enumerate() {
                let (start, end) = f.window_ms();
                self.events.schedule_at(start, Event::FaultStart(idx));
                self.events.schedule_at(end, Event::FaultEnd(idx));
            }
        }
        if self.opts.monitor {
            let mut mon = MonitorState::new(
                fleet,
                self.opts.cheapest,
                &self.opts.data_bucket,
                self.events.now(),
            );
            if self.opts.queue_downscale {
                mon = mon.with_queue_downscale();
            }
            if let Some(policy) = &self.opts.scaling {
                let ctl = AutoscaleState::new(
                    policy.clone(),
                    fleet,
                    self.acct.ec2.fleet_target(fleet),
                    self.events.now(),
                );
                ctl.arm(&mut self.acct.alarms, &self.cfg, self.events.now());
                mon = mon.with_autoscale(ctl);
            }
            self.monitor = Some(mon);
            self.events.schedule_in(0, Event::MonitorTick);
        }
        Ok(())
    }

    /// Drive the event loop to completion.  `executor` is the inside of
    /// the Docker container (modeled or PJRT).
    pub fn run(&mut self, executor: &mut dyn JobExecutor) -> Result<RunReport> {
        ensure!(self.fleet.is_some(), "start the cluster before running");
        while let Some((now, ev)) = self.events.pop() {
            self.stats.events_processed += 1;
            if now >= self.opts.max_sim_time || self.finished {
                break;
            }
            self.handle(now, ev, executor);
            if self.should_stop(now) {
                break;
            }
        }
        Ok(self.report())
    }

    fn should_stop(&mut self, now: SimTime) -> bool {
        if self.finished {
            return true;
        }
        // Without a monitor the run "ends" for reporting purposes after
        // the queue has drained and the configured overrun has elapsed —
        // unless the workload is still pending (a gap between arrival
        // bursts or workflow stages is not the end of the workload).
        if self.monitor.is_none() && !self.workload_pending() {
            if let Some(d) = self.drained_at {
                if now >= d + self.opts.overrun_after_drain {
                    return true;
                }
            }
        }
        false
    }

    /// Scheduled submissions, unreleased workflow nodes, or future
    /// generator arrivals outstanding: an empty queue is a gap in the
    /// workload, not its end.  This is what generalizes "queue drained"
    /// into "workload done" for both the monitor's cleanup decision and
    /// the no-monitor drain window.  The traffic clause is the fix for
    /// the `submit_at`-era drain race: a quiet gap between arrival
    /// bursts used to look exactly like the end of the run.
    fn workload_pending(&self) -> bool {
        self.pending_submits > 0
            || self
                .workflow
                .as_ref()
                .is_some_and(|w| w.pending_releases > 0)
            || self
                .traffic
                .as_ref()
                .is_some_and(|t| t.pending_arrivals > 0)
    }

    // -- event handlers ----------------------------------------------------

    fn handle(&mut self, now: SimTime, ev: Event, executor: &mut dyn JobExecutor) {
        match ev {
            Event::MarketTick => self.on_market_tick(now),
            Event::InstanceReady(id) => self.on_instance_ready(now, id),
            Event::CoreWake { container, core } => {
                self.on_core_wake(now, container, core, executor)
            }
            Event::JobDone {
                container,
                core,
                receipt,
                success,
                bucket,
                outputs,
                log,
                output_bytes,
            } => self.on_job_done(
                now,
                container,
                core,
                receipt,
                success,
                bucket,
                outputs,
                log,
                output_bytes,
            ),
            Event::NetTick { epoch } => self.on_net_tick(now, epoch, executor),
            Event::InstanceCrash(id) => self.on_instance_crash(now, id),
            Event::AlarmEval => self.on_alarm_eval(now),
            Event::MonitorTick => self.on_monitor_tick(now),
            Event::SubmitJobs(jobs) => self.on_submit_jobs(now, &jobs),
            Event::TrafficArrival(tenant) => self.on_traffic_arrival(now, tenant),
            Event::FaultStart(idx) => self.on_fault_start(now, idx),
            Event::FaultEnd(idx) => self.on_fault_end(now, idx),
        }
    }

    // -- correlated faults --------------------------------------------------

    /// A scripted fault window opens.  The market already prices the
    /// window (capacity zeroed / prices multiplied from `start`); the
    /// driver's half is the *correlated* part: killing everything that
    /// is currently running in the domain, or squeezing the home
    /// bucket's aggregate budget.
    fn on_fault_start(&mut self, now: SimTime, idx: usize) {
        let (fault, domain, hits_home_bucket) = {
            let Some(topo) = &self.opts.topology else {
                return;
            };
            let f = topo.faults[idx].clone();
            let d = topo.index_of(&f.domain).unwrap();
            let home = topo.region_of(d) == topo.home_region();
            (f, d as u32, home)
        };
        let (start, end) = fault.window_ms();
        self.outages.push(OutageWindow {
            domain: fault.domain.clone(),
            kind: fault.kind.name().to_string(),
            start_ms: start,
            end_ms: end,
        });
        match fault.kind {
            FaultKind::AzOutage => {
                // Every machine in the domain goes dark at once — the
                // correlated loss AZ-spread placement exists to survive.
                for id in self.acct.ec2.active_in_domain(domain) {
                    self.stats.interruptions += 1;
                    self.log_instance(now, id, "AZ outage: correlated termination");
                    self.acct.ec2.terminate(id, TerminationReason::AzOutage, now);
                    self.instance_died(now, id);
                }
            }
            // Pricing is the market's overlay; interruptions follow on
            // the ordinary per-minute evaluation as prices cross bids.
            FaultKind::PriceStorm => {}
            FaultKind::BucketThrottle => {
                // The run's data bucket lives in the home region; a
                // throttle scripted against a cross-region domain has
                // nothing of ours to squeeze.
                if hits_home_bucket {
                    let bucket = self.opts.data_bucket.clone();
                    self.acct.net.set_bucket_factor(now, &bucket, fault.magnitude);
                    self.schedule_net_tick();
                }
            }
        }
    }

    /// The fault window closes: undo the data-plane squeeze.  Market
    /// overlays expire on their own, and outage-killed machines come
    /// back through ordinary fleet replacement.
    fn on_fault_end(&mut self, now: SimTime, idx: usize) {
        let restore = {
            let Some(topo) = &self.opts.topology else {
                return;
            };
            let f = &topo.faults[idx];
            f.kind == FaultKind::BucketThrottle
                && topo.region_of(topo.index_of(&f.domain).unwrap()) == topo.home_region()
        };
        if restore {
            let bucket = self.opts.data_bucket.clone();
            self.acct.net.set_bucket_factor(now, &bucket, 1.0);
            self.schedule_net_tick();
        }
    }

    fn on_market_tick(&mut self, now: SimTime) {
        // Publish per-instance CPU from busy-core counts.
        let fleet = self.fleet.unwrap();
        let running = self.acct.ec2.instances_in_state(fleet, InstanceState::Running);
        for id in &running {
            let crashed = self.acct.ec2.instance(*id).map(|i| i.crashed).unwrap_or(false);
            let containers = self.acct.ecs.containers_on(*id);
            let total_cores = (containers.len() as u32 * self.cfg.docker_cores).max(1);
            let busy: u32 = containers
                .iter()
                .map(|c| self.worker_busy(c.id))
                .sum();
            let cpu = if crashed {
                0.1
            } else {
                f64::from(busy) / f64::from(total_cores) * 100.0
            };
            self.acct
                .metrics
                .put("CPUUtilization", &format!("i-{id}"), now, cpu);
        }

        // Fleet evaluation: interruptions + fulfillment.
        let evs = self.acct.ec2.evaluate_fleets(now);
        self.apply_fleet_events(now, evs);

        // ECS placement pass.
        self.place_and_start_containers(now);

        // Storage billing integration.
        self.acct.sample_storage(now);

        self.events.schedule_in(MINUTE, Event::MarketTick);
    }

    /// Schedule the consequences of a batch of fleet events — from the
    /// per-minute evaluation or from a mid-run autoscale launch.
    fn apply_fleet_events(&mut self, now: SimTime, evs: Vec<FleetEvent>) {
        for ev in evs {
            match ev {
                FleetEvent::InstanceRequested { id, ready_at, .. } => {
                    self.stats.instances_launched += 1;
                    self.events.schedule_at(ready_at, Event::InstanceReady(id));
                }
                FleetEvent::InstanceInterrupted { id, price } => {
                    self.stats.interruptions += 1;
                    self.log_instance(now, id, &format!("spot interruption at ${price:.3}/h"));
                    self.instance_died(now, id);
                }
                FleetEvent::CapacityUnavailable { .. } => {}
            }
        }
    }

    fn on_instance_ready(&mut self, now: SimTime, id: InstanceId) {
        if !self.acct.ec2.mark_running(id, now) {
            return; // died while booting
        }
        let (vcpus, mem) = {
            let i = self.acct.ec2.instance(id).unwrap();
            (i.itype.vcpus, i.itype.memory_mb)
        };
        let _ = self.acct.ecs.register_instance(&self.cfg.ecs_cluster, id, vcpus, mem);
        self.log_instance(now, id, "boot complete, ECS agent registered");
        // Machines outside the bucket's home region pay an inter-region
        // round trip on every bucket request (first byte only; the
        // bandwidth model is unchanged).
        if let Some(topo) = &self.opts.topology {
            let domain = self.acct.ec2.instance(id).map(|i| i.domain).unwrap_or(0);
            if topo.is_cross_region(domain as usize) {
                self.acct.net.set_instance_penalty(id, XREGION_FIRST_BYTE_MS);
            }
        }
        // Arm the crash clock.
        if let Some(mttf) = self.opts.crash_mttf {
            let dt = crate::sim::clock::from_secs_f64(
                self.rng.exp(mttf as f64 / 1000.0),
            )
            .max(1);
            self.events.schedule_in(dt, Event::InstanceCrash(id));
        }
        self.place_and_start_containers(now);
    }

    /// ECS placement + container startup (naming, alarms, core wakes).
    fn place_and_start_containers(&mut self, now: SimTime) {
        let placed = self.acct.ecs.place_tasks(now);
        for c in placed {
            // "When a Docker container gets placed it gives the instance
            // it's on its own name" + per-instance alarm.
            let inst_id = c.instance;
            let needs_alarm = {
                let inst = self.acct.ec2.instance_mut(inst_id).unwrap();
                if inst.name_tag.is_none() {
                    inst.name_tag = Some(format!("{}Instance{}", self.cfg.app_name, inst_id));
                    true
                } else {
                    false
                }
            };
            if needs_alarm {
                self.acct.alarms.put_alarm(
                    &format!("{}_cpu_low_i-{}", self.cfg.app_name, inst_id),
                    "CPUUtilization",
                    &format!("i-{inst_id}"),
                    Comparison::LessThan,
                    1.0,
                    MINUTE,
                    15,
                    AlarmAction::TerminateInstance(inst_id),
                    now,
                );
            }
            self.log_instance(
                now,
                inst_id,
                &format!("container {} placed ({})", c.id, c.task_family),
            );
            self.new_worker(c.id);
            // SECONDS_TO_START staggers core startup.
            for core in 0..self.cfg.docker_cores {
                self.events.schedule_in(
                    u64::from(core) * self.cfg.seconds_to_start,
                    Event::CoreWake {
                        container: c.id,
                        core,
                    },
                );
            }
        }
    }

    // -- arena-backed per-run bookkeeping -----------------------------------

    fn slot_of(&self, container: ContainerId) -> Option<SlotId> {
        self.container_slot.get(container as usize).copied().flatten()
    }

    /// Busy-core count for a container (0 if it has no worker slot).
    fn worker_busy(&self, container: ContainerId) -> u32 {
        self.slot_of(container)
            .and_then(|s| self.workers.get(s))
            .map(|w| w.busy)
            .unwrap_or(0)
    }

    fn worker_mut(&mut self, container: ContainerId) -> Option<&mut WorkerState> {
        let slot = self.slot_of(container)?;
        self.workers.get_mut(slot)
    }

    /// Allocate the container's worker slot (at placement).
    fn new_worker(&mut self, container: ContainerId) {
        let slot = self.workers.insert(WorkerState {
            busy: 0,
            cores_done: 0,
        });
        let i = container as usize;
        if i >= self.container_slot.len() {
            self.container_slot.resize(i + 1, None);
        }
        self.container_slot[i] = Some(slot);
    }

    /// Release the container's worker slot (when it stops).  No-op if
    /// the slot was already released.
    fn free_worker(&mut self, container: ContainerId) {
        if let Some(slot) = self
            .container_slot
            .get_mut(container as usize)
            .and_then(Option::take)
        {
            self.workers.remove(slot);
        }
    }

    /// Park a job on a data-plane flow (flow ids are sequential).
    fn park_flow(&mut self, flow: FlowId, xfer: Xfer) {
        let i = flow as usize;
        if i >= self.flow_job.len() {
            self.flow_job.resize_with(i + 1, || None);
        }
        self.flow_job[i] = Some(xfer);
    }

    fn take_flow(&mut self, flow: FlowId) -> Option<Xfer> {
        self.flow_job.get_mut(flow as usize).and_then(Option::take)
    }

    fn container_alive(&self, container: ContainerId) -> Option<InstanceId> {
        let c = self.acct.ecs.container(container)?;
        if c.stopped {
            return None;
        }
        let inst = self.acct.ec2.instance(c.instance)?;
        (inst.state == InstanceState::Running && !inst.crashed).then_some(c.instance)
    }

    fn on_core_wake(
        &mut self,
        now: SimTime,
        container: ContainerId,
        core: u32,
        executor: &mut dyn JobExecutor,
    ) {
        let Some(inst_id) = self.container_alive(container) else {
            return;
        };
        // Tenant-aware dispatch only engages for a traffic run under a
        // non-FIFO policy; every other run takes the untouched legacy
        // receive, so pre-traffic experiments replay bit-identically
        // (and a FIFO-policy traffic run is byte-equal to head-of-line).
        let received = match (&mut self.traffic, self.opts.queueing) {
            (Some(tr), policy) if policy != QueueingPolicy::Fifo => self
                .acct
                .sqs
                .receive_choose(&self.cfg.sqs_queue_name, now, |msgs| tr.choose(msgs)),
            _ => self.acct.sqs.receive(&self.cfg.sqs_queue_name, now),
        };
        let received = match received {
            Ok(r) => r,
            Err(_) => return, // queue deleted: run is over
        };
        let Some((msg, receipt)) = received else {
            // "If SQS tells them there are no visible jobs then they shut
            // themselves down."
            self.core_exit(now, container, inst_id);
            return;
        };
        let Some(parsed) = parse_message(&msg.body) else {
            // Malformed message: fail it (leave in flight -> DLQ path).
            self.stats.failed_attempts += 1;
            self.log_instance(now, inst_id, "unparseable job message, exit 1");
            self.events.schedule_in(1_000, Event::CoreWake { container, core });
            return;
        };

        // A workflow delivery: remember which node this receipt works
        // so the finish paths can commit its artifact.
        if let Some(wf) = self.workflow.as_mut() {
            if let Some(i) = wf.node_of(&parsed) {
                wf.by_receipt.insert(receipt, i);
            }
        }

        // A traffic delivery: remember its tenant and the queue wait at
        // dispatch (first enqueue → now) so the finish paths can credit
        // the completion and judge the SLO.
        if let Some(tr) = self.traffic.as_mut() {
            if let Some(t) = tr.tenant_of(&parsed) {
                tr.by_receipt
                    .insert(receipt, (t, now.saturating_sub(msg.first_enqueued)));
            }
        }

        // CHECK_IF_DONE: skip already-complete jobs.
        let bucket = output_bucket(&parsed).to_string();
        let prefix = job_output_prefix(&parsed);
        if check_if_done(&mut self.acct.s3, &self.cfg.check_if_done, &bucket, &prefix) {
            let _ = self.acct.sqs.delete(&self.cfg.sqs_queue_name, receipt, now);
            self.stats.skipped_done += 1;
            self.log_job(now, &prefix, "already done, skipping (CHECK_IF_DONE)");
            // The outputs exist, so the artifact counts as committed —
            // children must not wait on a job that will never rerun.
            self.workflow_commit(now, receipt);
            // A skipped traffic delivery is not a completion: drop the
            // wait sample without counting it.
            if let Some(tr) = self.traffic.as_mut() {
                tr.by_receipt.remove(&receipt);
            }
            self.mark_drained_if_empty(now);
            self.events.schedule_in(0, Event::CoreWake { container, core });
            return;
        }

        // Phase 1, if the job declares input bytes: a timed download on
        // the data plane; compute starts when the flow lands.  Zero-data
        // jobs take the exact pre-data-plane path (same events, same RNG
        // draws), so old experiments replay bit-identically.
        let input_bytes = parsed.get("input_bytes").and_then(Value::as_u64).unwrap_or(0);
        if input_bytes > 0 {
            // Workflow consumers route by sharing mode: node-local pulls
            // straight from the producer's machine, shared-fs from the
            // filesystem link — both peer flows (no S3 requests, no
            // egress) that skip the HeadObject probe, since there is no
            // staged object to size.  S3 staging and every flat job take
            // the legacy path below.
            let flow = if let Some(link) = self.workflow_peer_link(&parsed) {
                if let Some(wf) = self.workflow.as_mut() {
                    wf.bytes_staged += input_bytes;
                }
                self.acct.net.start_peer(
                    now,
                    inst_id,
                    self.nic_gbps(inst_id),
                    &link,
                    Direction::Download,
                    input_bytes,
                )
            } else {
                let input_bucket = parsed
                    .get("input_bucket")
                    .and_then(Value::as_str)
                    .unwrap_or("ds-data")
                    .to_string();
                // Size the input first (HeadObject, like a worker does
                // before `aws s3 cp`): a billable request even when the
                // object only exists as a declared size.
                let input_key = crate::workloads::drivers::input_key(&parsed);
                let _ = self.acct.s3.head(&input_bucket, &input_key);
                if let Some(wf) = self.workflow.as_mut() {
                    if wf.node_of(&parsed).is_some() {
                        wf.bytes_staged += input_bytes;
                    }
                }
                self.acct.net.start(
                    now,
                    inst_id,
                    self.nic_gbps(inst_id),
                    &input_bucket,
                    Direction::Download,
                    input_bytes,
                )
            };
            self.park_flow(
                flow,
                Xfer::Download {
                    container,
                    core,
                    receipt,
                    bucket,
                    msg: parsed,
                },
            );
            self.schedule_net_tick();
            return;
        }
        self.start_compute(now, container, core, receipt, bucket, &parsed, executor);
    }

    /// Phase 2: run the tool.  Entered directly for zero-input jobs and
    /// at download completion for data-shaped ones.
    #[allow(clippy::too_many_arguments)]
    fn start_compute(
        &mut self,
        now: SimTime,
        container: ContainerId,
        core: u32,
        receipt: ReceiptHandle,
        bucket: String,
        msg: &Value,
        executor: &mut dyn JobExecutor,
    ) {
        let Some(inst_id) = self.container_alive(container) else {
            return;
        };
        let output_bytes = msg.get("output_bytes").and_then(Value::as_u64).unwrap_or(0);
        let mut ctx = JobCtx {
            s3: &mut self.acct.s3,
            rng: &mut self.rng,
            now,
        };
        match executor.execute(msg, &mut ctx) {
            JobOutcome::Done {
                duration,
                outputs,
                log,
            } => {
                if let Some(w) = self.worker_mut(container) {
                    w.busy += 1;
                }
                self.events.schedule_in(
                    duration,
                    Event::JobDone {
                        container,
                        core,
                        receipt,
                        success: true,
                        bucket,
                        outputs,
                        log,
                        output_bytes,
                    },
                );
            }
            JobOutcome::Failed { duration, log } => {
                if let Some(w) = self.worker_mut(container) {
                    w.busy += 1;
                }
                self.events.schedule_in(
                    duration,
                    Event::JobDone {
                        container,
                        core,
                        receipt,
                        success: false,
                        bucket,
                        outputs: Vec::new(),
                        log,
                        output_bytes: 0,
                    },
                );
            }
            JobOutcome::Stalled => {
                // Wedged core: never completes, never polls again.  The
                // message resurfaces via the visibility timeout; if every
                // core wedges, CPU -> 0 and the alarm reaper recovers the
                // machine.
                self.stats.stalled += 1;
                self.log_instance(now, inst_id, "worker stalled (no exit)");
            }
        }
    }

    /// The instance's NIC bandwidth from the shape sheet (Gbit/s).
    fn nic_gbps(&self, id: InstanceId) -> f64 {
        self.acct
            .ec2
            .instance(id)
            .map(|i| i.itype.nic_gbps)
            .unwrap_or(1.0)
    }

    /// (Re)arm the single outstanding `NetTick` after any change to the
    /// flow set.  The epoch bump invalidates previously scheduled ticks.
    fn schedule_net_tick(&mut self) {
        self.net_epoch += 1;
        if let Some(at) = self.acct.net.next_event() {
            let epoch = self.net_epoch;
            self.events.schedule_at(at, Event::NetTick { epoch });
        }
    }

    /// Collect flows that finished by `now` and advance their jobs to
    /// the next phase.
    fn on_net_tick(&mut self, now: SimTime, epoch: u64, executor: &mut dyn JobExecutor) {
        if epoch != self.net_epoch {
            return; // superseded by a later re-plan
        }
        // Reuse the scratch vector: the steady-state tick allocates
        // nothing (the report is bit-identical either way — see the
        // differential test in `aws::s3::dataplane`).
        let mut done = std::mem::take(&mut self.net_done);
        done.clear();
        self.acct.net.poll_into(now, &mut done);
        for i in 0..done.len() {
            let (flow, ref end) = done[i];
            // Cross-region byte accounting: a completed download whose
            // machine sits outside the bucket's region bills the
            // inter-region rate on top of the regular egress line.
            if end.dir == Direction::Download {
                self.account_xregion(end);
            }
            let Some(xfer) = self.take_flow(flow) else {
                continue;
            };
            match xfer {
                Xfer::Download {
                    container,
                    core,
                    receipt,
                    bucket,
                    msg,
                } => {
                    // A flow can finish in the same instant its machine
                    // dies (the death event pops first and cancellation
                    // finds the flow already complete): lost work, like
                    // the upload arm — the message redelivers.
                    if self.container_alive(container).is_none() {
                        self.stats.lost_to_death += 1;
                        continue;
                    }
                    self.start_compute(now, container, core, receipt, bucket, &msg, executor);
                }
                Xfer::Upload {
                    container,
                    core,
                    receipt,
                    bucket,
                    outputs,
                    log,
                } => {
                    if self.container_alive(container).is_none() {
                        self.stats.lost_to_death += 1;
                        continue;
                    }
                    self.finish_job(now, container, core, receipt, bucket, outputs, log);
                }
            }
        }
        done.clear();
        self.net_done = done;
        self.schedule_net_tick();
    }

    /// Count a completed download's bytes against the inter-region
    /// egress meter when its machine lives outside the data bucket's
    /// home region.  Peer links (node-local, shared-fs) never leave
    /// S3, so only the real data bucket is metered.
    fn account_xregion(&mut self, end: &FlowEnd) {
        let Some(topo) = &self.opts.topology else {
            return;
        };
        if end.bucket != self.opts.data_bucket {
            return;
        }
        let Some(inst) = self.acct.ec2.instance(end.instance) else {
            return;
        };
        if topo.is_cross_region(inst.domain as usize) {
            self.xregion_bytes += end.bytes;
        }
    }

    /// Land outputs, delete the message, count the job, poll again —
    /// the common tail of the zero-data and the post-upload paths.
    #[allow(clippy::too_many_arguments)]
    fn finish_job(
        &mut self,
        now: SimTime,
        container: ContainerId,
        core: u32,
        receipt: ReceiptHandle,
        bucket: String,
        outputs: Vec<(String, Body)>,
        log: String,
    ) {
        for (key, body) in outputs {
            let _ = self.acct.s3.put(&bucket, &key, body, now);
        }
        match self.acct.sqs.delete(&self.cfg.sqs_queue_name, receipt, now) {
            Ok(()) => {
                self.stats.completed += 1;
                self.count_domain_job(container);
                self.count_tenant_job(receipt);
                self.log_job(now, &log, "");
            }
            Err(_) => {
                // Receipt went stale: the message timed out mid-run
                // and someone else will (or did) redo it.  The redo's
                // own receipt carries the tenant accounting.
                self.stats.duplicates += 1;
                if let Some(tr) = self.traffic.as_mut() {
                    tr.by_receipt.remove(&receipt);
                }
                self.log_job(now, &log, " [duplicate: visibility expired mid-job]");
            }
        }
        // Commit the artifact (first completion wins; duplicates no-op)
        // *before* the drain check, so children released in this instant
        // keep the queue visibly non-empty.
        self.workflow_commit(now, receipt);
        self.mark_drained_if_empty(now);
        self.events.schedule_in(0, Event::CoreWake { container, core });
    }

    // -- workflow scheduling ------------------------------------------------

    /// For a workflow consumer in a peer sharing mode, the link its
    /// input artifact flows over (`None` = legacy S3 staging path).
    fn workflow_peer_link(&self, msg: &Value) -> Option<String> {
        let wf = self.workflow.as_ref()?;
        let i = wf.node_of(msg)?;
        match self.opts.sharing {
            SharingMode::S3Staging => None,
            SharingMode::SharedFs => Some("fs:shared".into()),
            // The artifact sits on the machine that produced it; name
            // the link after the (lexicographically first) producer so
            // each producer's NIC-side budget is its own.
            SharingMode::NodeLocal => {
                let producer = wf.nodes[i]
                    .parents
                    .iter()
                    .map(|&p| wf.spec.jobs[p].name.as_str())
                    .min()?;
                Some(format!("node:{producer}"))
            }
        }
    }

    /// The sharing mode governing a finishing delivery's output: flat
    /// jobs always stage through S3.
    fn sharing_of(&self, receipt: ReceiptHandle) -> SharingMode {
        match &self.workflow {
            Some(wf) if wf.by_receipt.contains_key(&receipt) => self.opts.sharing,
            _ => SharingMode::S3Staging,
        }
    }

    /// Commit the artifact behind a finished delivery and release any
    /// child whose last parent just landed.  The first commit wins;
    /// later duplicates of the same node no-op.
    fn workflow_commit(&mut self, now: SimTime, receipt: ReceiptHandle) {
        let Some(wf) = self.workflow.as_mut() else {
            return;
        };
        let Some(i) = wf.by_receipt.remove(&receipt) else {
            return;
        };
        if wf.nodes[i].committed_at.is_some() {
            return;
        }
        wf.nodes[i].committed_at = Some(now);
        if wf.nodes[i].output_bytes > 0 && self.opts.sharing != SharingMode::NodeLocal {
            // S3 staging and shared-fs park the artifact on the sharing
            // medium; node-local leaves it where it was produced.
            wf.bytes_staged += wf.nodes[i].output_bytes;
        }
        for c in wf.nodes[i].children.clone() {
            wf.nodes[c].unmet -= 1;
            if wf.nodes[c].unmet > 0 {
                continue;
            }
            // Released: this commit was the last parent the child was
            // waiting on.  Stall is measured from the child's
            // first-committed parent — how long the artifact sat before
            // the slowest sibling branch caught up.
            let first_parent_commit = wf.nodes[c]
                .parents
                .iter()
                .filter_map(|&p| wf.nodes[p].committed_at)
                .min()
                .unwrap_or(now);
            let body = wf.message(c, &self.opts.data_bucket);
            if self.acct.sqs.send(&self.cfg.sqs_queue_name, body, now).is_ok() {
                self.jobs_submitted += 1;
                // The queue is no longer drained (mirrors
                // `on_submit_jobs`); the fleet replaces any machines
                // that self-shut-down during the stage gap.
                self.drained_at = None;
            }
            wf.nodes[c].released_at = Some(now);
            wf.stall_ms += now.saturating_sub(first_parent_commit);
            wf.releases += 1;
            wf.pending_releases -= 1;
        }
    }

    /// The per-run [`WorkflowBreakdown`]: topology counts from the spec,
    /// scheduling counters from the run, one [`StageSpan`] per depth
    /// that saw at least one release and one commit.
    fn workflow_breakdown(&self) -> WorkflowBreakdown {
        let Some(wf) = &self.workflow else {
            return WorkflowBreakdown::default();
        };
        let max_depth = wf.nodes.iter().map(|n| n.depth).max().unwrap_or(0);
        let mut stages = Vec::new();
        for d in 0..=max_depth {
            let mut released: Option<SimTime> = None;
            let mut committed: Option<SimTime> = None;
            for n in wf.nodes.iter().filter(|n| n.depth == d) {
                if let Some(r) = n.released_at {
                    released = Some(released.map_or(r, |x: SimTime| x.min(r)));
                }
                if let Some(c) = n.committed_at {
                    committed = Some(committed.map_or(c, |x: SimTime| x.max(c)));
                }
            }
            if let (Some(released_ms), Some(committed_ms)) = (released, committed) {
                stages.push(StageSpan {
                    depth: d,
                    released_ms,
                    committed_ms,
                });
            }
        }
        WorkflowBreakdown {
            workflow: wf.spec.name.clone(),
            sharing: self.opts.sharing.name().to_string(),
            nodes: wf.spec.node_count() as u64,
            edges: wf.spec.edge_count() as u64,
            critical_path_len: wf.spec.critical_path_len(),
            releases: wf.releases,
            artifact_bytes_staged: wf.bytes_staged,
            stall_ms: wf.stall_ms,
            stages,
        }
    }

    /// Abort every flow on a dead or wedged machine.  Bytes already
    /// flowed stay billed (the re-download tax in `DataBreakdown`).
    fn cancel_transfers(&mut self, now: SimTime, id: InstanceId) {
        let cancelled = self.acct.net.cancel_instance(now, id);
        if !cancelled.is_empty() {
            for flow in &cancelled {
                self.take_flow(*flow);
            }
            self.schedule_net_tick();
        }
    }

    /// A core saw an empty queue: exit.  When all of a container's cores
    /// have exited the container stops; when the *last* container on the
    /// machine stops, the machine shuts itself down (paper: "If SQS tells
    /// them there are no visible jobs then they shut themselves down").
    /// Sibling containers still running jobs keep the machine alive, so a
    /// fast-exiting container cannot murder a sibling's in-flight work.
    /// The fleet replaces shut-down machines while the run is live and
    /// the ECS service re-places containers there, so late redeliveries
    /// (visibility timeouts, poison retries) always find a poller again.
    fn core_exit(&mut self, now: SimTime, container: ContainerId, inst_id: InstanceId) {
        let done = {
            let Some(w) = self.worker_mut(container) else {
                return;
            };
            w.cores_done += 1;
            w.cores_done
        };
        if done < self.cfg.docker_cores {
            return;
        }
        self.acct.ecs.stop_container(container);
        self.free_worker(container);
        if self.acct.ecs.containers_on(inst_id).is_empty() {
            self.stats.self_shutdowns += 1;
            self.log_instance(now, inst_id, "queue empty: shutting down");
            self.acct
                .ec2
                .terminate(inst_id, TerminationReason::SelfShutdown, now);
            for c in self.acct.ecs.deregister_instance(inst_id) {
                self.free_worker(c);
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_job_done(
        &mut self,
        now: SimTime,
        container: ContainerId,
        core: u32,
        receipt: ReceiptHandle,
        success: bool,
        bucket: String,
        outputs: Vec<(String, Body)>,
        log: String,
        output_bytes: u64,
    ) {
        if let Some(w) = self.worker_mut(container) {
            w.busy = w.busy.saturating_sub(1);
        }
        let Some(inst_id) = self.container_alive(container) else {
            // Machine died mid-job: work lost, message redelivers.
            self.stats.lost_to_death += 1;
            return;
        };
        if success {
            // Phase 3, if the job declares output bytes: the results
            // only land (and the message is only deleted) after the
            // upload flow drains.  Workflow producers route by sharing
            // mode: node-local publishes in place (no flow at all — the
            // consumer pays the transfer instead), shared-fs flows to
            // the filesystem link, S3 staging takes the legacy upload.
            let sharing = self.sharing_of(receipt);
            if output_bytes > 0 && sharing != SharingMode::NodeLocal {
                let flow = match sharing {
                    SharingMode::SharedFs => self.acct.net.start_peer(
                        now,
                        inst_id,
                        self.nic_gbps(inst_id),
                        "fs:shared",
                        Direction::Upload,
                        output_bytes,
                    ),
                    _ => self.acct.net.start(
                        now,
                        inst_id,
                        self.nic_gbps(inst_id),
                        &bucket,
                        Direction::Upload,
                        output_bytes,
                    ),
                };
                self.park_flow(
                    flow,
                    Xfer::Upload {
                        container,
                        core,
                        receipt,
                        bucket,
                        outputs,
                        log,
                    },
                );
                self.schedule_net_tick();
                return;
            }
            self.finish_job(now, container, core, receipt, bucket, outputs, log);
        } else {
            self.stats.failed_attempts += 1;
            self.log_instance(now, inst_id, &log);
            self.events.schedule_in(0, Event::CoreWake { container, core });
        }
    }

    fn on_instance_crash(&mut self, now: SimTime, id: InstanceId) {
        let Some(inst) = self.acct.ec2.instance_mut(id) else {
            return;
        };
        if inst.state != InstanceState::Running || inst.crashed {
            return;
        }
        inst.crashed = true;
        self.stats.crashes += 1;
        self.log_instance(now, id, "machine crash (CPU flatlines)");
        // Its containers stop making progress; busy counts stay (the
        // pending JobDone events will see the crash and drop the work).
        // In-flight transfers die with the machine: partial bytes billed.
        self.cancel_transfers(now, id);
    }

    fn on_alarm_eval(&mut self, now: SimTime) {
        let actions = self.acct.alarms.evaluate(&self.acct.metrics, now);
        for a in actions {
            match a {
                AlarmAction::TerminateInstance(id) => {
                    let active = self
                        .acct
                        .ec2
                        .instance(id)
                        .map(|i| i.is_active())
                        .unwrap_or(false);
                    if active {
                        self.stats.alarm_terminations += 1;
                        self.log_instance(now, id, "CPU<1% for 15 min: alarm terminating");
                        self.acct
                            .ec2
                            .terminate(id, TerminationReason::AlarmAction, now);
                        for c in self.acct.ecs.deregister_instance(id) {
                            self.free_worker(c);
                        }
                        self.acct.metrics.drop_dimension(&format!("i-{id}"));
                        // A machine that was only *network*-busy looks
                        // idle to the CPU alarm; its transfers are lost
                        // with it (the re-download tax).
                        self.cancel_transfers(now, id);
                    }
                }
                AlarmAction::RebootInstance(_) => {}
                // Scaling signals go to the monitor's controller; the
                // next monitor tick turns them into one bounded,
                // cooldown-gated capacity decision.
                AlarmAction::ScaleOut(_) | AlarmAction::ScaleIn(_) => {
                    if let Some(mon) = &mut self.monitor {
                        mon.scale_signal(&a);
                    }
                }
            }
        }
        self.events.schedule_in(MINUTE, Event::AlarmEval);
    }

    /// A scheduled mid-run submission: enqueue the jobs and re-open the
    /// drain window (the queue is no longer drained).
    fn on_submit_jobs(&mut self, now: SimTime, jobs: &JobSpec) {
        self.pending_submits = self.pending_submits.saturating_sub(1);
        match submit::submit_job(&mut self.acct, &self.cfg, jobs, now) {
            Ok(n) => {
                self.jobs_submitted += n;
                self.drained_at = None;
            }
            Err(_) => {
                // The queue is gone: the run ended before this burst
                // (no monitor + max-time cap).  Nothing to enqueue.
            }
        }
    }

    /// A tenant's generator fires: enqueue one job, then draw the delay
    /// to the tenant's next arrival and reschedule.  The per-tenant RNG
    /// never touches the main run RNG, so the schedule is a pure
    /// function of (seed, spec) — engine- and policy-invariant.
    fn on_traffic_arrival(&mut self, now: SimTime, tenant: usize) {
        let Some(tr) = self.traffic.as_mut() else {
            return;
        };
        if tr.tenants[tenant].remaining == 0 {
            return;
        }
        let seq = tr.tenants[tenant].submitted;
        let body = tr.message(tenant, seq, &self.opts.data_bucket);
        match self.acct.sqs.send(&self.cfg.sqs_queue_name, body, now) {
            Ok(()) => {
                tr.tenants[tenant].remaining -= 1;
                tr.tenants[tenant].submitted += 1;
                tr.pending_arrivals -= 1;
                self.jobs_submitted += 1;
                // The queue is no longer drained (mirrors `on_submit_jobs`).
                self.drained_at = None;
                if tr.tenants[tenant].remaining > 0 {
                    let delay = tr
                        .spec
                        .process_of(tenant)
                        .next_delay_ms(&mut tr.tenants[tenant].rng, now);
                    self.events.schedule_in(delay, Event::TrafficArrival(tenant));
                }
            }
            Err(_) => {
                // The queue is gone: the run ended before this tenant
                // finished arriving (no monitor + max-time cap).  Drop
                // the rest of its schedule so the pending count cannot
                // hold a dead run open.
                tr.pending_arrivals -= tr.tenants[tenant].remaining;
                tr.tenants[tenant].remaining = 0;
            }
        }
    }

    fn on_monitor_tick(&mut self, now: SimTime) {
        let pending = self.workload_pending();
        let Some(mut mon) = self.monitor.take() else {
            return;
        };
        let tick = mon.tick(&mut self.acct, &self.cfg, now, pending);
        self.monitor = Some(mon);
        let done = tick.done;
        // A scale-out decision launches immediately into the fleet's
        // allocation strategy: schedule the boots it produced.
        self.apply_fleet_events(now, tick.fleet_events);
        // The monitor terminates machines on its own (queue downscale,
        // final cleanup): abort transfers stranded on machines that are
        // no longer alive.
        let mut busy = std::mem::take(&mut self.net_busy);
        self.acct.net.instances_with_flows_into(&mut busy);
        for &id in &busy {
            let alive = self
                .acct
                .ec2
                .instance(id)
                .map(|i| i.state == InstanceState::Running && !i.crashed)
                .unwrap_or(false);
            if !alive {
                self.cancel_transfers(now, id);
            }
        }
        busy.clear();
        self.net_busy = busy;
        if done {
            self.finished = true;
        } else {
            self.events.schedule_in(MINUTE, Event::MonitorTick);
        }
    }

    fn instance_died(&mut self, now: SimTime, id: InstanceId) {
        for c in self.acct.ecs.deregister_instance(id) {
            self.free_worker(c);
        }
        self.acct.metrics.drop_dimension(&format!("i-{id}"));
        self.cancel_transfers(now, id);
    }

    /// Credit a completed job to the failure domain its container's
    /// machine sits in (no-op without a topology).
    fn count_domain_job(&mut self, container: ContainerId) {
        if self.domain_jobs.is_empty() {
            return;
        }
        let Some(c) = self.acct.ecs.container(container) else {
            return;
        };
        let Some(inst) = self.acct.ec2.instance(c.instance) else {
            return;
        };
        if let Some(slot) = self.domain_jobs.get_mut(inst.domain as usize) {
            *slot += 1;
        }
    }

    /// Credit a completed delivery to its tenant: the wait sample joins
    /// the percentile pool and the SLO verdict lands (no-op without a
    /// traffic spec, or for deliveries the dispatch never tagged).
    fn count_tenant_job(&mut self, receipt: ReceiptHandle) {
        let Some(tr) = self.traffic.as_mut() else {
            return;
        };
        let Some((t, wait)) = tr.by_receipt.remove(&receipt) else {
            return;
        };
        let ts = &mut tr.tenants[t];
        ts.completed += 1;
        ts.waits_ms.push(wait);
        if wait <= tr.spec.tenants[t].slo_wait_s * 1000 {
            ts.slo_attained += 1;
        }
    }

    fn mark_drained_if_empty(&mut self, now: SimTime) {
        if self.drained_at.is_none() {
            let (v, f) = self.acct.sqs.approximate_counts(&self.cfg.sqs_queue_name, now);
            if v == 0 && f == 0 {
                self.drained_at = Some(now);
            }
        }
    }

    fn log_instance(&mut self, now: SimTime, id: InstanceId, line: &str) {
        let group = self.cfg.instance_log_group();
        self.acct.logs.put(&group, &format!("i-{id}"), now, line);
    }

    fn log_job(&mut self, now: SimTime, line: &str, suffix: &str) {
        self.acct.logs.put(
            &self.cfg.log_group_name,
            "jobs",
            now,
            format!("{line}{suffix}"),
        );
    }

    // -- reporting ----------------------------------------------------------

    fn report(&mut self) -> RunReport {
        let ended_at = self.events.now();
        let mut stats = self.stats.clone();
        stats.dead_lettered = self
            .acct
            .sqs
            .approximate_counts(&self.cfg.sqs_dead_letter_queue, ended_at)
            .0 as u64;
        let cost = self.acct.cost_report(ended_at);
        let pools = self.acct.ec2.pool_breakdown(ended_at);
        let data = data_breakdown(self.acct.s3.stats(), self.acct.net.stats());
        let scaling = self
            .monitor
            .as_ref()
            .and_then(|m| m.scaling_breakdown(ended_at))
            .unwrap_or_default();
        let traffic = self.traffic_breakdown(cost.total_usd());
        RunReport {
            stats,
            drained_at: self.drained_at,
            ended_at,
            cleaned_up: self
                .monitor
                .as_ref()
                .map(|m| m.cleanup_done)
                .unwrap_or(false),
            cost,
            pools,
            data,
            scaling,
            workflow: self.workflow_breakdown(),
            topology: self.topology_breakdown(ended_at),
            traffic,
            jobs_submitted: self.jobs_submitted,
        }
    }

    /// The per-run [`TenantBreakdown`]: spec identity zipped with the
    /// driver's own counters (submissions, completions, sorted wait
    /// percentiles, SLO attainment) plus each tenant's bill share by
    /// completed-job fraction.  The default breakdown for traffic-free
    /// runs — their report JSON carries no traffic key.
    fn traffic_breakdown(&self, total_usd: f64) -> TenantBreakdown {
        let Some(tr) = &self.traffic else {
            return TenantBreakdown::default();
        };
        let total_completed: u64 = tr.tenants.iter().map(|t| t.completed).sum();
        let tenants = tr
            .spec
            .tenants
            .iter()
            .zip(&tr.tenants)
            .map(|(spec, ts)| {
                let mut waits = ts.waits_ms.clone();
                waits.sort_unstable();
                TenantSlice {
                    tenant: spec.name.clone(),
                    weight: spec.weight,
                    priority: spec.priority,
                    submitted: ts.submitted,
                    completed: ts.completed,
                    wait_p50_ms: wait_percentile(&waits, 0.5),
                    wait_p95_ms: wait_percentile(&waits, 0.95),
                    slo_target_ms: spec.slo_wait_s * 1000,
                    slo_attained: ts.slo_attained,
                    billed_usd: if total_completed == 0 {
                        0.0
                    } else {
                        total_usd * ts.completed as f64 / total_completed as f64
                    },
                }
            })
            .collect();
        TenantBreakdown {
            traffic: tr.spec.name.clone(),
            queueing: self.opts.queueing.name().to_string(),
            tenants,
        }
    }

    /// The per-run [`TopologyBreakdown`]: fleet usage per domain zipped
    /// with the driver's own counters (jobs per domain, cross-region
    /// bytes, fault windows that opened).  The default breakdown for
    /// topology-free runs — their report JSON carries no topology key.
    fn topology_breakdown(&mut self, ended_at: SimTime) -> TopologyBreakdown {
        let Some(topo) = self.opts.topology.clone() else {
            return TopologyBreakdown::default();
        };
        let usage = self.acct.ec2.domain_breakdown(ended_at);
        let domains = topo
            .domains
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let u = usage.get(i).cloned().unwrap_or_default();
                DomainSlice {
                    domain: d.name.clone(),
                    region: d.region.clone(),
                    launched: u.launched,
                    interrupted: u.interrupted,
                    jobs_completed: self.domain_jobs.get(i).copied().unwrap_or(0),
                    cost_usd: u.cost_usd,
                }
            })
            .collect();
        TopologyBreakdown {
            topology: topo.name.clone(),
            placement: self.opts.placement.name().to_string(),
            domains,
            xregion_bytes: self.xregion_bytes,
            xregion_usd: self.xregion_bytes as f64 / 1e9 * S3_XREGION_PER_GB,
            outages: self.outages.clone(),
        }
    }

    /// Events processed so far (perf telemetry).
    pub fn events_processed(&self) -> u64 {
        self.stats.events_processed
    }
}

/// Convenience wrapper: the full four-command flow with defaults.  When
/// the options carry a workflow, the DAG replaces `jobs` (only its
/// roots are enqueued up front; the rest release as parents commit).
/// When they carry a traffic spec, the tenants' generators replace
/// `jobs` (nothing is enqueued up front; every job arrives on its
/// tenant's process).
pub fn run_full(
    cfg: &AppConfig,
    jobs: &JobSpec,
    fleet_file: &FleetSpec,
    executor: &mut dyn JobExecutor,
    opts: RunOptions,
) -> Result<RunReport> {
    let mut sim = Simulation::new(cfg.clone(), opts)?;
    if sim.opts.traffic.is_some() {
        sim.submit_traffic()?;
    } else if sim.opts.workflow.is_some() {
        sim.submit_workflow()?;
    } else {
        sim.submit(jobs)?;
    }
    sim.start(fleet_file)?;
    sim.run(executor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::ModeledExecutor;

    fn quick_cfg() -> AppConfig {
        crate::testutil::fixtures::quick_cfg(3)
    }

    fn modeled(mean_s: f64) -> ModeledExecutor {
        crate::testutil::fixtures::modeled(mean_s)
    }

    #[test]
    fn full_run_completes_all_jobs_and_cleans_up() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 8, 4, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut ex = modeled(60.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap();
        assert_eq!(report.stats.completed, 32, "{}", report.summary());
        assert!(report.cleaned_up);
        assert!(report.fully_accounted());
        assert!(report.drained_at.is_some());
        assert!(report.cost.total_usd() > 0.0);
        // A flat run reports the flat workflow breakdown.
        assert_eq!(report.workflow, crate::workflow::WorkflowBreakdown::default());
    }

    #[test]
    fn deterministic_replay() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 4, 2, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let run = || {
            let mut ex = modeled(30.0);
            run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.drained_at, b.drained_at);
        assert_eq!(a.cost, b.cost);
    }

    #[test]
    fn check_if_done_skips_preexisting_outputs() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 4, 2, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut sim = Simulation::new(cfg, RunOptions::default()).unwrap();
        // Pre-stage outputs for half the jobs (first 4 of 8).
        sim.stage(|acct| {
            for g in jobs.to_messages().iter().take(4) {
                let msg = crate::json::parse(g).unwrap();
                let prefix = job_output_prefix(&msg);
                acct.s3
                    .put(
                        "ds-data",
                        &format!("{prefix}/out_0.csv"),
                        Body::Synthetic { size: 4096 },
                        0,
                    )
                    .unwrap();
            }
        });
        sim.submit(&jobs).unwrap();
        sim.start(&fleet).unwrap();
        let mut ex = modeled(30.0);
        let report = sim.run(&mut ex).unwrap();
        assert_eq!(report.stats.skipped_done, 4, "{}", report.summary());
        assert_eq!(report.stats.completed, 4);
    }

    #[test]
    fn no_monitor_leaves_resources_and_costs_more() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 4, 2, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mk_opts = |monitor| RunOptions {
            monitor,
            overrun_after_drain: 2 * HOUR,
            ..Default::default()
        };
        let mut ex = modeled(30.0);
        let with = run_full(&cfg, &jobs, &fleet, &mut ex, mk_opts(true)).unwrap();
        let mut ex = modeled(30.0);
        let without = run_full(&cfg, &jobs, &fleet, &mut ex, mk_opts(false)).unwrap();
        assert!(with.cleaned_up);
        assert!(!without.cleaned_up);
        assert_eq!(without.stats.completed, 8);
        // The unmonitored fleet keeps replacing self-shutdown instances
        // for two extra hours: strictly more EC2 spend.
        assert!(
            without.cost.ec2_usd > with.cost.ec2_usd * 1.5,
            "with=${:.4} without=${:.4}",
            with.cost.ec2_usd,
            without.cost.ec2_usd
        );
    }

    #[test]
    fn poison_jobs_go_to_dlq_and_run_still_ends() {
        let cfg = quick_cfg();
        let mut jobs = JobSpec::plate("P1", 4, 2, vec![]);
        // Poison two of the eight jobs.
        for g in jobs.groups.iter_mut().take(2) {
            g.push(("poison".into(), crate::json::Value::Bool(true)));
        }
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut ex = modeled(30.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap();
        assert_eq!(report.stats.completed, 6, "{}", report.summary());
        assert_eq!(report.stats.dead_lettered, 2);
        assert!(report.cleaned_up, "DLQ keeps the cluster from spinning forever");
        assert!(report.fully_accounted());
    }

    #[test]
    fn crashes_are_reaped_and_work_completes() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 12, 4, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let opts = RunOptions {
            crash_mttf: Some(40 * MINUTE),
            ..Default::default()
        };
        let mut ex = modeled(60.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap();
        assert!(report.stats.crashes > 0, "{}", report.summary());
        assert!(report.stats.alarm_terminations > 0);
        assert!(report.fully_accounted(), "{}", report.summary());
        assert!(report.cleaned_up);
    }

    #[test]
    fn heterogeneous_fleet_reports_per_pool_costs() {
        use crate::aws::ec2::{AllocationStrategy, InstanceSlot};
        let mut cfg = quick_cfg();
        cfg.cluster_machines = 4;
        cfg.machine_price = 0.20;
        let jobs = JobSpec::plate("P1", 8, 4, vec![]);
        let mut fleet = FleetSpec::template("us-east-1").unwrap();
        fleet.instance_types =
            vec![InstanceSlot::new("m5.large"), InstanceSlot::new("c5.xlarge")];
        fleet.allocation_strategy = AllocationStrategy::Diversified;
        fleet.on_demand_base = 1;
        let mut ex = modeled(60.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap();
        assert_eq!(report.stats.completed, 32, "{}", report.summary());
        assert!(report.cleaned_up);
        // Per-pool breakdown: both spot pools plus the on-demand slice.
        let labels: Vec<&str> = report.pools.iter().map(|p| p.pool.as_str()).collect();
        assert!(labels.contains(&"m5.large"), "{labels:?}");
        assert!(labels.contains(&"c5.xlarge"), "{labels:?}");
        assert!(labels.contains(&"m5.large/on-demand"), "{labels:?}");
        let pool_cost: f64 = report.pools.iter().map(|p| p.cost_usd).sum();
        assert!(
            (pool_cost - report.cost.ec2_usd).abs() < 1e-9,
            "pool sum {pool_cost} != ec2 {}",
            report.cost.ec2_usd
        );
        // The summary surfaces the per-pool lines.
        assert!(report.summary().contains("m5.large/on-demand"), "{}", report.summary());
    }

    #[test]
    fn queue_downscale_run_completes_and_shrinks_fleet() {
        use crate::aws::ec2::TerminationReason;
        let cfg = quick_cfg(); // 3 machines, 4 cores each
        let jobs = JobSpec::plate("P1", 10, 2, vec![]); // 20 jobs
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let opts = RunOptions {
            queue_downscale: true,
            ..Default::default()
        };
        let mut sim = Simulation::new(cfg, opts).unwrap();
        sim.submit(&jobs).unwrap();
        sim.start(&fleet).unwrap();
        let mut ex = modeled(300.0); // long jobs: the queue drains slowly
        let report = sim.run(&mut ex).unwrap();
        assert!(report.fully_accounted(), "{}", report.summary());
        assert!(report.cleaned_up);
        assert!(
            sim.acct
                .ec2
                .all_instances()
                .iter()
                .any(|i| i.termination_reason == Some(TerminationReason::FleetDownscale)),
            "queue downscale never fired: {}",
            report.summary()
        );
    }

    #[test]
    fn queue_downscale_conflicts_with_cheapest() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 2, 1, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let opts = RunOptions {
            cheapest: true,
            queue_downscale: true,
            ..Default::default()
        };
        let mut sim = Simulation::new(cfg, opts).unwrap();
        sim.submit(&jobs).unwrap();
        let err = sim.start(&fleet).unwrap_err();
        assert!(err.to_string().contains("cheapest"), "{err}");
    }

    #[test]
    fn scaling_requires_monitor_and_excludes_other_downscalers() {
        use crate::coordinator::autoscale::ScalingPolicy;
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 2, 1, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let bad = [
            RunOptions {
                scaling: Some(ScalingPolicy::target_tracking(4.0)),
                monitor: false,
                ..Default::default()
            },
            RunOptions {
                scaling: Some(ScalingPolicy::target_tracking(4.0)),
                cheapest: true,
                ..Default::default()
            },
            RunOptions {
                scaling: Some(ScalingPolicy::step(4.0)),
                queue_downscale: true,
                ..Default::default()
            },
        ];
        for opts in bad {
            let mut sim = Simulation::new(cfg.clone(), opts).unwrap();
            sim.submit(&jobs).unwrap();
            assert!(sim.start(&fleet).is_err());
        }
    }

    #[test]
    fn elastic_run_scales_in_while_draining_and_completes() {
        let cfg = quick_cfg(); // 3 machines = 12 workers
        let jobs = JobSpec::plate("P1", 12, 2, vec![]); // 24 jobs
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let policy = crate::coordinator::autoscale::ScalingPolicy::target_tracking(8.0);
        let opts = RunOptions {
            scaling: Some(policy),
            ..Default::default()
        };
        let mut ex = modeled(300.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap();
        assert!(report.fully_accounted(), "{}", report.summary());
        assert!(report.cleaned_up);
        assert_eq!(report.scaling.policy, "target-tracking");
        // The wide scale-in band shrinks the fleet as the queue drains.
        assert!(report.scaling.scale_ins >= 1, "{:?}", report.scaling);
        assert!(report.scaling.floor_capacity < 3, "{:?}", report.scaling);
        assert_eq!(
            report.scaling.decisions as usize,
            report.scaling.timeline.len()
        );
        // The summary line surfaces the policy.
        assert!(report.summary().contains("scaling(target-tracking)"), "{}", report.summary());
    }

    #[test]
    fn bursty_arrivals_hold_cleanup_and_rescale_out() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 6, 2, vec![]); // 12 jobs per wave
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut policy = crate::coordinator::autoscale::ScalingPolicy::target_tracking(1.0);
        policy.limits.scale_in_cooldown = 2 * MINUTE;
        policy.limits.warmup = 2 * MINUTE;
        let opts = RunOptions {
            scaling: Some(policy),
            ..Default::default()
        };
        let mut sim = Simulation::new(cfg, opts).unwrap();
        sim.submit(&jobs).unwrap();
        sim.submit_at(40 * MINUTE, jobs.clone());
        sim.start(&fleet).unwrap();
        let mut ex = modeled(120.0);
        let report = sim.run(&mut ex).unwrap();
        assert_eq!(report.jobs_submitted, 24);
        assert!(report.fully_accounted(), "{}", report.summary());
        assert!(report.cleaned_up, "cleanup only after the last wave");
        // The final drain postdates the second wave: drained_at re-opens
        // when a scheduled burst lands.
        assert!(report.drained_at.unwrap() > 40 * MINUTE);
        // The idle gap scaled the fleet in; the second wave scaled it
        // back out through the alarm loop.
        assert!(report.scaling.scale_ins >= 1, "{:?}", report.scaling);
        assert!(report.scaling.scale_outs >= 1, "{:?}", report.scaling);
        assert!(report.scaling.floor_capacity == 1, "{:?}", report.scaling);
        assert_eq!(report.scaling.peak_capacity, 3);
    }

    #[test]
    fn zero_byte_data_fields_take_the_legacy_path() {
        // Jobs that *declare* input_bytes/output_bytes = 0 must replay
        // bit-identically to jobs that never heard of the data plane —
        // the acceptance gate for every pre-data-plane experiment.
        let cfg = quick_cfg();
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut ex = modeled(45.0);
        let plain = run_full(
            &cfg,
            &JobSpec::plate("P1", 4, 2, vec![]),
            &fleet,
            &mut ex,
            RunOptions::default(),
        )
        .unwrap();
        let mut ex = modeled(45.0);
        let zeroed = run_full(
            &cfg,
            &JobSpec::plate("P1", 4, 2, vec![]).with_uniform_data(0, 0),
            &fleet,
            &mut ex,
            RunOptions::default(),
        )
        .unwrap();
        assert_eq!(plain, zeroed);
        assert_eq!(zeroed.data.bytes_downloaded, 0);
    }

    #[test]
    fn data_shaped_jobs_run_three_phases() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 4, 2, vec![]).with_uniform_data(64_000_000, 8_000_000);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut ex = modeled(60.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap();
        assert_eq!(report.stats.completed, 8, "{}", report.summary());
        assert!(report.cleaned_up);
        assert!(report.fully_accounted());
        // Every job pulled its input and pushed its output at least once,
        // and the transfers reached the bill.
        assert!(report.data.bytes_downloaded >= 8 * 64_000_000, "{:?}", report.data);
        assert!(report.data.bytes_uploaded >= 8 * 8_000_000, "{:?}", report.data);
        assert!(report.data.get_requests >= 8 && report.data.put_requests >= 8);
        // One HeadObject size probe per download attempt.
        assert!(report.data.head_requests >= 8, "{:?}", report.data);
        assert!(report.cost.s3_egress_usd > 0.0);
        assert!(report.data.bucket_bound_ms + report.data.nic_bound_ms > 0);
        // Moving ~576 MB through the pipes costs wall-clock: the drain is
        // strictly later than the identical zero-data run's.
        let mut ex = modeled(60.0);
        let zero = run_full(
            &cfg,
            &JobSpec::plate("P1", 4, 2, vec![]),
            &fleet,
            &mut ex,
            RunOptions::default(),
        )
        .unwrap();
        assert!(report.drained_at.unwrap() > zero.drained_at.unwrap());
    }

    #[test]
    fn reaper_eats_network_bound_machines() {
        // A machine that is only *network*-busy publishes ~0% CPU; on a
        // narrow bucket a big-enough download outlives the 15-minute
        // flatline alarm and the machine is reaped mid-transfer — the
        // partial bytes are wasted (the re-download tax).
        let cfg = quick_cfg();
        // 15 GB inputs on a 1 Gbit/s bucket shared by 12 cores: ~24 min
        // per attempt, reaped at ~16-17 min.
        let jobs = JobSpec::plate("P1", 6, 2, vec![]).with_uniform_data(15_000_000_000, 1_000);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let opts = RunOptions {
            net: crate::aws::s3::dataplane::NetProfile::narrow(),
            max_sim_time: 3 * HOUR,
            ..Default::default()
        };
        let mut ex = modeled(30.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap();
        assert!(
            report.stats.alarm_terminations > 0,
            "storage-bound machines should flatline: {}",
            report.summary()
        );
        assert!(report.data.bytes_wasted > 0, "{:?}", report.data);
        assert!(
            report.data.bucket_bound_fraction() > 0.5,
            "the bucket, not the NICs, is the bottleneck: {:?}",
            report.data
        );
    }

    #[test]
    fn data_runs_replay_bit_identically() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 4, 2, vec![]).with_data_shape(32_000_000, 5);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let opts = RunOptions {
            net: crate::aws::s3::dataplane::NetProfile::narrow(),
            ..Default::default()
        };
        let run = || {
            let mut ex = modeled(30.0);
            run_full(&cfg, &jobs, &fleet, &mut ex, opts.clone()).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(a.data.total_bytes() > 0);
    }

    fn workflow_opts(spec: WorkflowSpec, sharing: SharingMode) -> RunOptions {
        RunOptions {
            workflow: Some(spec),
            sharing,
            ..Default::default()
        }
    }

    fn run_workflow(opts: RunOptions) -> RunReport {
        let cfg = quick_cfg();
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut sim = Simulation::new(cfg, opts).unwrap();
        sim.submit_workflow().unwrap();
        sim.start(&fleet).unwrap();
        let mut ex = modeled(60.0);
        sim.run(&mut ex).unwrap()
    }

    #[test]
    fn submit_workflow_requires_a_workflow() {
        let mut sim = Simulation::new(quick_cfg(), RunOptions::default()).unwrap();
        let err = sim.submit_workflow().unwrap_err();
        assert!(err.to_string().contains("no workflow"), "{err}");
    }

    #[test]
    fn diamond_workflow_releases_stages_in_dependency_order() {
        let spec = crate::workloads::dag::diamond();
        let report = run_workflow(workflow_opts(spec, SharingMode::S3Staging));
        assert_eq!(report.stats.completed, 6, "{}", report.summary());
        assert!(report.cleaned_up);
        assert!(report.fully_accounted());
        let wf = &report.workflow;
        assert_eq!(wf.workflow, "diamond");
        assert_eq!(wf.sharing, "s3");
        assert_eq!((wf.nodes, wf.edges, wf.critical_path_len), (6, 8, 3));
        // One root enqueued up front; everything else released by the
        // scheduler as parent artifacts committed.
        assert_eq!(wf.releases, 5);
        assert_eq!(report.jobs_submitted, 6);
        // Three stages, each released no earlier than the one above and
        // committed no earlier than released.
        assert_eq!(wf.stages.len(), 3, "{wf:?}");
        for (d, s) in wf.stages.iter().enumerate() {
            assert_eq!(s.depth as usize, d);
            assert!(s.committed_ms >= s.released_ms, "{wf:?}");
        }
        for w in wf.stages.windows(2) {
            assert!(w[1].released_ms >= w[0].released_ms, "{wf:?}");
            // A child stage can only be released once its parent stage
            // has fully committed.
            assert!(w[1].released_ms >= w[0].committed_ms, "{wf:?}");
        }
        // The merge job waited on four randomly-timed branches: its
        // first-committed parent sat for a while.
        assert!(wf.stall_ms > 0, "{wf:?}");
        // 256 MB root + 4x64 MB branches + 32 MB merge staged up, and
        // every consumer pulled its inputs back down.
        assert!(wf.artifact_bytes_staged >= 544_000_000, "{wf:?}");
        // The summary surfaces the workflow line.
        assert!(report.summary().contains("workflow(diamond/s3)"), "{}", report.summary());
    }

    #[test]
    fn linear_pipeline_survives_drained_queue_between_stages() {
        // The queue is empty after every stage (one job at a time); the
        // monitor must treat that as a gap, not the end of the workload
        // — this is what `workload_pending` generalizes beyond
        // `submit_at`'s pending counter.
        let spec = crate::workloads::dag::linear();
        let report = run_workflow(workflow_opts(spec, SharingMode::S3Staging));
        assert_eq!(report.stats.completed, 5, "{}", report.summary());
        assert!(report.cleaned_up, "cleanup only after the last stage");
        assert!(report.fully_accounted());
        assert_eq!(report.workflow.releases, 4);
        assert_eq!(report.workflow.stages.len(), 5);
        // The final drain postdates the last stage's release.
        let last = report.workflow.stages.last().unwrap();
        assert!(report.drained_at.unwrap() >= last.released_ms);
    }

    #[test]
    fn sharing_modes_route_artifact_bytes_differently() {
        let run = |sharing| run_workflow(workflow_opts(crate::workloads::dag::diamond(), sharing));
        let s3 = run(SharingMode::S3Staging);
        let nl = run(SharingMode::NodeLocal);
        let fs = run(SharingMode::SharedFs);
        for r in [&s3, &nl, &fs] {
            assert_eq!(r.stats.completed, 6, "{}", r.summary());
            assert!(r.cleaned_up && r.fully_accounted());
        }
        // S3 staging pays real S3 traffic: egress dollars and upload
        // flows through the bucket.
        assert!(s3.cost.s3_egress_usd > 0.0, "{:?}", s3.cost);
        assert!(s3.data.bytes_uploaded > 0, "{:?}", s3.data);
        // Peer modes move the same artifacts without touching S3: no
        // egress, and node-local producers never upload at all.
        assert_eq!(nl.cost.s3_egress_usd, 0.0, "{:?}", nl.cost);
        assert_eq!(fs.cost.s3_egress_usd, 0.0, "{:?}", fs.cost);
        assert_eq!(nl.data.bytes_uploaded, 0, "{:?}", nl.data);
        assert!(fs.data.bytes_uploaded > 0, "shared-fs still flows uploads");
        // Node-local stages only the consumer-side transfers, so it
        // moves strictly fewer artifact bytes than the staging modes.
        assert!(
            nl.workflow.artifact_bytes_staged < s3.workflow.artifact_bytes_staged,
            "nl={} s3={}",
            nl.workflow.artifact_bytes_staged,
            s3.workflow.artifact_bytes_staged
        );
        // Downloads skip the HeadObject size probe on peer links.
        assert!(nl.data.head_requests < s3.data.head_requests, "{:?}", nl.data);
    }

    #[test]
    fn workflow_runs_replay_bit_identically() {
        let run = || {
            run_workflow(workflow_opts(
                crate::workloads::dag::mosaic(),
                SharingMode::NodeLocal,
            ))
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert_eq!(a.stats.completed, 20, "{}", a.summary());
        assert_eq!(a.workflow.releases, 14); // 20 nodes - 6 roots
    }

    #[test]
    fn short_visibility_causes_duplicates() {
        let mut cfg = quick_cfg();
        // Jobs take ~120 s, visibility only 30 s: rampant redelivery.
        cfg.sqs_message_visibility = 30 * crate::sim::SECOND;
        cfg.check_if_done.enabled = false; // make duplicates maximally likely
        let jobs = JobSpec::plate("P1", 6, 2, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut ex = modeled(120.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap();
        assert!(
            report.stats.duplicates > 0,
            "expected duplicate work: {}",
            report.summary()
        );
        assert!(report.fully_accounted());
    }

    // -- topology and correlated faults -------------------------------------

    /// Two regions, with the home AZ dark for the whole window.
    fn two_region_outage(duration_min: u64) -> ClusterTopology {
        ClusterTopology::builder("two-region")
            .domain("us-east-1a", "us-east-1")
            .domain("us-west-2a", "us-west-2")
            .fault(FaultKind::AzOutage, "us-east-1a", 0, duration_min, 1.0)
            .build()
            .unwrap()
    }

    #[test]
    fn topology_free_runs_report_the_default_breakdown() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 4, 2, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut ex = modeled(30.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap();
        assert_eq!(report.topology, TopologyBreakdown::default());
        assert!(!report.summary().contains("topology("), "{}", report.summary());
        assert!(report.to_json().get("topology").is_none());
    }

    #[test]
    fn az_outage_darkens_pack_but_spread_completes_cross_region() {
        let cfg = quick_cfg();
        // Data-shaped jobs so the surviving region's completions move
        // metered bytes across the region boundary.
        let jobs = JobSpec::plate("P1", 4, 2, vec![]).with_uniform_data(8_000_000, 1_000_000);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let run = |placement| {
            let opts = RunOptions {
                topology: Some(two_region_outage(24 * 60)),
                placement,
                max_sim_time: 4 * HOUR,
                ..Default::default()
            };
            let mut ex = modeled(60.0);
            run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap()
        };
        let pack = run(Placement::Pack);
        let spread = run(Placement::Spread);
        // Pack puts everything in the dark home domain: nothing ever
        // launches, nothing completes.
        assert_eq!(pack.stats.completed, 0, "{}", pack.summary());
        assert_eq!(pack.topology.domains[0].launched, 0, "{:?}", pack.topology);
        // Spread routes around the outage through us-west-2...
        assert_eq!(spread.stats.completed, 8, "{}", spread.summary());
        assert_eq!(spread.topology.domains[1].jobs_completed, 8, "{:?}", spread.topology);
        assert_eq!(spread.topology.domains[0].jobs_completed, 0, "{:?}", spread.topology);
        // ...and pays for it as cross-region egress line items.
        assert!(spread.topology.xregion_bytes >= 8 * 8_000_000, "{:?}", spread.topology);
        assert!(spread.topology.xregion_usd > 0.0, "{:?}", spread.topology);
        // Both runs witnessed the scripted window.
        for r in [&pack, &spread] {
            assert_eq!(r.topology.outages.len(), 1, "{:?}", r.topology);
            assert_eq!(r.topology.outages[0].kind, "az-outage");
            assert_eq!(r.topology.topology, "two-region");
        }
        assert!(spread.summary().contains("topology(two-region/spread)"), "{}", spread.summary());
    }

    #[test]
    fn az_outage_mid_run_kills_running_machines_at_once() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 12, 4, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let topo = ClusterTopology::builder("two-region")
            .domain("us-east-1a", "us-east-1")
            .domain("us-west-2a", "us-west-2")
            .fault(FaultKind::AzOutage, "us-east-1a", 10, 23 * 60, 1.0)
            .build()
            .unwrap();
        let opts = RunOptions {
            topology: Some(topo),
            placement: Placement::Spread,
            max_sim_time: 8 * HOUR,
            ..Default::default()
        };
        let mut ex = modeled(300.0); // long jobs: machines are busy at +30 min
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap();
        // The window opened with machines running in the home domain:
        // the correlated kill shows up as domain-0 interruptions.
        assert!(report.topology.domains[0].launched > 0, "{:?}", report.topology);
        assert!(report.topology.domains[0].interrupted > 0, "{:?}", report.topology);
        // The workload still finishes on the surviving domain.
        assert_eq!(report.stats.completed, 48, "{}", report.summary());
        assert!(report.fully_accounted(), "{}", report.summary());
    }

    #[test]
    fn bucket_throttle_fault_stretches_the_drain() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 4, 2, vec![]).with_uniform_data(64_000_000, 8_000_000);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let run = |throttle: Option<f64>| {
            let mut topo = ClusterTopology::builder("one-az").domain("us-east-1a", "us-east-1");
            if let Some(m) = throttle {
                topo = topo.fault(FaultKind::BucketThrottle, "us-east-1a", 0, 24 * 60, m);
            }
            let opts = RunOptions {
                topology: Some(topo.build().unwrap()),
                // Narrow bucket: the throttle binds (on the default
                // profile the NICs are the bottleneck and a squeezed
                // bucket budget would change nothing).
                net: crate::aws::s3::dataplane::NetProfile::narrow(),
                ..Default::default()
            };
            let mut ex = modeled(60.0);
            run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap()
        };
        let full = run(None);
        let squeezed = run(Some(0.05));
        assert_eq!(full.stats.completed, 8, "{}", full.summary());
        assert_eq!(squeezed.stats.completed, 8, "{}", squeezed.summary());
        // 5% of the bucket budget: the same bytes take longer to flow.
        assert!(
            squeezed.drained_at.unwrap() > full.drained_at.unwrap(),
            "squeezed={:?} full={:?}",
            squeezed.drained_at,
            full.drained_at
        );
        assert_eq!(squeezed.topology.outages[0].kind, "bucket-throttle");
        // Same region: no cross-region egress either way.
        assert_eq!(squeezed.topology.xregion_bytes, 0);
    }

    #[test]
    fn topology_runs_replay_bit_identically() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 6, 2, vec![]).with_uniform_data(16_000_000, 2_000_000);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let run = || {
            let opts = RunOptions {
                topology: Some(two_region_outage(2 * 60)),
                placement: Placement::Cheapest,
                max_sim_time: 8 * HOUR,
                ..Default::default()
            };
            let mut ex = modeled(45.0);
            run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert_eq!(a.topology.domains.len(), 2);
    }

    // -- multi-tenant open-loop traffic --------------------------------------

    #[test]
    fn traffic_free_runs_report_the_default_breakdown() {
        let cfg = quick_cfg();
        let jobs = JobSpec::plate("P1", 4, 2, vec![]);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let mut ex = modeled(30.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap();
        assert_eq!(report.traffic, TenantBreakdown::default());
        assert!(!report.summary().contains("traffic("), "{}", report.summary());
        assert!(report.to_json().get("traffic").is_none());
    }

    fn run_traffic(spec: TrafficSpec, queueing: QueueingPolicy, seed: u64) -> RunReport {
        let cfg = quick_cfg();
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let opts = RunOptions {
            seed,
            traffic: Some(spec),
            queueing,
            ..Default::default()
        };
        let mut ex = modeled(45.0);
        let mut sim = Simulation::new(cfg, opts).unwrap();
        sim.submit_traffic().unwrap();
        sim.start(&fleet).unwrap();
        sim.run(&mut ex).unwrap()
    }

    #[test]
    fn traffic_run_completes_every_tenants_jobs() {
        let spec = TrafficSpec::shape("two-tenant").unwrap();
        let total = spec.total_jobs();
        let report = run_traffic(spec, QueueingPolicy::Fifo, 42);
        assert_eq!(report.jobs_submitted, total, "{}", report.summary());
        assert!(report.cleaned_up);
        assert!(report.fully_accounted());
        let b = &report.traffic;
        assert_eq!(b.traffic, "two-tenant");
        assert_eq!(b.queueing, "fifo");
        assert_eq!(b.tenants.len(), 2);
        let completed: u64 = b.tenants.iter().map(|t| t.completed).sum();
        assert_eq!(completed, total, "{b:?}");
        for t in &b.tenants {
            assert_eq!(t.submitted, t.completed, "{b:?}");
            assert!(t.wait_p95_ms >= t.wait_p50_ms, "{b:?}");
            assert!(t.slo_attained <= t.completed, "{b:?}");
            assert!(t.billed_usd > 0.0, "{b:?}");
        }
        let billed: f64 = b.tenants.iter().map(|t| t.billed_usd).sum();
        assert!(
            (billed - report.cost.total_usd()).abs() < 1e-9,
            "bill shares {billed} != total {}",
            report.cost.total_usd()
        );
        // The summary surfaces the traffic block for engaged runs.
        assert!(report.summary().contains("traffic(two-tenant/fifo)"), "{}", report.summary());
    }

    #[test]
    fn submit_traffic_requires_a_traffic_spec() {
        let mut sim = Simulation::new(quick_cfg(), RunOptions::default()).unwrap();
        let err = sim.submit_traffic().unwrap_err();
        assert!(err.to_string().contains("no traffic"), "{err}");
    }

    #[test]
    fn traffic_conflicts_with_a_workflow() {
        let opts = RunOptions {
            traffic: TrafficSpec::shape("single"),
            workflow: Some(crate::workloads::dag::diamond()),
            ..Default::default()
        };
        let err = Simulation::new(quick_cfg(), opts).unwrap_err();
        assert!(err.to_string().contains("conflicts"), "{err}");
    }

    /// The drain-race regression: a tenant whose arrivals are separated
    /// by gaps far longer than a job (and than the monitor's patience)
    /// empties the queue between bursts.  The monitor must treat the
    /// scheduled future arrivals as `workload_pending` and hold cleanup
    /// — before the fix it tore the cluster down at the first quiet gap
    /// and the rest of the workload bounced off a deleted queue.
    #[test]
    fn quiet_gap_between_arrivals_holds_cleanup() {
        let spec = TrafficSpec::builder("trickle")
            .tenant("slow", 3, 1, 0, 3600)
            .poisson("slow", 0.02) // mean 50 min between arrivals
            .build()
            .unwrap();
        let report = run_traffic(spec, QueueingPolicy::Fifo, 7);
        assert_eq!(report.jobs_submitted, 3, "{}", report.summary());
        assert_eq!(report.stats.completed, 3, "{}", report.summary());
        assert!(report.cleaned_up, "cleanup only after the last arrival");
        assert_eq!(report.traffic.tenants[0].completed, 3, "{:?}", report.traffic);
        // The final drain postdates at least two long inter-arrival
        // gaps: the run really did idle across quiet stretches.
        assert!(
            report.drained_at.unwrap() > 30 * MINUTE,
            "drained at {:?} — the gaps never happened",
            report.drained_at
        );
    }

    #[test]
    fn traffic_runs_replay_bit_identically() {
        let run = || {
            run_traffic(
                TrafficSpec::shape("noisy-neighbor").unwrap(),
                QueueingPolicy::FairShare,
                13,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert_eq!(a.traffic.tenants.len(), 2);
        let completed: u64 = a.traffic.tenants.iter().map(|t| t.completed).sum();
        assert_eq!(
            completed,
            TrafficSpec::shape("noisy-neighbor").unwrap().total_jobs()
        );
    }

    /// Fair sharing is not cosmetic: with a heavy-tailed noisy neighbor
    /// flooding the queue, the victim tenant's p95 wait under fair-share
    /// must come in strictly below FIFO's (T17 runs the full elastic
    /// version of this; here the fleet is fixed and small so contention
    /// is guaranteed).
    #[test]
    fn fair_share_bounds_the_victims_wait_below_fifo() {
        let spec = || {
            TrafficSpec::builder("crunch")
                .tenant("victim", 12, 1, 1, 300)
                .tenant("noisy", 90, 1, 0, 3600)
                .poisson("victim", 1.0)
                .heavy_tailed("noisy", 1.2, 0.02)
                .build()
                .unwrap()
        };
        let fifo = run_traffic(spec(), QueueingPolicy::Fifo, 5);
        let fair = run_traffic(spec(), QueueingPolicy::FairShare, 5);
        for r in [&fifo, &fair] {
            let done: u64 = r.traffic.tenants.iter().map(|t| t.completed).sum();
            assert_eq!(done, 102, "{}", r.summary());
        }
        let victim = |r: &RunReport| r.traffic.tenants[0].clone();
        assert!(
            victim(&fair).wait_p95_ms < victim(&fifo).wait_p95_ms,
            "fair-share p95 {} !< fifo p95 {}",
            victim(&fair).wait_p95_ms,
            victim(&fifo).wait_p95_ms
        );
    }
}
