//! The generic worker (`worker/generic-worker.py` analog).
//!
//! Each Docker container runs DOCKER_CORES copies of this loop:
//! poll SQS → CHECK_IF_DONE → run the tool → upload outputs → delete the
//! message → log.  The loop itself is event-driven inside
//! [`crate::coordinator::run`]; this module holds the pure pieces:
//! CHECK_IF_DONE and message parsing.

use crate::aws::s3::S3;
use crate::config::app_config::CheckIfDone;
use crate::json::{parse, Value};

/// CHECK_IF_DONE: "If your software determines the correct number of
/// files are already in the output folder it will designate that job as
/// completed and move onto the next one."
///
/// A file counts iff its size ≥ MIN_FILE_SIZE_BYTES and its key contains
/// NECESSARY_STRING; the job is done iff ≥ EXPECTED_NUMBER_FILES count.
pub fn check_if_done(
    s3: &mut S3,
    check: &CheckIfDone,
    bucket: &str,
    output_prefix: &str,
) -> bool {
    if !check.enabled {
        return false;
    }
    let qualifying = s3
        .list_prefix(bucket, output_prefix)
        .into_iter()
        .filter(|(key, size)| {
            *size >= check.min_file_size_bytes
                && (check.necessary_string.is_empty() || key.contains(&check.necessary_string))
        })
        .count();
    qualifying >= check.expected_number_files as usize
}

/// Parse a job message body; malformed messages are the classic poison
/// pill, so they surface as `None` (worker fails the job, SQS redrives
/// to the DLQ).
pub fn parse_message(body: &str) -> Option<Value> {
    parse(body).ok().filter(|v| v.as_obj().is_some())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::s3::Body;

    fn s3_with(files: &[(&str, u64)]) -> S3 {
        let mut s3 = S3::new();
        s3.create_bucket("b");
        for (k, sz) in files {
            s3.put("b", k, Body::Synthetic { size: *sz }, 0).unwrap();
        }
        s3
    }

    fn check(n: u32, min: u64, nec: &str) -> CheckIfDone {
        CheckIfDone {
            enabled: true,
            expected_number_files: n,
            min_file_size_bytes: min,
            necessary_string: nec.into(),
        }
    }

    #[test]
    fn disabled_never_done() {
        let mut s3 = s3_with(&[("out/j1/a.csv", 100)]);
        let mut c = check(1, 0, "");
        c.enabled = false;
        assert!(!check_if_done(&mut s3, &c, "b", "out/j1"));
    }

    #[test]
    fn counts_files_under_prefix() {
        let mut s3 = s3_with(&[
            ("out/j1/a.csv", 100),
            ("out/j1/b.csv", 100),
            ("out/j2/c.csv", 100),
        ]);
        assert!(check_if_done(&mut s3, &check(2, 0, ""), "b", "out/j1"));
        assert!(!check_if_done(&mut s3, &check(3, 0, ""), "b", "out/j1"));
    }

    #[test]
    fn min_size_filters_corrupt_files() {
        let mut s3 = s3_with(&[("out/j/a.csv", 10), ("out/j/b.csv", 5_000)]);
        assert!(!check_if_done(&mut s3, &check(2, 1_000, ""), "b", "out/j"));
        assert!(check_if_done(&mut s3, &check(1, 1_000, ""), "b", "out/j"));
    }

    #[test]
    fn necessary_string_filters() {
        let mut s3 = s3_with(&[("out/j/image.png", 9_999), ("out/j/data.csv", 9_999)]);
        assert!(check_if_done(&mut s3, &check(1, 0, ".csv"), "b", "out/j"));
        assert!(!check_if_done(&mut s3, &check(2, 0, ".csv"), "b", "out/j"));
    }

    #[test]
    fn parse_message_rejects_garbage() {
        assert!(parse_message("{\"a\": 1}").is_some());
        assert!(parse_message("not json").is_none());
        assert!(parse_message("[1,2]").is_none());
    }
}
