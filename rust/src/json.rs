//! Minimal JSON: parser, writer, and ergonomic accessors.
//!
//! The paper's UX is "edit two human-readable JSON files, run four
//! commands" — so the Config, Job, and Fleet files here are real JSON on
//! disk, exactly like upstream Distributed-Something.  The image vendors
//! no serde, so this is a small, well-tested recursive-descent parser
//! (strict: rejects trailing garbage, bad escapes, overlong nesting) plus
//! a pretty-printer.  Object key order is preserved (files round-trip
//! diffably).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Objects preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

/// Parse error with byte offset and message.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError {
            offset: self.pos,
            msg: msg.into(),
        })
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, ParseError> {
        if depth > MAX_DEPTH {
            return self.err("nesting too deep");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => self.err(format!("unexpected byte '{}'", b as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Value::Num(n)),
            _ => self.err(format!("bad number '{s}'")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return self.err("bad low surrogate");
                                    }
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    return self.err("lone high surrogate");
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return self.err("lone low surrogate");
                            } else {
                                char::from_u32(cp)
                            };
                            match ch {
                                Some(c) => {
                                    out.push(c);
                                    continue; // hex4 advanced pos already
                                }
                                None => return self.err("bad unicode escape"),
                            }
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x20 => return self.err("control char in string"),
                Some(_) => {
                    // Fast path: consume the whole contiguous run of
                    // plain bytes (no quote/backslash/control) and append
                    // it in one UTF-8-validated push (perf pass: the
                    // per-char from_utf8 made parsing quadratic).
                    let start = self.pos;
                    while let Some(&b) = self.bytes.get(self.pos) {
                        if b == b'"' || b == b'\\' || b < 0x20 {
                            break;
                        }
                        self.pos += 1;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| ParseError {
                            offset: start,
                            msg: "invalid utf-8".into(),
                        })?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = match self.peek() {
                Some(b) => b,
                None => return self.err("eof in \\u escape"),
            };
            let d = match b {
                b'0'..=b'9' => b - b'0',
                b'a'..=b'f' => b - b'a' + 10,
                b'A'..=b'F' => b - b'A' + 10,
                _ => return self.err("bad hex digit"),
            };
            v = v * 16 + u32::from(d);
            self.pos += 1;
        }
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

impl Value {
    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad1 = "  ".repeat(indent + 1);
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => out.push_str(&fmt_num(*n)),
            Value::Str(s) => escape_into(s, out),
            Value::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    out.push_str(&pad1);
                    item.write_pretty(out, indent + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Value::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    out.push_str(&pad1);
                    escape_into(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < fields.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    /// Pretty-print with two-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    // ----- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object fields as a map (for lookup-heavy callers).
    pub fn to_map(&self) -> BTreeMap<String, Value> {
        match self {
            Value::Obj(fields) => fields.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    // ----- builders -------------------------------------------------------

    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }

    /// Chainable field append for building objects.
    pub fn with(mut self, key: &str, v: impl Into<Value>) -> Value {
        if let Value::Obj(fields) = &mut self {
            fields.push((key.to_string(), v.into()));
        }
        self
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Self {
        Value::Num(f64::from(n))
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Self {
        Value::Arr(v)
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn escapes_roundtrip() {
        let orig = Value::Str("line1\nline2\t\"quoted\" \\slash\u{1}".into());
        let text = orig.pretty();
        assert_eq!(parse(&text).unwrap(), orig);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
        // surrogate pair: 😀
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Value::Str("😀".into())
        );
        assert!(parse(r#""\ud83d""#).is_err()); // lone high surrogate
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("\"\x01\"").is_err());
    }

    #[test]
    fn rejects_deep_nesting() {
        let s = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&s).is_err());
    }

    #[test]
    fn pretty_roundtrips() {
        let text = r#"{"name": "app", "n": 3, "list": [1, 2.5, true, null], "sub": {"k": "v"}, "empty": {}, "earr": []}"#;
        let v = parse(text).unwrap();
        let pretty = v.pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\"name\": \"app\""));
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Value::Num(5.0).pretty(), "5");
        assert_eq!(Value::Num(5.25).pretty(), "5.25");
        assert_eq!(Value::Num(-0.0).pretty(), "0");
    }

    #[test]
    fn builder_api() {
        let v = Value::obj()
            .with("a", 1u64)
            .with("b", "x")
            .with("c", Value::Arr(vec![Value::from(true)]));
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Value::Num(1.5).as_u64(), None);
        assert_eq!(Value::Num(-1.0).as_u64(), None);
        assert_eq!(Value::Num(7.0).as_u64(), Some(7));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" \n\t{ \"a\" :\r\n [ 1 , 2 ] } \n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
