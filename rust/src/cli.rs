//! Tiny CLI argument parser (no clap in the vendored crate set).
//!
//! Supports `ds <command> [positionals] [--flag] [--key value]`.
//! Numeric access is strict-only ([`Args::try_parse`] /
//! [`Args::try_parse_list`]): a malformed value is an error, never a
//! silent fallback to the default — `--machines 8x` must not run a
//! different study than the one asked for.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, Option<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key value | --key=value | --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), Some(v.to_string()));
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), Some(v));
                } else {
                    out.flags.insert(name.to_string(), None);
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name)?.as_deref()
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Comma-separated list value (`--machines 2,4,8`).  Empty items are
    /// dropped, so trailing commas are harmless.  `None` if the flag is
    /// absent or valueless.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name).map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        })
    }

    /// Strict scalar parse: absent flag -> `default`; present with no
    /// value or with garbage -> `Err` (never a silent fallback — a
    /// malformed invocation must not run a different study than the one
    /// asked for).
    pub fn try_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.get(name) {
            None if self.flag(name) => Err(format!("missing value for --{name}")),
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| format!("bad value '{s}' for --{name}")),
        }
    }

    /// Flags present on the command line that are not in `known`, in
    /// sorted order.  Commands with a declared flag table use this to
    /// reject typos instead of silently ignoring them — which also
    /// guarantees the table (and any help text rendered from it) covers
    /// every flag the command actually reads.
    pub fn unknown_flags(&self, known: &[&str]) -> Vec<String> {
        self.flags
            .keys()
            .filter(|k| !known.contains(&k.as_str()))
            .cloned()
            .collect()
    }

    /// Strict comma-list parse: absent flag -> `Ok(None)`; present with
    /// no value or any unparseable item -> `Err`.
    pub fn try_parse_list<T: std::str::FromStr>(
        &self,
        name: &str,
    ) -> Result<Option<Vec<T>>, String> {
        match self.get_list(name) {
            None if self.flag(name) => Err(format!("missing value for --{name}")),
            None => Ok(None),
            // A value of only commas/whitespace is a forgotten value too.
            Some(items) if items.is_empty() => Err(format!("missing value for --{name}")),
            Some(items) => items
                .iter()
                .map(|s| {
                    s.parse::<T>()
                        .map_err(|_| format!("bad value '{s}' for --{name}"))
                })
                .collect::<Result<Vec<T>, String>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("submit-job files/job.json extra");
        assert_eq!(a.command.as_deref(), Some("submit-job"));
        assert_eq!(a.positionals, vec!["files/job.json", "extra"]);
    }

    #[test]
    fn flags_all_forms() {
        let a = parse("run --cheapest --seed 7 --bucket=my-bkt trailing");
        assert!(a.flag("cheapest"));
        assert!(!a.flag("missing"));
        assert_eq!(a.try_parse("seed", 0u64), Ok(7));
        assert_eq!(a.get("bucket"), Some("my-bkt"));
        assert_eq!(a.positionals, vec!["trailing"]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("region", "us-east-1"), "us-east-1");
        assert_eq!(a.try_parse("price", 0.1f64), Ok(0.1));
    }

    #[test]
    fn empty() {
        let a = parse("");
        assert!(a.command.is_none());
    }

    #[test]
    fn strict_parsing() {
        let a = parse("sweep --seeds 8 --machines 2,4,x");
        assert_eq!(a.try_parse("seeds", 4u64), Ok(8));
        assert_eq!(a.try_parse("missing", 4u64), Ok(4));
        assert_eq!(
            a.try_parse::<u64>("machines", 0),
            Err("bad value '2,4,x' for --machines".to_string())
        );
        assert_eq!(
            a.try_parse_list::<u32>("machines"),
            Err("bad value 'x' for --machines".to_string())
        );
        assert_eq!(a.try_parse_list::<u32>("missing"), Ok(None));
        let b = parse("sweep --machines 2,4");
        assert_eq!(b.try_parse_list::<u32>("machines"), Ok(Some(vec![2, 4])));
        // A flag whose value was forgotten must error, not default.
        let c = parse("sweep --seeds --json");
        assert_eq!(
            c.try_parse("seeds", 4u64),
            Err("missing value for --seeds".to_string())
        );
        assert_eq!(
            c.try_parse_list::<u64>("seeds"),
            Err("missing value for --seeds".to_string())
        );
        let d = parse("sweep --machines ,");
        assert_eq!(
            d.try_parse_list::<u32>("machines"),
            Err("missing value for --machines".to_string())
        );
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse("sweep --seeds 4 --machnies 2 --json");
        assert_eq!(a.unknown_flags(&["seeds", "machines", "json"]), vec!["machnies"]);
        assert!(a.unknown_flags(&["seeds", "machnies", "json"]).is_empty());
    }

    #[test]
    fn list_values() {
        let a = parse("sweep --machines 2,4,8 --volatility low, --empty");
        assert_eq!(
            a.get_list("machines"),
            Some(vec!["2".to_string(), "4".to_string(), "8".to_string()])
        );
        assert_eq!(a.get_list("volatility"), Some(vec!["low".to_string()]));
        assert_eq!(a.get_list("empty"), None);
        assert_eq!(a.get_list("missing"), None);
    }
}
