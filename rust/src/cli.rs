//! Tiny CLI argument parser (no clap in the vendored crate set).
//!
//! Supports `ds <command> [positionals] [--flag] [--key value]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, Option<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key value | --key=value | --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), Some(v.to_string()));
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), Some(v));
                } else {
                    out.flags.insert(name.to_string(), None);
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name)?.as_deref()
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("submit-job files/job.json extra");
        assert_eq!(a.command.as_deref(), Some("submit-job"));
        assert_eq!(a.positionals, vec!["files/job.json", "extra"]);
    }

    #[test]
    fn flags_all_forms() {
        let a = parse("run --cheapest --seed 7 --bucket=my-bkt trailing");
        assert!(a.flag("cheapest"));
        assert!(!a.flag("missing"));
        assert_eq!(a.get_u64("seed", 0), 7);
        assert_eq!(a.get("bucket"), Some("my-bkt"));
        assert_eq!(a.positionals, vec!["trailing"]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("region", "us-east-1"), "us-east-1");
        assert_eq!(a.get_f64("price", 0.1), 0.1);
    }

    #[test]
    fn empty() {
        let a = parse("");
        assert!(a.command.is_none());
    }
}
