//! Compile-once/execute-many wrapper over the `xla` crate's PJRT client.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile`.  Executables are
//! cached by workload name; compilation happens at most once per process.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{Manifest, WorkloadInfo};

/// A PJRT CPU client plus a cache of compiled workload executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// (compile_ms, execute_count, total_execute_ms) per workload.
    stats: HashMap<String, (f64, u64, f64)>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: HashMap::new(),
            stats: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn info(&self, workload: &str) -> Result<&WorkloadInfo> {
        self.manifest.get(workload)
    }

    /// Compile (or fetch cached) executable for `workload`.
    pub fn ensure_compiled(&mut self, workload: &str) -> Result<()> {
        if self.cache.contains_key(workload) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(workload)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling workload {workload}"))?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.cache.insert(workload.to_string(), exe);
        self.stats
            .entry(workload.to_string())
            .or_insert((compile_ms, 0, 0.0))
            .0 = compile_ms;
        Ok(())
    }

    /// Execute `workload` on flat f32 inputs (one Vec per argument, sizes
    /// per the manifest).  Returns the flat f32 output and wall time (ms).
    pub fn execute(&mut self, workload: &str, inputs: &[Vec<f32>]) -> Result<(Vec<f32>, f64)> {
        self.ensure_compiled(workload)?;
        let info = self.manifest.get(workload)?.clone();
        let expected = info.input_lens();
        if inputs.len() != expected.len() {
            bail!(
                "workload {workload} wants {} inputs, got {}",
                expected.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (inp, shape)) in inputs.iter().zip(&info.input_shapes).enumerate() {
            if inp.len() != expected[i] {
                bail!(
                    "workload {workload} input {i}: expected {} f32s, got {}",
                    expected[i],
                    inp.len()
                );
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(inp)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping input {i}"))?,
            );
        }
        let exe = self.cache.get(workload).unwrap();
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {workload}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = lit.to_tuple1().context("unwrapping 1-tuple result")?;
        let values = out.to_vec::<f32>().context("result to_vec")?;
        if values.len() != info.output_len {
            bail!(
                "workload {workload}: expected {} outputs, got {}",
                info.output_len,
                values.len()
            );
        }
        let st = self.stats.entry(workload.to_string()).or_insert((0.0, 0, 0.0));
        st.1 += 1;
        st.2 += ms;
        Ok((values, ms))
    }

    /// (compile_ms, execute_count, total_execute_ms) for a workload.
    pub fn stats(&self, workload: &str) -> Option<(f64, u64, f64)> {
        self.stats.get(workload).copied()
    }

    /// Mean execute latency (ms) observed so far.
    pub fn mean_latency_ms(&self, workload: &str) -> Option<f64> {
        self.stats
            .get(workload)
            .filter(|(_, n, _)| *n > 0)
            .map(|(_, n, total)| total / *n as f64)
    }
}

// No unit tests here: PJRT needs the artifacts on disk, which exist only
// after `make artifacts`; rust/tests/runtime_roundtrip.rs covers the real
// load/compile/execute path end-to-end (including golden numerics vs the
// python oracle).
