//! `artifacts/manifest.json`: what the AOT step produced.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::json::{parse, Value};

/// Which pipeline family an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Distributed-CellProfiler analogue: images -> feature vectors.
    CellProfiler,
    /// Distributed-Fiji analogue: tile stack -> montage + seam scores.
    Stitch,
    /// Distributed-OmeZarrCreator analogue: image -> pyramid levels.
    Pyramid,
}

impl WorkloadKind {
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "cellprofiler" => Self::CellProfiler,
            "stitch" => Self::Stitch,
            "pyramid" => Self::Pyramid,
            other => bail!("unknown workload kind '{other}'"),
        })
    }
}

/// One AOT artifact's metadata.
#[derive(Debug, Clone)]
pub struct WorkloadInfo {
    pub name: String,
    pub kind: WorkloadKind,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// f32 input shapes, in argument order.
    pub input_shapes: Vec<Vec<usize>>,
    /// Flat f32 output length.
    pub output_len: usize,
    /// Pipeline parameters (batch, size, grid, levels, …).
    pub params: BTreeMap<String, f64>,
}

impl WorkloadInfo {
    /// Total f32 elements expected per input argument.
    pub fn input_lens(&self) -> Vec<usize> {
        self.input_shapes
            .iter()
            .map(|s| s.iter().product())
            .collect()
    }

    pub fn param(&self, key: &str) -> Option<f64> {
        self.params.get(key).copied()
    }

    pub fn param_usize(&self, key: &str) -> Option<usize> {
        self.param(key).map(|v| v as usize)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub source_digest: String,
    workloads: BTreeMap<String, WorkloadInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        Self::from_json(&text, dir)
    }

    pub fn from_json(text: &str, dir: PathBuf) -> Result<Self> {
        let v = parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let source_digest = v
            .get("source_digest")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string();
        let mut workloads = BTreeMap::new();
        for w in v
            .get("workloads")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'workloads'"))?
        {
            let name = w
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("workload missing name"))?
                .to_string();
            let kind = WorkloadKind::from_str(
                w.get("kind").and_then(Value::as_str).unwrap_or_default(),
            )?;
            let file = w
                .get("file")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("workload {name} missing file"))?
                .to_string();
            let input_shapes = w
                .get("input_shapes")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("workload {name} missing input_shapes"))?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .map(|dims| {
                            dims.iter()
                                .filter_map(Value::as_u64)
                                .map(|d| d as usize)
                                .collect::<Vec<usize>>()
                        })
                        .ok_or_else(|| anyhow!("bad shape in {name}"))
                })
                .collect::<Result<Vec<_>>>()?;
            let output_len = w
                .get("output_len")
                .and_then(Value::as_u64)
                .ok_or_else(|| anyhow!("workload {name} missing output_len"))?
                as usize;
            let params = w
                .get("params")
                .and_then(Value::as_obj)
                .map(|fields| {
                    fields
                        .iter()
                        .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
                        .collect()
                })
                .unwrap_or_default();
            workloads.insert(
                name.clone(),
                WorkloadInfo {
                    name,
                    kind,
                    file,
                    input_shapes,
                    output_len,
                    params,
                },
            );
        }
        Ok(Self {
            dir,
            source_digest,
            workloads,
        })
    }

    pub fn get(&self, name: &str) -> Result<&WorkloadInfo> {
        self.workloads.get(name).ok_or_else(|| {
            anyhow!(
                "unknown workload '{name}'; available: {:?}",
                self.names()
            )
        })
    }

    pub fn names(&self) -> Vec<&str> {
        self.workloads.keys().map(String::as_str).collect()
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "source_digest": "abc123",
      "workloads": [
        {"name": "cp_128_b1", "kind": "cellprofiler", "file": "cp_128_b1.hlo.txt",
         "input_shapes": [[1, 128, 128]], "dtype": "f32", "output_len": 16,
         "params": {"batch": 1, "size": 128, "sigma": 2.0, "radius": 6}},
        {"name": "pyramid_256_l4", "kind": "pyramid", "file": "pyramid_256_l4.hlo.txt",
         "input_shapes": [[256, 256]], "dtype": "f32", "output_len": 87040,
         "params": {"size": 256, "levels": 4}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.source_digest, "abc123");
        assert_eq!(m.names(), vec!["cp_128_b1", "pyramid_256_l4"]);
        let w = m.get("cp_128_b1").unwrap();
        assert_eq!(w.kind, WorkloadKind::CellProfiler);
        assert_eq!(w.input_lens(), vec![128 * 128]);
        assert_eq!(w.param_usize("size"), Some(128));
        assert_eq!(
            m.hlo_path("pyramid_256_l4").unwrap(),
            PathBuf::from("/tmp/pyramid_256_l4.hlo.txt")
        );
    }

    #[test]
    fn unknown_workload_lists_available() {
        let m = Manifest::from_json(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("cp_128_b1"));
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = SAMPLE.replace("cellprofiler", "quantum");
        assert!(Manifest::from_json(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_built() {
        // Exercised fully in integration tests; here just check wiring.
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.get("cp_256_b1").is_ok());
            assert!(m.hlo_path("cp_256_b1").unwrap().exists());
        }
    }
}
