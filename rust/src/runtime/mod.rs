//! PJRT runtime: load AOT artifacts, compile once, execute from the hot
//! path.
//!
//! The "Dockerized workload" of the paper is, here, an HLO module lowered
//! at build time by `python/compile/aot.py` (`make artifacts`).  This
//! module is the only place that touches the `xla` crate; everything
//! above it sees plain `Vec<f32>` in/out.  Python never runs at request
//! time.

pub mod executor;
pub mod manifest;

pub use executor::PjrtRuntime;
pub use manifest::{Manifest, WorkloadInfo, WorkloadKind};
