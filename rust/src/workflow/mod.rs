//! DAG workflows: typed specs, topological validation, and data-sharing
//! modes (DESIGN.md §11).
//!
//! The paper's workloads are embarrassingly parallel — every SQS message
//! is independent.  Real scientific pipelines (Montage mosaics, the
//! CellProfiler → Fiji → OME-Zarr chain the paper targets) are DAGs
//! whose edges are *data*: a job may only start once every parent's
//! artifact has been committed to the sharing medium.  This module is
//! the typed half of that story:
//!
//! * [`WorkflowSpec`] — jobs plus directed dependency edges with named
//!   intermediate artifacts.  Construction validates eagerly: duplicate
//!   job names, dangling edge endpoints, self-loops, duplicate edges,
//!   and dependency cycles are all typed [`WorkflowError`]s, never
//!   panics.  Specs parse from a WORKFLOW JSON file ([`WorkflowSpec::parse`],
//!   strict about unknown keys like the Sweep file), render back
//!   bit-identically ([`WorkflowSpec::render`]), and build in code via
//!   [`WorkflowSpec::builder`].
//! * Topology queries — canonical Kahn order ([`WorkflowSpec::topo_order`],
//!   lexicographic job-name tie-break, so it is a pure function of the
//!   spec), per-node depths, critical-path length, and a topological
//!   [`fingerprint`](WorkflowSpec::fingerprint) that labels a workflow's
//!   *shape* independently of declaration order.
//! * [`SharingMode`] — where artifact bytes move and what they cost:
//!   S3 staging (upload + download through the data bucket, full request
//!   and egress billing), node-local with transfer (producers keep
//!   artifacts on their node; consumers pull peer-to-peer, no S3
//!   dollars), or a shared-filesystem profile (all artifact traffic
//!   contends on one FS server link, no S3 dollars).
//! * [`WorkflowBreakdown`] — the workflow slice of a run report
//!   (critical path, per-stage spans, artifact bytes staged, stall time
//!   waiting on parents), threaded RunReport → ScenarioSummary → sweep
//!   JSON exactly like the pool/data/scaling breakdowns.
//!
//! The readiness scheduler that consumes all of this lives in
//! [`crate::coordinator::run`]; the canonical shape generators (diamond,
//! fan-out/fan-in, Montage-shaped mosaic, linear pipeline) live in
//! [`crate::workloads::dag`].

use std::collections::BTreeMap;

use thiserror::Error;

use crate::json::{parse, Value};
use crate::sim::SimTime;

/// Why a workflow spec was rejected.  Every variant names the workflow
/// and the offending element, so `ds describe`/`ds sweep --dry-run` can
/// surface the problem without a panic.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum WorkflowError {
    #[error("workflow spec: {0}")]
    Parse(String),
    #[error("workflow '{workflow}': no jobs declared")]
    Empty { workflow: String },
    #[error("workflow '{workflow}': duplicate job name '{job}'")]
    DuplicateJob { workflow: String, job: String },
    #[error("workflow '{workflow}': edge '{artifact}' references unknown job '{job}'")]
    UnknownJob {
        workflow: String,
        artifact: String,
        job: String,
    },
    #[error("workflow '{workflow}': edge '{artifact}' is a self-loop on '{job}'")]
    SelfLoop {
        workflow: String,
        artifact: String,
        job: String,
    },
    #[error("workflow '{workflow}': duplicate edge '{from}' -> '{to}'")]
    DuplicateEdge {
        workflow: String,
        from: String,
        to: String,
    },
    #[error("workflow '{workflow}': dependency cycle through {jobs:?}")]
    Cycle { workflow: String, jobs: Vec<String> },
    #[error(
        "unknown workflow '{0}' (expected a shape name — diamond, fanout, mosaic, linear — or a readable WORKFLOW file path)"
    )]
    Unknown(String),
}

fn parse_err(msg: impl Into<String>) -> WorkflowError {
    WorkflowError::Parse(msg.into())
}

/// One node of the DAG: a named job producing `output_bytes` of
/// artifact data for its children.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkflowJob {
    pub name: String,
    /// Bytes of intermediate artifact this job writes to the sharing
    /// medium (0 = control-only dependency).
    pub output_bytes: u64,
}

/// One directed dependency edge: `to` may not start before `from`'s
/// artifact has committed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkflowEdge {
    pub from: String,
    pub to: String,
    /// Name of the intermediate artifact the edge carries.
    pub artifact: String,
}

/// A validated DAG workflow.  Invariants (enforced by every
/// constructor): at least one job, unique job names, every edge endpoint
/// declared, no self-loops, no duplicate edges, no cycles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkflowSpec {
    pub name: String,
    /// Jobs in declaration order (parse/render round-trips preserve it).
    pub jobs: Vec<WorkflowJob>,
    /// Edges in declaration order.
    pub edges: Vec<WorkflowEdge>,
}

impl WorkflowSpec {
    /// Build and validate.  The single gate every front door (file,
    /// JSON, builder, generators) funnels through.
    pub fn new(
        name: &str,
        jobs: Vec<WorkflowJob>,
        edges: Vec<WorkflowEdge>,
    ) -> Result<Self, WorkflowError> {
        let spec = Self {
            name: name.to_string(),
            jobs,
            edges,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Start an in-code spec.
    pub fn builder(name: &str) -> WorkflowBuilder {
        WorkflowBuilder {
            name: name.to_string(),
            jobs: Vec::new(),
            edges: Vec::new(),
        }
    }

    fn validate(&self) -> Result<(), WorkflowError> {
        let wf = || self.name.clone();
        if self.jobs.is_empty() {
            return Err(WorkflowError::Empty { workflow: wf() });
        }
        let mut index: BTreeMap<&str, usize> = BTreeMap::new();
        for (i, j) in self.jobs.iter().enumerate() {
            if index.insert(j.name.as_str(), i).is_some() {
                return Err(WorkflowError::DuplicateJob {
                    workflow: wf(),
                    job: j.name.clone(),
                });
            }
        }
        let mut seen: BTreeMap<(usize, usize), ()> = BTreeMap::new();
        for e in &self.edges {
            let missing = [&e.from, &e.to]
                .into_iter()
                .find(|j| !index.contains_key(j.as_str()));
            if let Some(job) = missing {
                return Err(WorkflowError::UnknownJob {
                    workflow: wf(),
                    artifact: e.artifact.clone(),
                    job: job.clone(),
                });
            }
            if e.from == e.to {
                return Err(WorkflowError::SelfLoop {
                    workflow: wf(),
                    artifact: e.artifact.clone(),
                    job: e.from.clone(),
                });
            }
            let key = (index[e.from.as_str()], index[e.to.as_str()]);
            if seen.insert(key, ()).is_some() {
                return Err(WorkflowError::DuplicateEdge {
                    workflow: wf(),
                    from: e.from.clone(),
                    to: e.to.clone(),
                });
            }
        }
        // Kahn's algorithm: whatever the canonical order cannot reach is
        // on (or downstream of) a cycle.
        let order = self.topo_order();
        if order.len() < self.jobs.len() {
            let mut reached = vec![false; self.jobs.len()];
            for &i in &order {
                reached[i] = true;
            }
            let mut jobs: Vec<String> = self
                .jobs
                .iter()
                .enumerate()
                .filter(|&(i, _)| !reached[i])
                .map(|(_, j)| j.name.clone())
                .collect();
            jobs.sort();
            return Err(WorkflowError::Cycle {
                workflow: wf(),
                jobs,
            });
        }
        Ok(())
    }

    pub fn node_count(&self) -> usize {
        self.jobs.len()
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Job index by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.jobs.iter().position(|j| j.name == name)
    }

    /// Parent job indices per job index (edge declaration order).
    pub fn parents(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.jobs.len()];
        for e in &self.edges {
            if let (Some(f), Some(t)) = (self.index_of(&e.from), self.index_of(&e.to)) {
                out[t].push(f);
            }
        }
        out
    }

    /// Child job indices per job index (edge declaration order).
    pub fn children(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.jobs.len()];
        for e in &self.edges {
            if let (Some(f), Some(t)) = (self.index_of(&e.from), self.index_of(&e.to)) {
                out[f].push(t);
            }
        }
        out
    }

    /// Canonical topological order: Kahn's algorithm, always popping the
    /// lexicographically smallest ready job name — a pure function of
    /// the spec, shared by the fingerprint and the property tests.  On a
    /// cyclic graph (only reachable pre-validation) the order is
    /// truncated to the acyclic prefix.
    pub fn topo_order(&self) -> Vec<usize> {
        let parents = self.parents();
        let children = self.children();
        let mut unmet: Vec<usize> = parents.iter().map(Vec::len).collect();
        let mut ready: BTreeMap<&str, usize> = self
            .jobs
            .iter()
            .enumerate()
            .filter(|&(i, _)| unmet[i] == 0)
            .map(|(i, j)| (j.name.as_str(), i))
            .collect();
        let mut order = Vec::with_capacity(self.jobs.len());
        while let Some((&name, &i)) = ready.iter().next() {
            ready.remove(name);
            order.push(i);
            for &c in &children[i] {
                unmet[c] -= 1;
                if unmet[c] == 0 {
                    ready.insert(self.jobs[c].name.as_str(), c);
                }
            }
        }
        order
    }

    /// Longest-path depth per job: roots are 0, every other job is one
    /// past its deepest parent.
    pub fn depths(&self) -> Vec<u32> {
        let parents = self.parents();
        let mut depth = vec![0u32; self.jobs.len()];
        for &i in &self.topo_order() {
            depth[i] = parents[i]
                .iter()
                .map(|&p| depth[p] + 1)
                .max()
                .unwrap_or(0);
        }
        depth
    }

    /// Jobs on the longest dependency chain (depth stages): the lower
    /// bound on sequential stages no amount of machines removes.
    pub fn critical_path_len(&self) -> u64 {
        self.depths().iter().map(|&d| u64::from(d) + 1).max().unwrap_or(0)
    }

    /// Bytes job `i` must pull before it can start: the sum of its
    /// parents' declared `output_bytes`.
    pub fn input_bytes(&self, i: usize) -> u64 {
        self.parents()[i]
            .iter()
            .map(|&p| self.jobs[p].output_bytes)
            .sum()
    }

    /// Deterministic 64-bit fingerprint of the workflow's *topology*:
    /// FNV-1a over the canonical Kahn order (names, bytes, sorted parent
    /// names).  Two declarations of the same DAG — jobs or edges listed
    /// in any order — fingerprint identically.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(FNV_PRIME);
            }
            h ^= 0xff;
            h = h.wrapping_mul(FNV_PRIME);
        };
        eat(self.name.as_bytes());
        let parents = self.parents();
        for &i in &self.topo_order() {
            let j = &self.jobs[i];
            eat(j.name.as_bytes());
            eat(&j.output_bytes.to_le_bytes());
            let mut ps: Vec<&str> = parents[i].iter().map(|&p| self.jobs[p].name.as_str()).collect();
            ps.sort_unstable();
            for p in ps {
                eat(p.as_bytes());
            }
        }
        h
    }

    /// The WORKFLOW file as JSON (NAME / JOBS / EDGES, declaration order
    /// preserved) — [`parse`](Self::parse) inverts it bit-identically.
    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("NAME", self.name.as_str())
            .with(
                "JOBS",
                Value::Arr(
                    self.jobs
                        .iter()
                        .map(|j| {
                            Value::obj()
                                .with("name", j.name.as_str())
                                .with("output_bytes", j.output_bytes)
                        })
                        .collect(),
                ),
            )
            .with(
                "EDGES",
                Value::Arr(
                    self.edges
                        .iter()
                        .map(|e| {
                            Value::obj()
                                .with("from", e.from.as_str())
                                .with("to", e.to.as_str())
                                .with("artifact", e.artifact.as_str())
                        })
                        .collect(),
                ),
            )
    }

    /// Decode (and validate) a WORKFLOW JSON value.  Strict like the
    /// Sweep file: unknown keys are rejected, not ignored.
    pub fn from_json(v: &Value) -> Result<Self, WorkflowError> {
        let fields = v
            .as_obj()
            .ok_or_else(|| parse_err("expected a WORKFLOW object"))?;
        let mut name = None;
        let mut jobs = None;
        let mut edges = None;
        for (k, val) in fields {
            match k.as_str() {
                "NAME" => {
                    name = Some(
                        val.as_str()
                            .ok_or_else(|| parse_err("NAME must be a string"))?
                            .to_string(),
                    );
                }
                "JOBS" => {
                    let arr = val
                        .as_arr()
                        .ok_or_else(|| parse_err("JOBS must be an array"))?;
                    jobs = Some(
                        arr.iter()
                            .map(Self::job_from_json)
                            .collect::<Result<Vec<_>, _>>()?,
                    );
                }
                "EDGES" => {
                    let arr = val
                        .as_arr()
                        .ok_or_else(|| parse_err("EDGES must be an array"))?;
                    edges = Some(
                        arr.iter()
                            .map(Self::edge_from_json)
                            .collect::<Result<Vec<_>, _>>()?,
                    );
                }
                other => return Err(parse_err(format!("unknown WORKFLOW key '{other}'"))),
            }
        }
        let name = name.ok_or_else(|| parse_err("missing NAME"))?;
        let jobs = jobs.ok_or_else(|| parse_err("missing JOBS"))?;
        let edges = edges.unwrap_or_default();
        Self::new(&name, jobs, edges)
    }

    fn job_from_json(v: &Value) -> Result<WorkflowJob, WorkflowError> {
        let fields = v
            .as_obj()
            .ok_or_else(|| parse_err("each JOBS entry must be an object"))?;
        let mut name = None;
        let mut output_bytes = 0u64;
        for (k, val) in fields {
            match k.as_str() {
                "name" => {
                    name = Some(
                        val.as_str()
                            .ok_or_else(|| parse_err("job name must be a string"))?
                            .to_string(),
                    );
                }
                "output_bytes" => {
                    output_bytes = val
                        .as_u64()
                        .ok_or_else(|| parse_err("output_bytes must be an unsigned integer"))?;
                }
                other => return Err(parse_err(format!("unknown job key '{other}'"))),
            }
        }
        Ok(WorkflowJob {
            name: name.ok_or_else(|| parse_err("job missing 'name'"))?,
            output_bytes,
        })
    }

    fn edge_from_json(v: &Value) -> Result<WorkflowEdge, WorkflowError> {
        let fields = v
            .as_obj()
            .ok_or_else(|| parse_err("each EDGES entry must be an object"))?;
        let mut from = None;
        let mut to = None;
        let mut artifact = None;
        for (k, val) in fields {
            let s = val
                .as_str()
                .ok_or_else(|| parse_err(format!("edge key '{k}' must be a string")))?
                .to_string();
            match k.as_str() {
                "from" => from = Some(s),
                "to" => to = Some(s),
                "artifact" => artifact = Some(s),
                other => return Err(parse_err(format!("unknown edge key '{other}'"))),
            }
        }
        Ok(WorkflowEdge {
            from: from.ok_or_else(|| parse_err("edge missing 'from'"))?,
            to: to.ok_or_else(|| parse_err("edge missing 'to'"))?,
            artifact: artifact.ok_or_else(|| parse_err("edge missing 'artifact'"))?,
        })
    }

    /// Parse (and validate) a WORKFLOW file's text.
    pub fn parse(text: &str) -> Result<Self, WorkflowError> {
        let v = parse(text).map_err(|e| parse_err(format!("invalid JSON: {e}")))?;
        Self::from_json(&v)
    }

    /// Render the WORKFLOW file text; `parse(render())` is bit-identical
    /// (pinned by the round-trip tests).
    pub fn render(&self) -> String {
        self.to_json().pretty()
    }

    /// Resolve a `--workflow` value: a canonical shape name
    /// ([`crate::workloads::dag`]) first, else a WORKFLOW file path.
    pub fn resolve(value: &str) -> Result<Self, WorkflowError> {
        if let Some(spec) = crate::workloads::dag::shape(value) {
            return Ok(spec);
        }
        match std::fs::read_to_string(value) {
            Ok(text) => Self::parse(&text),
            Err(_) => Err(WorkflowError::Unknown(value.to_string())),
        }
    }
}

/// In-code spec construction; `build` runs the same validation as the
/// file parser.
///
/// ```
/// use ds_rs::workflow::WorkflowSpec;
///
/// let wf = WorkflowSpec::builder("two-step")
///     .job("extract", 1_000_000)
///     .job("report", 0)
///     .edge("extract", "report", "features")
///     .build()
///     .unwrap();
/// assert_eq!(wf.critical_path_len(), 2);
/// ```
#[derive(Debug, Clone)]
pub struct WorkflowBuilder {
    name: String,
    jobs: Vec<WorkflowJob>,
    edges: Vec<WorkflowEdge>,
}

impl WorkflowBuilder {
    /// Declare a job producing `output_bytes` of artifact data.
    pub fn job(mut self, name: &str, output_bytes: u64) -> Self {
        self.jobs.push(WorkflowJob {
            name: name.to_string(),
            output_bytes,
        });
        self
    }

    /// Declare a dependency: `to` waits for `from`'s `artifact`.
    pub fn edge(mut self, from: &str, to: &str, artifact: &str) -> Self {
        self.edges.push(WorkflowEdge {
            from: from.to_string(),
            to: to.to_string(),
            artifact: artifact.to_string(),
        });
        self
    }

    pub fn build(self) -> Result<WorkflowSpec, WorkflowError> {
        WorkflowSpec::new(&self.name, self.jobs, self.edges)
    }
}

/// Where intermediate artifacts live between producer and consumer —
/// the Juve et al. data-sharing axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SharingMode {
    /// Producers upload artifacts to the S3 data bucket, consumers
    /// download them: full request + egress billing, bucket-throughput
    /// contention.  The neutral default — non-workflow runs are
    /// unaffected by it.
    #[default]
    S3Staging,
    /// Artifacts stay on the producing node; consumers pull
    /// peer-to-peer, contending on the producer's serving link.  No S3
    /// requests, no egress dollars.
    NodeLocal,
    /// All artifact traffic goes through one shared-filesystem server
    /// link (uploads and downloads both contend on it).  No S3 dollars.
    SharedFs,
}

impl SharingMode {
    pub const ALL: [SharingMode; 3] = [Self::S3Staging, Self::NodeLocal, Self::SharedFs];

    /// Stable name (also the sweep-axis label).
    pub fn name(self) -> &'static str {
        match self {
            Self::S3Staging => "s3",
            Self::NodeLocal => "node-local",
            Self::SharedFs => "shared-fs",
        }
    }

    /// Parse a mode name (the `--sharing` axis).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|m| m.name() == s)
    }
}

/// One depth stage's observed span: when its first job became
/// SQS-visible and when its last artifact committed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSpan {
    /// Longest-path depth of the jobs in this stage (0 = roots).
    pub depth: u32,
    /// Earliest release (SQS visibility) among the stage's jobs, ms.
    pub released_ms: SimTime,
    /// Latest artifact commit among the stage's jobs, ms.
    pub committed_ms: SimTime,
}

/// The workflow slice of a run report, the DAG analog of
/// `Pool`/`Data`/`ScalingBreakdown`.  `workflow == "none"` — the
/// default — is the paper's flat bag of independent jobs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkflowBreakdown {
    /// Workflow name ("none" when the run had no DAG).
    pub workflow: String,
    /// Sharing-mode name the artifacts moved under.
    pub sharing: String,
    pub nodes: u64,
    pub edges: u64,
    /// Jobs on the longest dependency chain.
    pub critical_path_len: u64,
    /// Dependent jobs released by the readiness scheduler (roots are
    /// submitted up front and not counted).
    pub releases: u64,
    /// Artifact bytes moved through the sharing medium (producer
    /// uploads where the mode stages them, plus consumer downloads;
    /// duplicate attempts re-stage and count again).
    pub artifact_bytes_staged: u64,
    /// Total time released jobs spent waiting on their slowest parent,
    /// measured from each job's first-committed parent artifact.
    pub stall_ms: u64,
    /// Per-depth-stage spans (per-run evidence, like the scaling
    /// timeline; dropped in cross-seed summaries).
    pub stages: Vec<StageSpan>,
}

impl Default for WorkflowBreakdown {
    fn default() -> Self {
        Self {
            workflow: "none".to_string(),
            sharing: SharingMode::S3Staging.name().to_string(),
            nodes: 0,
            edges: 0,
            critical_path_len: 0,
            releases: 0,
            artifact_bytes_staged: 0,
            stall_ms: 0,
            stages: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> WorkflowSpec {
        WorkflowSpec::builder("d")
            .job("split", 100)
            .job("a", 10)
            .job("b", 20)
            .job("merge", 1)
            .edge("split", "a", "tiles")
            .edge("split", "b", "tiles")
            .edge("a", "merge", "part-a")
            .edge("b", "merge", "part-b")
            .build()
            .unwrap()
    }

    #[test]
    fn builder_and_topology() {
        let wf = diamond();
        assert_eq!(wf.node_count(), 4);
        assert_eq!(wf.edge_count(), 4);
        assert_eq!(wf.critical_path_len(), 3);
        assert_eq!(wf.depths(), vec![0, 1, 1, 2]);
        // Canonical Kahn: split first, then a before b, merge last.
        assert_eq!(wf.topo_order(), vec![0, 1, 2, 3]);
        // merge pulls both branch artifacts.
        assert_eq!(wf.input_bytes(wf.index_of("merge").unwrap()), 30);
        assert_eq!(wf.input_bytes(0), 0);
    }

    #[test]
    fn cycle_is_a_typed_error() {
        let err = WorkflowSpec::builder("c")
            .job("a", 0)
            .job("b", 0)
            .edge("a", "b", "x")
            .edge("b", "a", "y")
            .build()
            .unwrap_err();
        match err {
            WorkflowError::Cycle { workflow, jobs } => {
                assert_eq!(workflow, "c");
                assert_eq!(jobs, vec!["a".to_string(), "b".to_string()]);
            }
            other => panic!("expected Cycle, got {other}"),
        }
    }

    #[test]
    fn dangling_edge_names_the_unknown_job() {
        let err = WorkflowSpec::builder("d")
            .job("a", 0)
            .edge("a", "ghost", "x")
            .build()
            .unwrap_err();
        assert_eq!(
            err,
            WorkflowError::UnknownJob {
                workflow: "d".into(),
                artifact: "x".into(),
                job: "ghost".into(),
            }
        );
    }

    #[test]
    fn duplicate_names_self_loops_and_empty_are_rejected() {
        assert!(matches!(
            WorkflowSpec::builder("w").job("a", 0).job("a", 0).build(),
            Err(WorkflowError::DuplicateJob { .. })
        ));
        assert!(matches!(
            WorkflowSpec::builder("w").job("a", 0).edge("a", "a", "x").build(),
            Err(WorkflowError::SelfLoop { .. })
        ));
        assert!(matches!(
            WorkflowSpec::builder("w").build(),
            Err(WorkflowError::Empty { .. })
        ));
        assert!(matches!(
            WorkflowSpec::builder("w")
                .job("a", 0)
                .job("b", 0)
                .edge("a", "b", "x")
                .edge("a", "b", "y")
                .build(),
            Err(WorkflowError::DuplicateEdge { .. })
        ));
    }

    #[test]
    fn render_parse_round_trip_is_bit_identical() {
        let wf = diamond();
        let text = wf.render();
        let back = WorkflowSpec::parse(&text).unwrap();
        assert_eq!(back, wf);
        assert_eq!(back.render(), text);
    }

    #[test]
    fn parse_rejects_unknown_keys_and_bad_shapes() {
        assert!(matches!(
            WorkflowSpec::parse(r#"{"NAME": "w", "JOBS": [], "EXTRA": 1}"#),
            Err(WorkflowError::Parse(_))
        ));
        assert!(matches!(
            WorkflowSpec::parse(r#"{"NAME": "w", "JOBS": [{"name": "a", "color": "red"}]}"#),
            Err(WorkflowError::Parse(_))
        ));
        assert!(matches!(
            WorkflowSpec::parse(r#"{"JOBS": [{"name": "a"}]}"#),
            Err(WorkflowError::Parse(_))
        ));
        // Empty JOBS parses as JSON but fails validation.
        assert!(matches!(
            WorkflowSpec::parse(r#"{"NAME": "w", "JOBS": []}"#),
            Err(WorkflowError::Empty { .. })
        ));
    }

    #[test]
    fn fingerprint_is_declaration_order_independent() {
        let a = diamond();
        let b = WorkflowSpec::builder("d")
            .job("merge", 1)
            .job("b", 20)
            .job("a", 10)
            .job("split", 100)
            .edge("b", "merge", "part-b")
            .edge("a", "merge", "part-a")
            .edge("split", "b", "tiles")
            .edge("split", "a", "tiles")
            .build()
            .unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
        // ...but a different topology fingerprints differently.
        let c = WorkflowSpec::builder("d")
            .job("split", 100)
            .job("a", 10)
            .job("b", 20)
            .job("merge", 1)
            .edge("split", "a", "tiles")
            .edge("split", "b", "tiles")
            .edge("a", "merge", "part-a")
            .build()
            .unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn sharing_mode_parse_round_trip() {
        for m in SharingMode::ALL {
            assert_eq!(SharingMode::parse(m.name()), Some(m));
        }
        assert_eq!(SharingMode::parse("carrier-pigeon"), None);
        assert_eq!(SharingMode::default(), SharingMode::S3Staging);
    }

    #[test]
    fn breakdown_default_is_the_flat_run() {
        let b = WorkflowBreakdown::default();
        assert_eq!(b.workflow, "none");
        assert_eq!(b.sharing, "s3");
        assert_eq!(b.nodes, 0);
        assert!(b.stages.is_empty());
    }

    #[test]
    fn resolve_finds_shapes_and_rejects_nonsense() {
        let wf = WorkflowSpec::resolve("diamond").unwrap();
        assert_eq!(wf.name, "diamond");
        assert!(matches!(
            WorkflowSpec::resolve("no-such-workflow"),
            Err(WorkflowError::Unknown(_))
        ));
    }
}
