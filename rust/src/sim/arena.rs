//! Generational slot arena — contiguous, allocation-free entity storage
//! for the simulation hot path.
//!
//! `Arena<T>` stores values in a `Vec` of slots addressed by [`SlotId`]
//! (a `u32` index plus a `u32` generation).  Freed slots go on a free
//! list and are reused; the generation counter bumps on every free, so a
//! stale `SlotId` held across a remove can never alias the slot's new
//! occupant — `get` on a stale id returns `None` instead of someone
//! else's state.  Lookups are a bounds check and a generation compare
//! (no hashing), and the steady-state tick loop allocates nothing: slots
//! recycle in place.
//!
//! This is the per-run bookkeeping store for `coordinator/run.rs` (one
//! slot per live container), replacing the trio of
//! `HashMap<ContainerId, _>` maps that used to shadow each other.

/// Handle to a slot in an [`Arena`]: index + generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SlotId {
    index: u32,
    generation: u32,
}

impl SlotId {
    /// The slot's raw index (diagnostics only — not a stable identity;
    /// use the full `SlotId` for that).
    pub fn index(self) -> u32 {
        self.index
    }
}

#[derive(Debug)]
struct Slot<T> {
    generation: u32,
    value: Option<T>,
}

/// Contiguous generational storage.  See the module docs.
#[derive(Debug)]
pub struct Arena<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Arena<T> {
    pub fn new() -> Self {
        Self {
            slots: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self {
            slots: Vec::with_capacity(cap),
            free: Vec::new(),
            len: 0,
        }
    }

    /// Number of live values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert a value, reusing a freed slot when one exists.
    pub fn insert(&mut self, value: T) -> SlotId {
        self.len += 1;
        if let Some(index) = self.free.pop() {
            let slot = &mut self.slots[index as usize];
            debug_assert!(slot.value.is_none());
            slot.value = Some(value);
            SlotId {
                index,
                generation: slot.generation,
            }
        } else {
            let index = u32::try_from(self.slots.len()).expect("arena capacity exceeds u32");
            self.slots.push(Slot {
                generation: 0,
                value: Some(value),
            });
            SlotId {
                index,
                generation: 0,
            }
        }
    }

    /// Borrow the value at `id`; `None` if it was removed (stale
    /// generation) or never existed.
    pub fn get(&self, id: SlotId) -> Option<&T> {
        let slot = self.slots.get(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        slot.value.as_ref()
    }

    pub fn get_mut(&mut self, id: SlotId) -> Option<&mut T> {
        let slot = self.slots.get_mut(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        slot.value.as_mut()
    }

    pub fn contains(&self, id: SlotId) -> bool {
        self.get(id).is_some()
    }

    /// Remove and return the value at `id`; the slot's generation bumps
    /// so outstanding copies of `id` go stale.
    pub fn remove(&mut self, id: SlotId) -> Option<T> {
        let slot = self.slots.get_mut(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        let value = slot.value.take()?;
        slot.generation = slot.generation.wrapping_add(1);
        self.free.push(id.index);
        self.len -= 1;
        Some(value)
    }

    /// Iterate live `(SlotId, &T)` pairs in slot-index order
    /// (deterministic for a deterministic insert/remove history).
    pub fn iter(&self) -> impl Iterator<Item = (SlotId, &T)> {
        self.slots.iter().enumerate().filter_map(|(i, slot)| {
            slot.value.as_ref().map(|v| {
                (
                    SlotId {
                        index: i as u32,
                        generation: slot.generation,
                    },
                    v,
                )
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut a = Arena::new();
        let x = a.insert("x");
        let y = a.insert("y");
        assert_eq!(a.len(), 2);
        assert_eq!(a.get(x), Some(&"x"));
        assert_eq!(a.get(y), Some(&"y"));
        assert_eq!(a.remove(x), Some("x"));
        assert_eq!(a.get(x), None);
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn stale_id_cannot_alias_reused_slot() {
        let mut a = Arena::new();
        let old = a.insert(1u32);
        a.remove(old);
        let new = a.insert(2u32);
        // Same physical slot, different generation.
        assert_eq!(old.index(), new.index());
        assert_ne!(old, new);
        assert_eq!(a.get(old), None);
        assert_eq!(a.remove(old), None);
        assert_eq!(a.get(new), Some(&2));
    }

    #[test]
    fn free_list_recycles_without_growth() {
        let mut a = Arena::new();
        let ids: Vec<_> = (0..8).map(|i| a.insert(i)).collect();
        for id in &ids {
            a.remove(*id);
        }
        for i in 0..8 {
            a.insert(i + 100);
        }
        // All churn happened in the original 8 slots.
        assert_eq!(a.slots.len(), 8);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn iter_walks_index_order() {
        let mut a = Arena::new();
        let first = a.insert(10);
        a.insert(20);
        a.insert(30);
        a.remove(first);
        let live: Vec<u32> = a.iter().map(|(_, v)| *v).collect();
        assert_eq!(live, vec![20, 30]);
        assert!(!a.is_empty());
    }

    #[test]
    fn get_mut_updates_in_place() {
        let mut a = Arena::new();
        let id = a.insert(0u64);
        *a.get_mut(id).unwrap() += 41;
        *a.get_mut(id).unwrap() += 1;
        assert_eq!(a.get(id), Some(&42));
        assert!(a.contains(id));
    }
}
