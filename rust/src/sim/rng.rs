//! Deterministic RNG for the simulation: xoshiro256++ seeded via SplitMix64.
//!
//! No external crates (the image vendors only the `xla` closure), and the
//! simulator must be bit-reproducible across runs and platforms, so we
//! carry our own small generator plus the distributions the substrate
//! needs (uniform, exponential inter-arrival, normal via Box–Muller,
//! Bernoulli, log-normal job durations).

/// xoshiro256++ PRNG.  Cheap, high-quality, and trivially seedable.
#[derive(Debug, Clone)]
pub struct SimRng {
    s: [u64; 4],
    /// Cached second normal sample from Box–Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl SimRng {
    /// Seed deterministically; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. per instance, per queue) so
    /// component draws don't perturb each other's sequences.
    pub fn fork(&mut self, tag: u64) -> SimRng {
        SimRng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Lemire's multiply-shift rejection method (unbiased).
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with mean `mean` (inter-arrival times, failures).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal parameterized by the *target* mean and coefficient of
    /// variation — the natural parameterization for job durations
    /// ("mean 90 s, cv 0.3").
    pub fn lognormal_mean_cv(&mut self, mean: f64, cv: f64) -> f64 {
        if cv <= 0.0 {
            return mean;
        }
        let sigma2 = (1.0 + cv * cv).ln();
        let mu = mean.ln() - sigma2 / 2.0;
        (mu + sigma2.sqrt() * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = SimRng::new(42);
        let mut b = SimRng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SimRng::new(1);
        let mut b = SimRng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SimRng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = SimRng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = SimRng::new(13);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn exp_mean_matches() {
        let mut r = SimRng::new(17);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SimRng::new(19);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_mean_cv_matches() {
        let mut r = SimRng::new(23);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal_mean_cv(90.0, 0.3)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 90.0).abs() < 1.0, "mean={mean}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn lognormal_zero_cv_is_constant() {
        let mut r = SimRng::new(29);
        assert_eq!(r.lognormal_mean_cv(42.0, 0.0), 42.0);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = SimRng::new(31);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = SimRng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
