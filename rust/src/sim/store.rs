//! Keyed entity storage for the AWS substrate: a `HashMap`-compatible
//! store with a dense, index-addressed backend.
//!
//! EC2 instances and ECS containers get small sequential `u64` ids
//! (1, 2, 3, …), so keying them through a general-purpose `HashMap`
//! pays hashing and pointer-chasing on every lookup in the tick loop.
//! [`IdStore`] keeps the map API but defaults to a dense `Vec<Option<T>>`
//! indexed by the raw id — a lookup is one bounds check, iteration is a
//! contiguous scan, and no id arithmetic is needed (slot 0 is simply
//! never used).  The [`StoreKind::Map`] backend remains available as the
//! reference implementation for the A/B equivalence gate in
//! `tests/determinism.rs`.
//!
//! Determinism note: `values()`/`iter()` yield in ascending-id order on
//! *both* backends (the map backend sorts), so switching backends cannot
//! reorder any downstream iteration.

use std::collections::HashMap;

/// Which backing storage an [`IdStore`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StoreKind {
    /// `HashMap<u64, T>` — the reference implementation the dense
    /// backend is gated against.
    Map,
    /// `Vec<Option<T>>` indexed by the raw id — cache-local; the default.
    #[default]
    Dense,
}

#[derive(Debug)]
enum Backend<T> {
    Map(HashMap<u64, T>),
    Dense(Vec<Option<T>>),
}

/// Map from small sequential `u64` ids to values.  See the module docs.
#[derive(Debug)]
pub struct IdStore<T> {
    backend: Backend<T>,
    len: usize,
}

impl<T> Default for IdStore<T> {
    fn default() -> Self {
        Self::with_kind(StoreKind::default())
    }
}

impl<T> IdStore<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_kind(kind: StoreKind) -> Self {
        let backend = match kind {
            StoreKind::Map => Backend::Map(HashMap::new()),
            StoreKind::Dense => Backend::Dense(Vec::new()),
        };
        Self { backend, len: 0 }
    }

    /// Which backend this store runs on.
    pub fn kind(&self) -> StoreKind {
        match self.backend {
            Backend::Map(_) => StoreKind::Map,
            Backend::Dense(_) => StoreKind::Dense,
        }
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert `value` at `id`, returning the previous occupant if any.
    pub fn insert(&mut self, id: u64, value: T) -> Option<T> {
        let prev = match &mut self.backend {
            Backend::Map(m) => m.insert(id, value),
            Backend::Dense(v) => {
                let i = usize::try_from(id).expect("id exceeds usize");
                if i >= v.len() {
                    v.resize_with(i + 1, || None);
                }
                v[i].replace(value)
            }
        };
        if prev.is_none() {
            self.len += 1;
        }
        prev
    }

    pub fn get(&self, id: u64) -> Option<&T> {
        match &self.backend {
            Backend::Map(m) => m.get(&id),
            Backend::Dense(v) => v.get(id as usize).and_then(|s| s.as_ref()),
        }
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut T> {
        match &mut self.backend {
            Backend::Map(m) => m.get_mut(&id),
            Backend::Dense(v) => v.get_mut(id as usize).and_then(|s| s.as_mut()),
        }
    }

    pub fn contains(&self, id: u64) -> bool {
        self.get(id).is_some()
    }

    pub fn remove(&mut self, id: u64) -> Option<T> {
        let prev = match &mut self.backend {
            Backend::Map(m) => m.remove(&id),
            Backend::Dense(v) => v.get_mut(id as usize).and_then(|s| s.take()),
        };
        if prev.is_some() {
            self.len -= 1;
        }
        prev
    }

    /// Live values in ascending-id order (both backends).
    pub fn values(&self) -> std::vec::IntoIter<&T> {
        match &self.backend {
            Backend::Map(m) => {
                let mut pairs: Vec<(u64, &T)> = m.iter().map(|(&id, v)| (id, v)).collect();
                pairs.sort_unstable_by_key(|&(id, _)| id);
                pairs
                    .into_iter()
                    .map(|(_, v)| v)
                    .collect::<Vec<_>>()
                    .into_iter()
            }
            Backend::Dense(v) => v.iter().flatten().collect::<Vec<_>>().into_iter(),
        }
    }

    /// Live `(id, &value)` pairs in ascending-id order (both backends).
    pub fn iter(&self) -> std::vec::IntoIter<(u64, &T)> {
        match &self.backend {
            Backend::Map(m) => {
                let mut pairs: Vec<(u64, &T)> = m.iter().map(|(&id, v)| (id, v)).collect();
                pairs.sort_unstable_by_key(|&(id, _)| id);
                pairs.into_iter()
            }
            Backend::Dense(v) => v
                .iter()
                .enumerate()
                .filter_map(|(i, s)| s.as_ref().map(|v| (i as u64, v)))
                .collect::<Vec<_>>()
                .into_iter(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn on_both(check: impl Fn(IdStore<String>, StoreKind)) {
        for kind in [StoreKind::Map, StoreKind::Dense] {
            check(IdStore::with_kind(kind), kind);
        }
    }

    #[test]
    fn default_backend_is_dense() {
        let s: IdStore<u32> = IdStore::new();
        assert_eq!(s.kind(), StoreKind::Dense);
        assert_eq!(StoreKind::default(), StoreKind::Dense);
    }

    #[test]
    fn map_semantics_on_both_backends() {
        on_both(|mut s, kind| {
            assert!(s.insert(3, "c".into()).is_none(), "{kind:?}");
            assert!(s.insert(1, "a".into()).is_none());
            assert_eq!(s.insert(3, "c2".into()).as_deref(), Some("c"));
            assert_eq!(s.len(), 2);
            assert_eq!(s.get(3).map(String::as_str), Some("c2"));
            assert!(s.contains(1));
            assert!(!s.contains(2));
            assert_eq!(s.remove(1).as_deref(), Some("a"));
            assert!(s.remove(1).is_none());
            assert_eq!(s.len(), 1);
            assert!(!s.is_empty());
        });
    }

    #[test]
    fn iteration_is_id_ascending_on_both_backends() {
        on_both(|mut s, kind| {
            for id in [5u64, 2, 9, 1] {
                s.insert(id, format!("v{id}"));
            }
            s.remove(9);
            let ids: Vec<u64> = s.iter().map(|(id, _)| id).collect();
            assert_eq!(ids, vec![1, 2, 5], "{kind:?}");
            let vals: Vec<&String> = s.values().collect();
            assert_eq!(
                vals.iter().map(|v| v.as_str()).collect::<Vec<_>>(),
                vec!["v1", "v2", "v5"],
                "{kind:?}"
            );
        });
    }

    #[test]
    fn get_mut_updates_in_place() {
        on_both(|mut s, _| {
            s.insert(7, "x".into());
            s.get_mut(7).unwrap().push('!');
            assert_eq!(s.get(7).map(String::as_str), Some("x!"));
            assert!(s.get_mut(8).is_none());
        });
    }

    #[test]
    fn sparse_ids_work_on_dense_backend() {
        // register_instance-style usage: arbitrary (not insertion-order)
        // small ids.
        let mut s: IdStore<u8> = IdStore::with_kind(StoreKind::Dense);
        s.insert(100, 1);
        s.insert(2, 2);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(100), Some(&1));
        assert_eq!(s.iter().map(|(id, _)| id).collect::<Vec<_>>(), vec![2, 100]);
    }
}
