//! Simulated time: integer milliseconds since run start.
//!
//! Integer time makes event ordering exact (no f64 ties drifting across
//! platforms) and hashes/compares trivially.  Helper constants keep call
//! sites readable: `3 * MINUTE + 30 * SECOND`.

/// Simulated timestamp / duration in milliseconds.
pub type SimTime = u64;

/// One simulated second.
pub const SECOND: SimTime = 1_000;
/// One simulated minute.
pub const MINUTE: SimTime = 60 * SECOND;
/// One simulated hour.
pub const HOUR: SimTime = 60 * MINUTE;

/// Render a [`SimTime`] as `HH:MM:SS.mmm` for logs and reports.
pub fn fmt_time(t: SimTime) -> String {
    let ms = t % 1000;
    let s = (t / SECOND) % 60;
    let m = (t / MINUTE) % 60;
    let h = t / HOUR;
    format!("{h:02}:{m:02}:{s:02}.{ms:03}")
}

/// Render a duration compactly: `90s`, `2.5m`, `3.2h`.
pub fn fmt_dur(t: SimTime) -> String {
    if t >= HOUR {
        format!("{:.2}h", t as f64 / HOUR as f64)
    } else if t >= MINUTE {
        format!("{:.1}m", t as f64 / MINUTE as f64)
    } else {
        format!("{:.1}s", t as f64 / SECOND as f64)
    }
}

/// Convert fractional seconds to [`SimTime`], saturating at 0.
pub fn from_secs_f64(secs: f64) -> SimTime {
    if secs <= 0.0 {
        0
    } else {
        (secs * 1000.0).round() as SimTime
    }
}

/// Convert [`SimTime`] to fractional seconds.
pub fn to_secs_f64(t: SimTime) -> f64 {
    t as f64 / 1000.0
}

/// Convert [`SimTime`] to fractional hours (billing granularity).
pub fn to_hours_f64(t: SimTime) -> f64 {
    t as f64 / HOUR as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_compose() {
        assert_eq!(HOUR, 3_600_000);
        assert_eq!(MINUTE, 60_000);
        assert_eq!(2 * MINUTE + 30 * SECOND, 150_000);
    }

    #[test]
    fn fmt_time_renders() {
        assert_eq!(fmt_time(0), "00:00:00.000");
        assert_eq!(fmt_time(HOUR + 2 * MINUTE + 3 * SECOND + 45), "01:02:03.045");
        assert_eq!(fmt_time(25 * HOUR), "25:00:00.000");
    }

    #[test]
    fn fmt_dur_scales() {
        assert_eq!(fmt_dur(500), "0.5s");
        assert_eq!(fmt_dur(90 * SECOND), "1.5m");
        assert_eq!(fmt_dur(2 * HOUR + 30 * MINUTE), "2.50h");
    }

    #[test]
    fn secs_roundtrip() {
        assert_eq!(from_secs_f64(1.5), 1500);
        assert_eq!(from_secs_f64(-3.0), 0);
        assert!((to_secs_f64(2500) - 2.5).abs() < 1e-12);
        assert!((to_hours_f64(HOUR / 2) - 0.5).abs() < 1e-12);
    }
}
