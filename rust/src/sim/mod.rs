//! Discrete-event simulation core.
//!
//! Everything in the AWS substrate runs on a simulated clock so that a
//! multi-hour spot-fleet run (the paper's "walk away and let things run")
//! replays in milliseconds, deterministically, under a fixed seed.  The
//! design is a classic DES: a monotone virtual clock plus a binary heap of
//! timestamped events with FIFO tie-breaking.
//!
//! Real compute (PJRT execution of the AOT artifacts) happens *inline*
//! during an event; its measured wall-time is charged to the simulated
//! clock by the worker's duration model (see [`crate::workloads`]).

pub mod clock;
pub mod events;
pub mod rng;

pub use clock::{SimTime, HOUR, MINUTE, SECOND};
pub use events::EventQueue;
pub use rng::SimRng;
