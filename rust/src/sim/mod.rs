//! Discrete-event simulation core.
//!
//! Everything in the AWS substrate runs on a simulated clock so that a
//! multi-hour spot-fleet run (the paper's "walk away and let things run")
//! replays in milliseconds, deterministically, under a fixed seed.  The
//! design is a classic DES: a monotone virtual clock plus a priority
//! queue of timestamped events with FIFO tie-breaking (a bucketed
//! calendar queue by default, with the reference binary heap selectable
//! for A/B equivalence runs — see [`events`] and [`calendar`]).
//!
//! Real compute (PJRT execution of the AOT artifacts) happens *inline*
//! during an event; its measured wall-time is charged to the simulated
//! clock by the worker's duration model (see [`crate::workloads`]).

pub mod arena;
pub mod calendar;
pub mod clock;
pub mod events;
pub mod rng;
pub mod store;

pub use arena::{Arena, SlotId};
pub use clock::{SimTime, HOUR, MINUTE, SECOND};
pub use events::{EventQueue, QueueKind};
pub use rng::SimRng;
pub use store::{IdStore, StoreKind};
