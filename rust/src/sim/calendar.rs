//! Bucketed calendar queue (Brown 1988) — the O(1)-amortised priority
//! queue behind [`crate::sim::EventQueue`]'s `Calendar` backend.
//!
//! The structure is a circular array of "days" (buckets), each `width`
//! milliseconds of simulated time wide; an event at time `t` lives in
//! bucket `(t / width) % nbuckets`.  Because a discrete-event simulation
//! dequeues in near-monotone time order, the next event is almost always
//! found in the bucket the clock is already pointing at, making both
//! enqueue and dequeue O(1) amortised — versus O(log n) for the binary
//! heap — at million-event scale.
//!
//! Contract: pops come out in strictly ascending `(time, seq)` order,
//! bit-identical to the `BinaryHeap` implementation (the A/B gate in
//! `tests/determinism.rs` enforces this end-to-end).  Each bucket is kept
//! sorted by `(time, seq)` via binary-search insertion; since `seq` is
//! strictly increasing, keys are unique and FIFO tie-breaking on equal
//! timestamps is exact.
//!
//! Resizing: the bucket count doubles when occupancy exceeds two events
//! per bucket and halves below one event per two buckets (floor
//! [`MIN_BUCKETS`]); a resize rehashes every event and re-derives `width`
//! from the observed inter-event spacing, so the calendar adapts to the
//! workload's event density without tuning.

use super::clock::SimTime;

/// Smallest bucket count the calendar will shrink to.
const MIN_BUCKETS: usize = 16;

/// Starting width: one simulated second per bucket (event timestamps are
/// millisecond-resolution).  Self-corrects at the first resize.
const INITIAL_WIDTH: u64 = 1_000;

#[derive(Debug)]
struct Slot<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

/// A calendar queue of `(time, seq, event)` entries popping in ascending
/// `(time, seq)` order.
///
/// Invariants assumed from the caller ([`crate::sim::EventQueue`]):
/// `seq` values are unique and every inserted `time` is `>=` the time of
/// the last pop (the simulation clock never runs backwards).
#[derive(Debug)]
pub struct CalendarQueue<E> {
    buckets: Vec<Vec<Slot<E>>>,
    /// Simulated width of one bucket, in ms (always `>= 1`).
    width: u64,
    len: usize,
    /// Timestamp of the most recent pop; the dequeue scan starts at this
    /// bucket.  Monotone non-decreasing.
    last_time: SimTime,
}

impl<E> Default for CalendarQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> CalendarQueue<E> {
    pub fn new() -> Self {
        Self {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            width: INITIAL_WIDTH,
            len: 0,
            last_time: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn bucket_of(&self, time: SimTime) -> usize {
        ((time / self.width) % self.buckets.len() as u64) as usize
    }

    /// Insert an entry.  `seq` must be unique across all live entries.
    pub fn push(&mut self, time: SimTime, seq: u64, event: E) {
        let b = self.bucket_of(time);
        let bucket = &mut self.buckets[b];
        // Keep the bucket sorted by (time, seq): binary search for the
        // insertion point.  Err is guaranteed (seq unique ⇒ no duplicate
        // keys).
        let at = match bucket.binary_search_by(|s| (s.time, s.seq).cmp(&(time, seq))) {
            Ok(i) | Err(i) => i,
        };
        bucket.insert(at, Slot { time, seq, event });
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            let target = self.buckets.len() * 2;
            self.resize(target);
        }
    }

    /// Remove and return the entry with the smallest `(time, seq)`.
    pub fn pop(&mut self) -> Option<(SimTime, u64, E)> {
        let b = self.find_min()?;
        let slot = self.buckets[b].remove(0);
        self.len -= 1;
        self.last_time = slot.time;
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 2 {
            let target = (self.buckets.len() / 2).max(MIN_BUCKETS);
            self.resize(target);
        }
        Some((slot.time, slot.seq, slot.event))
    }

    /// Timestamp of the minimum entry without removing it.
    pub fn peek_time(&self) -> Option<SimTime> {
        let b = self.find_min()?;
        self.buckets[b].first().map(|s| s.time)
    }

    /// Index of the bucket whose head is the global minimum `(time, seq)`.
    ///
    /// Walks day-by-day from the bucket containing `last_time`: a bucket
    /// head qualifies only if it falls inside that step's calendar "day"
    /// (otherwise it belongs to a later lap of the circular array).  All
    /// live entries have `time >= last_time`, so the first qualifying
    /// head is the global minimum — equal timestamps always share a
    /// bucket, where sorting makes the head the FIFO-earliest.  If a full
    /// lap finds nothing (a sparse queue far in the future), fall back to
    /// a direct scan of all bucket heads.
    fn find_min(&self) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        let n = self.buckets.len() as u64;
        let mut day = self.last_time / self.width;
        for _ in 0..self.buckets.len() {
            let b = (day % n) as usize;
            if let Some(head) = self.buckets[b].first() {
                let day_end = (day + 1).saturating_mul(self.width);
                if head.time < day_end {
                    return Some(b);
                }
            }
            day += 1;
        }
        // Direct search: compare heads by (time, seq).
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.first().map(|s| ((s.time, s.seq), i)))
            .min_by_key(|&(key, _)| key)
            .map(|(_, i)| i)
    }

    /// Rehash into `nbuckets` buckets, re-deriving the bucket width from
    /// the observed event-time span.
    fn resize(&mut self, nbuckets: usize) {
        let mut entries: Vec<Slot<E>> = Vec::with_capacity(self.len);
        for bucket in &mut self.buckets {
            entries.append(bucket);
        }
        // Sorting once and appending in order keeps every per-bucket
        // insertion at the tail (binary search hits the end), making the
        // rehash O(len log len) overall.
        entries.sort_by_key(|s| (s.time, s.seq));
        self.width = Self::derive_width(&entries, self.width);
        self.buckets = (0..nbuckets).map(|_| Vec::new()).collect();
        self.len = 0;
        for s in entries {
            self.push(s.time, s.seq, s.event);
        }
    }

    /// Width heuristic: twice the average gap between adjacent event
    /// times (clamped to `>= 1` ms), so a bucket holds a couple of events
    /// on average.  With fewer than two distinct times there is no
    /// spacing signal — keep the current width.
    fn derive_width(sorted: &[Slot<E>], current: u64) -> u64 {
        if sorted.len() < 2 {
            return current;
        }
        let span = sorted[sorted.len() - 1].time - sorted[0].time;
        if span == 0 {
            return current;
        }
        (span / (sorted.len() as u64 - 1)).saturating_mul(2).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_seq_order() {
        let mut q = CalendarQueue::new();
        q.push(30, 1, "c");
        q.push(10, 2, "a");
        q.push(20, 3, "b");
        assert_eq!(q.pop(), Some((10, 2, "a")));
        assert_eq!(q.pop(), Some((20, 3, "b")));
        assert_eq!(q.pop(), Some((30, 1, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_on_equal_times_across_resizes() {
        // 200 equal-time entries force several doublings; order must
        // still be insertion (seq) order.
        let mut q = CalendarQueue::new();
        for i in 0..200u64 {
            q.push(5, i, i);
        }
        assert_eq!(q.len(), 200);
        for i in 0..200u64 {
            assert_eq!(q.pop(), Some((5, i, i)));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn sparse_far_future_uses_direct_search() {
        // One event many "years" past the current cursor: the lap finds
        // nothing and the head scan must locate it.
        let mut q = CalendarQueue::new();
        q.push(3, 1, "near");
        assert_eq!(q.pop(), Some((3, 1, "near")));
        q.push(10_000_000, 2, "far");
        assert_eq!(q.peek_time(), Some(10_000_000));
        assert_eq!(q.pop(), Some((10_000_000, 2, "far")));
    }

    #[test]
    fn shrinks_after_drain() {
        let mut q = CalendarQueue::new();
        for i in 0..500u64 {
            q.push(i * 7, i, ());
        }
        let grown = q.buckets.len();
        assert!(grown > MIN_BUCKETS);
        while q.pop().is_some() {}
        assert!(q.buckets.len() < grown);
        assert!(q.buckets.len() >= MIN_BUCKETS);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn interleaved_monotone_workload() {
        // A DES-shaped workload: pop the minimum, schedule a few events
        // relative to it.  Verify global (time, seq) ascending order.
        let mut q = CalendarQueue::new();
        let mut seq = 0u64;
        q.push(0, seq, 0u32);
        let mut last = (0u64, 0u64);
        let mut popped = 0;
        while let Some((t, s, e)) = q.pop() {
            assert!((t, s) > last || popped == 0, "order violated at {t},{s}");
            last = (t, s);
            popped += 1;
            if e < 7 {
                for k in 1..=3u64 {
                    seq += 1;
                    q.push(t + k * 13 % 97, seq, e + 1);
                }
            }
        }
        // A full ternary tree of depth 7: 3^0 + … + 3^7 pops.
        assert_eq!(popped, (0u32..=7).map(|d| 3usize.pow(d)).sum::<usize>());
    }
}
