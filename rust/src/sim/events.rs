//! Generic timestamped event queue with deterministic FIFO tie-breaking.
//!
//! Events are ordered by `(time, seq)`: two events scheduled for the same
//! simulated instant pop in the order they were pushed, which keeps
//! whole-simulation replays bit-identical.
//!
//! Two interchangeable backends implement that contract (selected by
//! [`QueueKind`]; see DESIGN.md §"Event core"):
//!
//! - `Heap` — the classic `BinaryHeap` min-heap, O(log n) per operation.
//! - `Calendar` — a bucketed [`CalendarQueue`], O(1) amortised for the
//!   near-monotone access pattern of a DES.  The default.
//!
//! The backends are *bit-equivalent*, not merely both correct: the A/B
//! gate in `tests/determinism.rs` runs the full determinism matrix under
//! each and asserts identical `RunReport`s, and the differential property
//! suite in `tests/calendar_queue.rs` pins pop-order equality on
//! randomized schedules.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::calendar::CalendarQueue;
use super::clock::SimTime;

/// Which priority-queue implementation backs an [`EventQueue`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueKind {
    /// `BinaryHeap` of `(time, seq)` entries — the reference
    /// implementation the calendar is gated against.
    Heap,
    /// Bucketed calendar queue — O(1) amortised; the default.
    #[default]
    Calendar,
}

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

enum Backend<E> {
    Heap(BinaryHeap<Entry<E>>),
    Calendar(CalendarQueue<E>),
}

/// Min-queue of `(SimTime, E)` with FIFO ordering for equal timestamps.
pub struct EventQueue<E> {
    backend: Backend<E>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// A queue on the default backend ([`QueueKind::Calendar`]).
    pub fn new() -> Self {
        Self::with_kind(QueueKind::default())
    }

    /// A queue on an explicit backend (the A/B equivalence gate runs the
    /// same simulation under both).
    pub fn with_kind(kind: QueueKind) -> Self {
        let backend = match kind {
            QueueKind::Heap => Backend::Heap(BinaryHeap::new()),
            QueueKind::Calendar => Backend::Calendar(CalendarQueue::new()),
        };
        Self {
            backend,
            seq: 0,
            now: 0,
        }
    }

    /// Which backend this queue runs on.
    pub fn kind(&self) -> QueueKind {
        match self.backend {
            Backend::Heap(_) => QueueKind::Heap,
            Backend::Calendar(_) => QueueKind::Calendar,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `at`.  Scheduling in the past
    /// (before `now`) is a logic error and panics in debug builds; in
    /// release it clamps to `now` (the event fires immediately-next).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let at = at.max(self.now);
        self.seq += 1;
        match &mut self.backend {
            Backend::Heap(h) => h.push(Entry {
                time: at,
                seq: self.seq,
                event,
            }),
            Backend::Calendar(c) => c.push(at, self.seq, event),
        }
    }

    /// Schedule `event` `delay` after now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let (time, event) = match &mut self.backend {
            Backend::Heap(h) => h.pop().map(|e| (e.time, e.event))?,
            Backend::Calendar(c) => c.pop().map(|(t, _, e)| (t, e))?,
        };
        debug_assert!(time >= self.now);
        self.now = time;
        Some((time, event))
    }

    /// Timestamp of the next event without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        match &self.backend {
            Backend::Heap(h) => h.peek().map(|e| e.time),
            Backend::Calendar(c) => c.peek_time(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Calendar(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever scheduled (telemetry for the perf pass).
    pub fn scheduled_total(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every module test runs against both backends: the API contract is
    /// backend-independent by construction.
    fn both(check: impl Fn(EventQueue<&'static str>)) {
        check(EventQueue::with_kind(QueueKind::Heap));
        check(EventQueue::with_kind(QueueKind::Calendar));
    }

    #[test]
    fn default_backend_is_calendar() {
        let q: EventQueue<()> = EventQueue::new();
        assert_eq!(q.kind(), QueueKind::Calendar);
        assert_eq!(QueueKind::default(), QueueKind::Calendar);
    }

    #[test]
    fn pops_in_time_order() {
        both(|mut q| {
            q.schedule_at(30, "c");
            q.schedule_at(10, "a");
            q.schedule_at(20, "b");
            assert_eq!(q.pop(), Some((10, "a")));
            assert_eq!(q.pop(), Some((20, "b")));
            assert_eq!(q.pop(), Some((30, "c")));
            assert_eq!(q.pop(), None);
        });
    }

    #[test]
    fn fifo_for_equal_times() {
        for kind in [QueueKind::Heap, QueueKind::Calendar] {
            let mut q = EventQueue::with_kind(kind);
            for i in 0..100 {
                q.schedule_at(5, i);
            }
            for i in 0..100 {
                assert_eq!(q.pop(), Some((5, i)), "{kind:?}");
            }
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        for kind in [QueueKind::Heap, QueueKind::Calendar] {
            let mut q = EventQueue::with_kind(kind);
            q.schedule_at(10, ());
            q.schedule_at(10, ());
            q.schedule_at(25, ());
            let mut last = 0;
            while let Some((t, _)) = q.pop() {
                assert!(t >= last);
                last = t;
            }
            assert_eq!(q.now(), 25);
        }
    }

    #[test]
    fn schedule_in_is_relative() {
        both(|mut q| {
            q.schedule_at(100, "first");
            q.pop();
            q.schedule_in(50, "second");
            assert_eq!(q.pop(), Some((150, "second")));
        });
    }

    #[test]
    fn peek_does_not_advance() {
        for kind in [QueueKind::Heap, QueueKind::Calendar] {
            let mut q = EventQueue::with_kind(kind);
            q.schedule_at(42, ());
            assert_eq!(q.peek_time(), Some(42));
            assert_eq!(q.now(), 0);
        }
    }

    #[test]
    fn interleaved_schedule_pop_deterministic() {
        // Two identical runs produce identical traces — and so do the
        // two backends, against each other.
        let run = |kind: QueueKind| {
            let mut q = EventQueue::with_kind(kind);
            let mut trace = vec![];
            q.schedule_at(1, 0u32);
            while let Some((t, e)) = q.pop() {
                trace.push((t, e));
                if e < 20 {
                    q.schedule_in(u64::from(e % 3), e + 1);
                    q.schedule_in(u64::from(e % 5) + 1, e + 100);
                }
                if trace.len() > 200 {
                    break;
                }
            }
            trace
        };
        assert_eq!(run(QueueKind::Heap), run(QueueKind::Heap));
        assert_eq!(run(QueueKind::Heap), run(QueueKind::Calendar));
    }
}
