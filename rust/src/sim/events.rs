//! Generic timestamped event queue with deterministic FIFO tie-breaking.
//!
//! The binary heap orders by `(time, seq)`: two events scheduled for the
//! same simulated instant pop in the order they were pushed, which keeps
//! whole-simulation replays bit-identical.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::clock::SimTime;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap of `(SimTime, E)` with FIFO ordering for equal timestamps.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `at`.  Scheduling in the past
    /// (before `now`) is a logic error and panics in debug builds; in
    /// release it clamps to `now` (the event fires immediately-next).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let at = at.max(self.now);
        self.seq += 1;
        self.heap.push(Entry {
            time: at,
            seq: self.seq,
            event,
        });
    }

    /// Schedule `event` `delay` after now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now);
        self.now = e.time;
        Some((e.time, e.event))
    }

    /// Timestamp of the next event without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever scheduled (telemetry for the perf pass).
    pub fn scheduled_total(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_for_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_at(10, ());
        q.schedule_at(10, ());
        q.schedule_at(25, ());
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 25);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(100, "first");
        q.pop();
        q.schedule_in(50, "second");
        assert_eq!(q.pop(), Some((150, "second")));
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.schedule_at(42, ());
        assert_eq!(q.peek_time(), Some(42));
        assert_eq!(q.now(), 0);
    }

    #[test]
    fn interleaved_schedule_pop_deterministic() {
        // Two identical runs produce identical traces.
        let run = || {
            let mut q = EventQueue::new();
            let mut trace = vec![];
            q.schedule_at(1, 0u32);
            while let Some((t, e)) = q.pop() {
                trace.push((t, e));
                if e < 20 {
                    q.schedule_in(u64::from(e % 3), e + 1);
                    q.schedule_in(u64::from(e % 5) + 1, e + 100);
                }
                if trace.len() > 200 {
                    break;
                }
            }
            trace
        };
        assert_eq!(run(), run());
    }
}
