//! The Sweep file: the fourth paper-style configuration file.
//!
//! Config, Job, and Fleet files describe *one* run; the Sweep file
//! describes a whole experiment matrix in the same human-readable
//! `KEY value` JSON shape, so a multi-day study is a committable,
//! re-runnable artifact instead of a 10-flag incantation:
//!
//! ```json
//! {
//!   "CONFIG": "files/config.json",
//!   "SEEDS": 8,
//!   "MACHINES": [2, 4, 8],
//!   "VOLATILITY": ["low", "medium"],
//!   "JOB_MEAN_S": [90, 240]
//! }
//! ```
//!
//! `CONFIG` / `JOB` / `FLEET` take a path (resolved relative to the
//! Sweep file) *or* the whole file inlined as an object — the inline
//! form is what [`SweepFile::render`] emits, so a rendered plan is
//! self-contained.  `SEEDS` takes a replicate count (paired with
//! `SEED_BASE`) or an explicit seed array.  Every axis key comes from
//! the registry ([`super::AXES`]); unknown keys are rejected against
//! the same registry that generates `ds sweep --help`, so file schema,
//! parser, and documentation cannot drift.  CLI flags override file
//! keys ([`plan_from_cli`]), mirroring how the paper's `run.py` flags
//! override its config files.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::cli::Args;
use crate::config::{AppConfig, FleetSpec, JobSpec};
use crate::json::{parse, Value};

use super::axis::{render_matrix_entries, sweep_file_keys, Axis, AXES};
use super::{ScenarioMatrix, SweepPlan};

/// A parsed Sweep file: validated JSON plus the directory its relative
/// `CONFIG`/`JOB`/`FLEET` paths resolve against.
#[derive(Debug, Clone)]
pub struct SweepFile {
    value: Value,
    dir: Option<PathBuf>,
}

impl SweepFile {
    /// Read and validate a Sweep file from disk.  Relative
    /// `CONFIG`/`JOB`/`FLEET` paths resolve against the file's
    /// directory.
    pub fn load(path: &str) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let dir = Path::new(path).parent().map(PathBuf::from);
        Self::parse_with_dir(&text, dir).with_context(|| format!("parsing Sweep file {path}"))
    }

    /// Parse a Sweep file from a string (relative paths resolve against
    /// the working directory).
    pub fn from_text(text: &str) -> Result<Self> {
        Self::parse_with_dir(text, None)
    }

    fn parse_with_dir(text: &str, dir: Option<PathBuf>) -> Result<Self> {
        let value = parse(text).context("invalid JSON")?;
        let obj = value
            .as_obj()
            .ok_or_else(|| anyhow!("a Sweep file must be a JSON object"))?;
        // Strict schema from the registry: a typo'd key must not
        // silently run a different study than the one asked for.
        let known = sweep_file_keys();
        for (k, _) in obj {
            if !known.contains(&k.as_str()) {
                bail!(
                    "unknown key '{k}' in Sweep file (valid keys: {})",
                    known.join(", ")
                );
            }
        }
        Ok(Self { value, dir })
    }

    /// Build the plan this file alone describes (no CLI overrides).
    pub fn to_plan(&self) -> Result<SweepPlan> {
        plan_from_cli(&Args::default(), Some(self))
    }

    /// Render a plan as a self-contained Sweep file (inline
    /// `CONFIG`/`JOB`/`FLEET`, explicit `SEEDS` array, every axis key).
    /// `SweepFile::from_text(&render(p))?.to_plan()?` reproduces `p`
    /// exactly — the round-trip gate in `rust/tests/scenario_api.rs`.
    ///
    /// The plan's `base_opts` are *not* part of the file: a Sweep file
    /// captures the experiment (files + matrix), not the host-side run
    /// options, which stay at their defaults when loaded.  Seeds, like
    /// every number in these files, are JSON doubles — exact only up to
    /// 2^53.
    pub fn render(plan: &SweepPlan) -> String {
        let mut v = Value::obj()
            .with("CONFIG", plan.base_cfg.to_json())
            .with("JOB", plan.jobs.to_json())
            .with("FLEET", plan.fleet.to_json())
            .with(
                "SEEDS",
                Value::Arr(plan.matrix.seeds.iter().map(|&s| Value::from(s)).collect()),
            );
        for (key, val) in render_matrix_entries(&plan.matrix) {
            v = v.with(key, val);
        }
        v.pretty()
    }

    fn get(&self, key: &str) -> Option<&Value> {
        self.value.get(key)
    }

    fn resolve(&self, path: &str) -> PathBuf {
        match &self.dir {
            Some(dir) => dir.join(path),
            None => PathBuf::from(path),
        }
    }
}

fn read_to_string(path: &Path) -> Result<String> {
    std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))
}

/// A `CONFIG`/`JOB`/`FLEET` value: a path string (read the file) or an
/// inline object (parse it directly).
fn file_or_inline<T>(
    file: &SweepFile,
    key: &'static str,
    parse: impl Fn(&str) -> Result<T>,
) -> Result<Option<T>> {
    match file.get(key) {
        None => Ok(None),
        Some(Value::Str(path)) => {
            let text = read_to_string(&file.resolve(path))?;
            parse(&text)
                .map(Some)
                .with_context(|| format!("parsing Sweep file {key} ({path})"))
        }
        Some(v @ Value::Obj(_)) => parse(&v.pretty())
            .map(Some)
            .with_context(|| format!("parsing inline {key} in Sweep file")),
        Some(_) => bail!("{key} must be a path string or an inline object"),
    }
}

/// Strict optional string flag: absent -> `None`; present with no value
/// -> error (`ds sweep --job --seeds 8` must not silently sweep the
/// default synthetic plate instead of the forgotten Job file).
fn cli_str<'a>(args: &'a Args, name: &str) -> Result<Option<&'a str>> {
    match args.get(name) {
        Some(v) => Ok(Some(v)),
        None if args.flag(name) => bail!("missing value for --{name}"),
        None => Ok(None),
    }
}

fn file_u64(file: Option<&SweepFile>, key: &'static str) -> Result<Option<u64>> {
    match file.and_then(|f| f.get(key)) {
        None => Ok(None),
        Some(v) => v
            .as_u64()
            .map(Some)
            .ok_or_else(|| anyhow!("{key} must be a non-negative integer")),
    }
}

/// Scalar CLI flag that overrides a Sweep-file key, with a final
/// default: CLI > file > `default`.
fn layered_u64(
    args: &Args,
    flag: &str,
    file: Option<&SweepFile>,
    key: &'static str,
    default: u64,
) -> Result<u64> {
    if args.flag(flag) {
        return args.try_parse(flag, default).map_err(|e| anyhow!(e));
    }
    Ok(file_u64(file, key)?.unwrap_or(default))
}

/// Resolve the layered sweep surface into one plan: CLI flags beat
/// Sweep-file keys beat defaults, per key.  `ds sweep` calls this with
/// its parsed arguments; [`SweepFile::to_plan`] calls it with empty
/// ones.
pub fn plan_from_cli(args: &Args, file: Option<&SweepFile>) -> Result<SweepPlan> {
    let cli_config = cli_str(args, "config")?;
    let cli_job = cli_str(args, "job")?;
    let cli_fleet = cli_str(args, "fleet")?;
    let cli_plate = cli_str(args, "plate")?;

    // Base config: CLI path > file CONFIG (path or inline) > defaults.
    let cfg = match cli_config {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            AppConfig::from_json(&text).context("parsing Config file")?
        }
        None => match file {
            Some(f) => file_or_inline(f, "CONFIG", |t| {
                AppConfig::from_json(t).map_err(Into::into)
            })?
            .unwrap_or_default(),
            None => AppConfig::default(),
        },
    };

    // Jobs: CLI path > file JOB > synthetic plate (whose shape layers
    // the same way: CLI --plate/--wells/--sites > file keys > defaults).
    // A known-but-dead knob must not silently run a different study
    // than the author believes: the synthetic-plate keys (and flags) do
    // nothing next to a real Job file.
    if cli_job.is_some() || file.is_some_and(|f| f.get("JOB").is_some()) {
        for (flag, key) in [("plate", "PLATE"), ("wells", "WELLS"), ("sites", "SITES")] {
            if args.flag(flag) {
                bail!("--{flag} has no effect when a Job file is given");
            }
            if file.is_some_and(|f| f.get(key).is_some()) {
                bail!("{key} has no effect when JOB is given — remove it or drop JOB");
            }
        }
    }
    let jobs = match cli_job {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            JobSpec::from_json(&text).context("parsing Job file")?
        }
        None => {
            let from_file = match file {
                Some(f) => file_or_inline(f, "JOB", |t| JobSpec::from_json(t).map_err(Into::into))?,
                None => None,
            };
            match from_file {
                Some(jobs) => jobs,
                None => {
                    let plate = match cli_plate {
                        Some(p) => p.to_string(),
                        None => match file.and_then(|f| f.get("PLATE")) {
                            Some(v) => v
                                .as_str()
                                .ok_or_else(|| anyhow!("PLATE must be a string"))?
                                .to_string(),
                            None => "P1".to_string(),
                        },
                    };
                    let wells = layered_u64(args, "wells", file, "WELLS", 24)?;
                    let sites = layered_u64(args, "sites", file, "SITES", 2)?;
                    JobSpec::plate(
                        &plate,
                        u32::try_from(wells).context("WELLS out of range")?,
                        u32::try_from(sites).context("SITES out of range")?,
                        vec![],
                    )
                }
            }
        }
    };

    // Fleet: CLI path > file FLEET > built-in template.
    let fleet = match cli_fleet {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            FleetSpec::from_json(&text).context("parsing Fleet file")?
        }
        None => {
            let from_file = match file {
                Some(f) => {
                    file_or_inline(f, "FLEET", |t| FleetSpec::from_json(t).map_err(Into::into))?
                }
                None => None,
            };
            match from_file {
                Some(fleet) => fleet,
                None => FleetSpec::template("us-east-1").expect("builtin fleet template"),
            }
        }
    };

    // Seeds: CLI --seeds/--seed-base > file SEEDS (count or explicit
    // array, with SEED_BASE) > 4 seeds from 0.
    let seed_base = layered_u64(args, "seed-base", file, "SEED_BASE", 0)?;
    let seeds: Vec<u64> = if args.flag("seeds") {
        let n = args.try_parse("seeds", 4u64).map_err(|e| anyhow!(e))?.max(1);
        (0..n).map(|i| seed_base + i).collect()
    } else {
        match file.and_then(|f| f.get("SEEDS")) {
            Some(Value::Arr(items)) => {
                ensure!(!items.is_empty(), "SEEDS must list at least one seed");
                // An explicit seed list makes SEED_BASE dead — reject it
                // rather than silently ignoring half the file.
                ensure!(
                    !args.flag("seed-base")
                        && !file.is_some_and(|f| f.get("SEED_BASE").is_some()),
                    "SEED_BASE has no effect with an explicit SEEDS list — use a SEEDS count"
                );
                items
                    .iter()
                    .map(|v| {
                        v.as_u64()
                            .ok_or_else(|| anyhow!("SEEDS must be non-negative integers"))
                    })
                    .collect::<Result<_>>()?
            }
            Some(v) => {
                let n = v
                    .as_u64()
                    .ok_or_else(|| anyhow!("SEEDS must be a count or an array of seeds"))?
                    .max(1);
                (0..n).map(|i| seed_base + i).collect()
            }
            None => (0..4).map(|i| seed_base + i).collect(),
        }
    };

    // Axes: defaults from the resolved config, then file keys, then CLI
    // flags — each layer only touching the axes it names.
    let mut matrix = ScenarioMatrix::defaults_from(&cfg);
    matrix.seeds = seeds;
    if let Some(f) = file {
        for ax in AXES {
            ax.parse_file(&f.value, &mut matrix)?;
        }
    }
    for ax in AXES {
        ax.parse_cli(args, &mut matrix)?;
    }

    let mut plan = SweepPlan {
        base_cfg: cfg,
        jobs,
        fleet,
        base_opts: Default::default(),
        matrix,
    };
    plan.fleet.on_demand_base = u32::try_from(layered_u64(
        args,
        "on-demand-base",
        file,
        "ON_DEMAND_BASE",
        u64::from(plan.fleet.on_demand_base),
    )?)
    .context("ON_DEMAND_BASE out of range")?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::Volatility;
    use crate::sim::MINUTE;

    fn cli(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn minimal_file_gets_cli_defaults() {
        let plan = SweepFile::from_text("{}").unwrap().to_plan().unwrap();
        assert_eq!(plan.matrix.seeds, vec![0, 1, 2, 3]);
        assert_eq!(plan.matrix.cluster_machines, vec![4]);
        assert_eq!(plan.jobs.groups.len(), 48); // 24 wells x 2 sites
    }

    #[test]
    fn file_keys_shape_the_matrix() {
        let f = SweepFile::from_text(
            r#"{
                "SEEDS": 2,
                "SEED_BASE": 10,
                "MACHINES": [2, 4],
                "VISIBILITY_S": [120, 600],
                "VOLATILITY": ["low", "high"],
                "JOB_MEAN_S": [45],
                "JOB_CV": 0.5,
                "WELLS": 2,
                "SITES": 1
            }"#,
        )
        .unwrap();
        let plan = f.to_plan().unwrap();
        assert_eq!(plan.matrix.seeds, vec![10, 11]);
        assert_eq!(plan.matrix.cluster_machines, vec![2, 4]);
        assert_eq!(plan.matrix.visibilities, vec![2 * MINUTE, 10 * MINUTE]);
        assert_eq!(
            plan.matrix.volatilities,
            vec![Volatility::Low, Volatility::High]
        );
        assert_eq!(plan.matrix.models.len(), 1);
        assert_eq!(plan.matrix.models[0].mean_s, 45.0);
        assert_eq!(plan.matrix.models[0].cv, 0.5);
        assert_eq!(plan.jobs.groups.len(), 2);
        assert_eq!(plan.matrix.scenarios().len(), 8);
    }

    #[test]
    fn cli_flags_override_file_keys() {
        let f = SweepFile::from_text(r#"{"MACHINES": [2, 4], "SEEDS": 8, "WELLS": 2, "SITES": 1}"#)
            .unwrap();
        let plan = plan_from_cli(&cli("sweep --machines 16 --seeds 2"), Some(&f)).unwrap();
        assert_eq!(plan.matrix.cluster_machines, vec![16]);
        assert_eq!(plan.matrix.seeds, vec![0, 1]);
        // Keys the CLI never named survive from the file.
        assert_eq!(plan.jobs.groups.len(), 2);
    }

    #[test]
    fn unknown_keys_rejected_against_the_registry() {
        let err = SweepFile::from_text(r#"{"MACHNIES": [2]}"#).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown key 'MACHNIES'"), "{msg}");
        assert!(msg.contains("MACHINES"), "the error lists valid keys: {msg}");
    }

    #[test]
    fn inline_config_and_explicit_seed_array() {
        let cfg = AppConfig {
            cluster_machines: 6,
            ..Default::default()
        };
        let text = Value::obj()
            .with("CONFIG", cfg.to_json())
            .with("SEEDS", Value::Arr(vec![Value::from(7u64), Value::from(9u64)]))
            .with("WELLS", 2u64)
            .with("SITES", 1u64)
            .pretty();
        let plan = SweepFile::from_text(&text).unwrap().to_plan().unwrap();
        assert_eq!(plan.base_cfg.cluster_machines, 6);
        // Machines default follows the inline config.
        assert_eq!(plan.matrix.cluster_machines, vec![6]);
        assert_eq!(plan.matrix.seeds, vec![7, 9]);
    }

    #[test]
    fn render_is_self_contained_and_round_trips() {
        let plan = SweepPlan::builder()
            .jobs(JobSpec::plate("P", 4, 2, vec![]))
            .seeds([3, 5])
            .machines([1, 2])
            .volatilities([Volatility::Medium])
            .input_mbs([0.0, 32.0])
            .build()
            .unwrap();
        let text = SweepFile::render(&plan);
        let back = SweepFile::from_text(&text).unwrap().to_plan().unwrap();
        assert_eq!(plan.base_cfg, back.base_cfg);
        assert_eq!(plan.jobs, back.jobs);
        assert_eq!(plan.fleet, back.fleet);
        assert_eq!(plan.matrix.seeds, back.matrix.seeds);
        let labels: Vec<String> = plan.matrix.scenarios().iter().map(|s| s.label()).collect();
        let back_labels: Vec<String> = back.matrix.scenarios().iter().map(|s| s.label()).collect();
        assert_eq!(labels, back_labels);
    }

    #[test]
    fn valueless_path_flags_are_rejected() {
        // `--job` with the path forgotten must not silently sweep the
        // default synthetic plate — same rule as every axis flag.
        for flag in ["config", "job", "fleet", "plate"] {
            let err = plan_from_cli(&cli(&format!("sweep --{flag} --seeds 2")), None).unwrap_err();
            assert!(
                format!("{err:#}").contains(&format!("missing value for --{flag}")),
                "--{flag}: {err:#}"
            );
        }
    }

    #[test]
    fn dead_keys_next_to_their_replacement_are_rejected() {
        // Synthetic-plate keys do nothing next to a real JOB; an
        // explicit SEEDS list makes SEED_BASE dead.  Both must error
        // instead of silently running a different study.
        let text = Value::obj()
            .with("JOB", JobSpec::plate("P", 2, 1, vec![]).to_json())
            .with("WELLS", 96u64)
            .pretty();
        let err = SweepFile::from_text(&text).unwrap().to_plan().unwrap_err();
        assert!(format!("{err:#}").contains("WELLS has no effect"), "{err:#}");

        let err = SweepFile::from_text(r#"{"SEEDS": [1, 2], "SEED_BASE": 5}"#)
            .unwrap()
            .to_plan()
            .unwrap_err();
        assert!(format!("{err:#}").contains("SEED_BASE has no effect"), "{err:#}");
    }

    #[test]
    fn bad_inline_value_reports_the_key() {
        let err = SweepFile::from_text(r#"{"CONFIG": 42}"#)
            .unwrap()
            .to_plan()
            .unwrap_err();
        assert!(format!("{err:#}").contains("CONFIG"), "{err:#}");
    }
}
