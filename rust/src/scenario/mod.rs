//! Scenario API v2 (DESIGN.md §5): the typed axis registry behind every
//! sweep surface.
//!
//! The paper's whole pitch is configuration through a handful of
//! human-readable files; this module keeps the *experiment* surface
//! honest the same way.  Every sweep axis — machines, visibility,
//! volatility, duration model, allocation strategy, instance set, input
//! MB, net profile, scaling policy, scaling target, workflow, sharing
//! mode, topology, placement, traffic, queueing — is one [`Axis`]
//! implementation declaring its CLI
//! flag(s), its Sweep-file key, its per-cell config/fleet/job overlay,
//! its label fragment, and its JSON identity.  The registry ([`AXES`])
//! is the single source of truth: `ds sweep --help`, the strict
//! unknown-flag rejection, the Sweep-file schema, scenario labels, and
//! the report's per-scenario `axes` object are all generated from it,
//! so adding an axis touches exactly this module (plus the knob it
//! drives) instead of seven call sites.
//!
//! Three front doors build the same [`SweepPlan`], and
//! [`run_sweep`](crate::coordinator::sweep::run_sweep) executes it:
//!
//! * **CLI flags** — `ds sweep --machines 2,4 --volatility low,high`
//! * **Sweep file** — a fourth paper-style `KEY value` JSON file
//!   ([`SweepFile`]): `ds sweep --plan sweep.json`, with CLI flags
//!   overriding file keys
//! * **Builder** — [`SweepPlan::builder`] for library users
//!
//! ```
//! use ds_rs::config::JobSpec;
//! use ds_rs::coordinator::sweep::SweepPlan;
//!
//! let plan = SweepPlan::builder()
//!     .jobs(JobSpec::plate("P", 2, 1, vec![]))
//!     .machines([1, 2])
//!     .seeds([1, 2])
//!     .build()
//!     .unwrap();
//! assert_eq!(plan.matrix.cell_count(), 4);
//! ```

pub mod axis;
pub mod builder;
pub mod file;

pub use axis::{
    describe_matrix, render_flag_specs, render_matrix_entries, run_flags, sweep_flags, Axis,
    FlagSpec, AXES,
};
pub use builder::SweepPlanBuilder;
pub use file::{plan_from_cli, SweepFile};

use crate::aws::ec2::{AllocationStrategy, InstanceSlot, Volatility};
use crate::aws::s3::dataplane::NetProfile;
use crate::config::{AppConfig, FleetSpec, JobSpec};
use crate::coordinator::autoscale::ScalingMode;
use crate::coordinator::run::RunOptions;
use crate::json::Value;
use crate::sim::{SimTime, MINUTE};
use crate::topology::{ClusterTopology, Placement};
use crate::traffic::{QueueingPolicy, TrafficSpec};
use crate::workflow::{SharingMode, WorkflowSpec};
use crate::workloads::DurationModel;

/// Stable display name for a volatility level.
pub fn volatility_name(v: Volatility) -> &'static str {
    match v {
        Volatility::Low => "low",
        Volatility::Medium => "medium",
        Volatility::High => "high",
    }
}

/// One point in the configuration matrix.  Seeds are *not* part of a
/// scenario: they replicate it, and aggregation reduces across them.
///
/// Every field is owned by exactly one [`Axis`] in [`AXES`]; the axis,
/// not the scenario, knows how to overlay the field onto a cell, label
/// it, and render it as JSON.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub volatility: Volatility,
    /// `SQS_MESSAGE_VISIBILITY` for this cell's config.
    pub visibility: SimTime,
    /// `CLUSTER_MACHINES` for this cell's config (weighted units).
    pub machines: u32,
    /// `ALLOCATION_STRATEGY` for this cell's fleet.
    pub allocation: AllocationStrategy,
    /// `INSTANCE_TYPES` for this cell's fleet; empty inherits the plan's
    /// fleet file / Config.
    pub instance_set: Vec<InstanceSlot>,
    /// Mean input MB per job; 0 leaves the plan's Job file untouched
    /// (zero-data cells take the pre-data-plane path).
    pub input_mb: f64,
    /// Network profile for this cell's data plane.
    pub net: NetProfile,
    /// Autoscaling policy mode for this cell's monitor
    /// ([`ScalingMode::None`] = the paper's fixed fleet).
    pub scaling: ScalingMode,
    /// Target backlog (visible + in-flight jobs) per capacity unit for
    /// the scaling policy; ignored when `scaling` is `None`.
    pub scaling_target: f64,
    pub model: DurationModel,
    /// DAG workflow replacing the flat job list; `None` = flat
    /// submission of the plan's Job file.
    pub workflow: Option<WorkflowSpec>,
    /// Where workflow artifacts live ([`SharingMode::S3Staging`] is the
    /// paper's bucket-staging baseline); ignored for flat cells.
    pub sharing: SharingMode,
    /// Failure-domain layout for this cell; `None` = the legacy
    /// single-domain world.
    pub topology: Option<ClusterTopology>,
    /// How the fleet spreads capacity across the topology's domains
    /// ([`Placement::Pack`] is the neutral default); ignored for
    /// single-domain cells.
    pub placement: Placement,
    /// Multi-tenant open-loop traffic replacing the flat job list;
    /// `None` = the legacy single-submitter world.
    pub traffic: Option<TrafficSpec>,
    /// How the coordinator arbitrates tenants at the queue head
    /// ([`QueueingPolicy::Fifo`] is the paper's baseline); ignored for
    /// single-tenant cells.
    pub queueing: QueueingPolicy,
}

impl Scenario {
    /// Stable human-readable label (also the aggregation key in
    /// reports), assembled from each axis's registry-declared fragment.
    /// Axes follow the only-label-when-used rule, so historical labels
    /// stay byte-stable as new axes land.
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        for ax in AXES {
            if let Some(fragment) = ax.label(self) {
                parts.push(fragment);
            }
        }
        parts.join(" ")
    }

    /// The scenario's coordinates as a JSON object keyed by the axes'
    /// Sweep-file keys (same only-when-used rule as [`Self::label`]) —
    /// what `metrics::aggregate` attaches to each `ScenarioSummary` so
    /// downstream tooling never parses labels.
    pub fn axis_json(&self) -> Value {
        let mut obj = Value::obj();
        for ax in AXES {
            if let Some(v) = ax.json_value(self) {
                obj = obj.with(ax.key(), v);
            }
        }
        obj
    }

    /// One cell's fully-overlaid inputs: the base config, fleet file,
    /// and run options with every axis's value applied (the sweep
    /// path).  The caller still owns the seed and the Job file overlay
    /// (see `coordinator::sweep::run_cell`).
    pub fn cell_inputs(
        &self,
        base_cfg: &AppConfig,
        base_fleet: &FleetSpec,
        base_opts: &RunOptions,
    ) -> CellInputs {
        self.overlaid(base_cfg, base_fleet, base_opts, |_| true)
    }

    /// Like [`Self::cell_inputs`] but applying only the axes `ds run`
    /// exposes ([`Axis::in_run`]): a single run's machines, visibility,
    /// allocation strategy, and instance set come from its Config and
    /// Fleet files, never from axis defaults.
    pub fn run_inputs(
        &self,
        base_cfg: &AppConfig,
        base_fleet: &FleetSpec,
        base_opts: &RunOptions,
    ) -> CellInputs {
        self.overlaid(base_cfg, base_fleet, base_opts, |ax| ax.in_run())
    }

    fn overlaid(
        &self,
        base_cfg: &AppConfig,
        base_fleet: &FleetSpec,
        base_opts: &RunOptions,
        want: impl Fn(&dyn Axis) -> bool,
    ) -> CellInputs {
        // Every field an axis owns starts at its base/neutral value —
        // the axis overlay (filtered by `want`) is the only writer, so
        // `run_inputs` excluding an axis really does exclude it.
        let mut cell = CellInputs {
            cfg: base_cfg.clone(),
            fleet: base_fleet.clone(),
            opts: base_opts.clone(),
            model: DurationModel::default(),
            input_mb: 0.0,
        };
        for ax in AXES {
            if want(*ax) {
                ax.overlay(self, &mut cell);
            }
        }
        cell
    }
}

/// One `(scenario, seed)` cell's inputs after every axis overlay: what
/// `run_full` consumes, minus the Job file (whose data-shape overlay
/// needs the seed).
#[derive(Debug, Clone)]
pub struct CellInputs {
    pub cfg: AppConfig,
    pub fleet: FleetSpec,
    pub opts: RunOptions,
    /// The cell's modeled duration distribution.
    pub model: DurationModel,
    /// Mean input MB overlaid on the Job file (0 = untouched).
    pub input_mb: f64,
}

/// Axes of the sweep: the scenario list is their cartesian product.
/// Each field is owned by one [`Axis`] in [`AXES`], which parses it
/// from the CLI and the Sweep file and renders it back.
#[derive(Debug, Clone)]
pub struct ScenarioMatrix {
    /// Replicate seeds applied to every scenario.
    pub seeds: Vec<u64>,
    pub volatilities: Vec<Volatility>,
    pub visibilities: Vec<SimTime>,
    pub cluster_machines: Vec<u32>,
    /// Fleet allocation strategies to compare.
    pub allocations: Vec<AllocationStrategy>,
    /// Instance sets to compare; an empty set inherits the plan's fleet
    /// file / Config types.
    pub instance_sets: Vec<Vec<InstanceSlot>>,
    /// Mean input MB per job (`--input-mb`); 0 = no data plane.
    pub input_mbs: Vec<f64>,
    /// Network profiles (`--net-profile`).
    pub net_profiles: Vec<NetProfile>,
    /// Autoscaling policy modes (`--scaling`); `None` = fixed fleet.
    pub scalings: Vec<ScalingMode>,
    /// Backlog-per-unit targets for the scaling policy
    /// (`--scaling-target`).
    pub scaling_targets: Vec<f64>,
    pub models: Vec<DurationModel>,
    /// DAG workflows (`--workflow`); `None` = flat submission.
    pub workflows: Vec<Option<WorkflowSpec>>,
    /// Artifact sharing modes (`--sharing`).
    pub sharings: Vec<SharingMode>,
    /// Failure-domain layouts (`--topology`); `None` = single-domain.
    pub topologies: Vec<Option<ClusterTopology>>,
    /// Placement policies (`--placement`).
    pub placements: Vec<Placement>,
    /// Multi-tenant traffic specs (`--traffic`); `None` = single
    /// submitter.
    pub traffics: Vec<Option<TrafficSpec>>,
    /// Queueing policies (`--queueing`).
    pub queueings: Vec<QueueingPolicy>,
}

impl Default for ScenarioMatrix {
    fn default() -> Self {
        Self {
            seeds: vec![1],
            volatilities: vec![Volatility::Low],
            visibilities: vec![10 * MINUTE],
            cluster_machines: vec![4],
            allocations: vec![AllocationStrategy::LowestPrice],
            instance_sets: vec![Vec::new()],
            input_mbs: vec![0.0],
            net_profiles: vec![NetProfile::default()],
            scalings: vec![ScalingMode::None],
            scaling_targets: vec![crate::coordinator::autoscale::DEFAULT_TARGET_PER_UNIT],
            models: vec![DurationModel::default()],
            workflows: vec![None],
            sharings: vec![SharingMode::S3Staging],
            topologies: vec![None],
            placements: vec![Placement::Pack],
            traffics: vec![None],
            queueings: vec![QueueingPolicy::Fifo],
        }
    }
}

impl ScenarioMatrix {
    /// The matrix every front door starts from: single-valued axes, with
    /// machines and visibility inheriting the base config (they are the
    /// two axes the Config file carries).
    pub fn defaults_from(cfg: &AppConfig) -> Self {
        Self {
            cluster_machines: vec![cfg.cluster_machines],
            visibilities: vec![cfg.sqs_message_visibility],
            ..Default::default()
        }
    }

    /// Expand the cartesian product in a fixed order: machines outermost,
    /// then visibility, volatility, allocation strategy, instance set,
    /// input MB, net profile, scaling mode, scaling target, duration
    /// model, workflow, sharing mode, topology, placement, traffic
    /// spec, and innermost the queueing policy.  Axis element order is
    /// preserved, so
    /// single-axis sweeps read like the input list.  (This expansion
    /// order is pinned by historical reports; the registry's order is
    /// the *label* order, which differs only in where the duration
    /// model sits.)
    pub fn scenarios(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.scenario_count());
        for &machines in &self.cluster_machines {
            for &visibility in &self.visibilities {
                for &volatility in &self.volatilities {
                    for &allocation in &self.allocations {
                        for instance_set in &self.instance_sets {
                            for &input_mb in &self.input_mbs {
                                for net in &self.net_profiles {
                                    for &scaling in &self.scalings {
                                        for &scaling_target in &self.scaling_targets {
                                            for model in &self.models {
                                                for workflow in &self.workflows {
                                                    for &sharing in &self.sharings {
                                                        for topology in &self.topologies {
                                                            for &placement in &self.placements {
                                                                for traffic in &self.traffics {
                                                                    for &queueing in
                                                                        &self.queueings
                                                                    {
                                                                        out.push(Scenario {
                                                                            volatility,
                                                                            visibility,
                                                                            machines,
                                                                            allocation,
                                                                            instance_set:
                                                                                instance_set
                                                                                    .clone(),
                                                                            input_mb,
                                                                            net: net.clone(),
                                                                            scaling,
                                                                            scaling_target,
                                                                            model: model.clone(),
                                                                            workflow: workflow
                                                                                .clone(),
                                                                            sharing,
                                                                            topology: topology
                                                                                .clone(),
                                                                            placement,
                                                                            traffic: traffic
                                                                                .clone(),
                                                                            queueing,
                                                                        });
                                                                    }
                                                                }
                                                            }
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Scenarios the matrix will expand to, computed from the
    /// registry's per-axis lengths *without* materializing the product
    /// — what lets `--dry-run` size an absurdly large matrix without
    /// allocating it.  Saturates at `usize::MAX`.
    pub fn scenario_count(&self) -> usize {
        AXES.iter()
            .map(|ax| ax.len(self))
            .fold(1, usize::saturating_mul)
    }

    /// Total cells the sweep will run (scenarios × seeds), computed
    /// without expanding the matrix.
    pub fn cell_count(&self) -> usize {
        self.scenario_count().saturating_mul(self.seeds.len())
    }
}

/// Everything a sweep needs besides the matrix: the base config the
/// scenario knobs are overlaid on, the job list every cell replays, the
/// fleet file, and the base run options (seed and volatility are
/// overridden per cell).
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub base_cfg: AppConfig,
    pub jobs: JobSpec,
    pub fleet: FleetSpec,
    pub base_opts: RunOptions,
    pub matrix: ScenarioMatrix,
}

impl SweepPlan {
    /// Plan over the built-in us-east-1 template fleet with default run
    /// options.
    pub fn new(base_cfg: AppConfig, jobs: JobSpec, matrix: ScenarioMatrix) -> Self {
        Self {
            base_cfg,
            jobs,
            fleet: FleetSpec::template("us-east-1").expect("builtin fleet template"),
            base_opts: RunOptions::default(),
            matrix,
        }
    }

    /// Fluent construction for library users (see [`SweepPlanBuilder`]).
    pub fn builder() -> SweepPlanBuilder {
        SweepPlanBuilder::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_assembles_in_registry_order() {
        let mut sc = Scenario {
            volatility: Volatility::Medium,
            visibility: 5 * MINUTE,
            machines: 8,
            allocation: AllocationStrategy::Diversified,
            instance_set: Vec::new(),
            input_mb: 0.0,
            net: NetProfile::default(),
            scaling: ScalingMode::None,
            scaling_target: 4.0,
            model: DurationModel {
                mean_s: 120.0,
                ..Default::default()
            },
            workflow: None,
            sharing: SharingMode::S3Staging,
            topology: None,
            placement: Placement::Pack,
            traffic: None,
            queueing: QueueingPolicy::Fifo,
        };
        assert_eq!(sc.label(), "m=8 vis=5.0m vol=medium mean=120s alloc=diversified");
        sc.input_mb = 64.0;
        sc.net = NetProfile::narrow();
        assert_eq!(
            sc.label(),
            "m=8 vis=5.0m vol=medium mean=120s alloc=diversified in=64MB net=narrow"
        );
        // Workflow and sharing fragments trail the registry (and stay
        // out of flat labels entirely — asserted above).
        sc.workflow = Some(crate::workloads::dag::diamond());
        sc.sharing = SharingMode::NodeLocal;
        assert_eq!(
            sc.label(),
            "m=8 vis=5.0m vol=medium mean=120s alloc=diversified in=64MB net=narrow \
             wf=diamond share=node-local"
        );
        // Topology and placement trail everything, same
        // only-label-when-used rule.
        sc.topology = ClusterTopology::shape("two-region");
        sc.placement = Placement::Spread;
        assert_eq!(
            sc.label(),
            "m=8 vis=5.0m vol=medium mean=120s alloc=diversified in=64MB net=narrow \
             wf=diamond share=node-local topo=two-region place=spread"
        );
        // Traffic and queueing trail everything, same rule again.
        sc.traffic = TrafficSpec::shape("two-tenant");
        sc.queueing = QueueingPolicy::FairShare;
        assert_eq!(
            sc.label(),
            "m=8 vis=5.0m vol=medium mean=120s alloc=diversified in=64MB net=narrow \
             wf=diamond share=node-local topo=two-region place=spread \
             traffic=two-tenant queue=fair-share"
        );
    }

    #[test]
    fn axis_json_mirrors_the_label_rule() {
        let mut sc = ScenarioMatrix::default().scenarios().remove(0);
        let j = sc.axis_json();
        assert_eq!(j.get("MACHINES").and_then(Value::as_u64), Some(4));
        assert_eq!(j.get("VOLATILITY").and_then(Value::as_str), Some("low"));
        // Unused optional axes stay out of the JSON, like the label.
        assert!(j.get("INPUT_MB").is_none());
        assert!(j.get("NET_PROFILE").is_none());
        assert!(j.get("INSTANCE_TYPES").is_none());
        sc.input_mb = 32.0;
        sc.net = NetProfile::narrow();
        sc.instance_set = vec![InstanceSlot::new("m5.large")];
        let j = sc.axis_json();
        assert_eq!(j.get("INPUT_MB").and_then(Value::as_f64), Some(32.0));
        assert_eq!(j.get("NET_PROFILE").and_then(Value::as_str), Some("narrow"));
        assert_eq!(
            j.get("INSTANCE_TYPES").and_then(Value::as_arr).map(<[Value]>::len),
            Some(1)
        );
    }

    #[test]
    fn run_inputs_leave_fleet_shaping_to_the_files() {
        // `ds run` must not let axis *defaults* clobber the Fleet file:
        // a diversified fleet stays diversified through run_inputs.
        let cfg = AppConfig::default();
        let mut fleet = FleetSpec::template("us-east-1").unwrap();
        fleet.allocation_strategy = AllocationStrategy::Diversified;
        fleet.instance_types = vec![InstanceSlot::new("m5.large")];
        let sc = ScenarioMatrix::defaults_from(&cfg).scenarios().remove(0);
        let cell = sc.run_inputs(&cfg, &fleet, &RunOptions::default());
        assert_eq!(cell.fleet.allocation_strategy, AllocationStrategy::Diversified);
        assert_eq!(cell.fleet.instance_types.len(), 1);
        // The sweep path, by contrast, owns those axes.
        let cell = sc.cell_inputs(&cfg, &fleet, &RunOptions::default());
        assert_eq!(cell.fleet.allocation_strategy, AllocationStrategy::LowestPrice);
    }
}
