//! The typed axis registry: one [`Axis`] impl per sweep axis, and the
//! flag tables every sweep surface is generated from.
//!
//! An axis owns its whole vertical slice — CLI flag(s) + parser,
//! Sweep-file key + parser + renderer, per-cell overlay, label
//! fragment, and JSON identity — so adding an axis is one impl plus one
//! entry in [`AXES`].  The registry order is the **label order**
//! (machines, visibility, volatility, duration, allocation, instance
//! set, input MB, net profile, scaling, scaling target, workflow,
//! sharing, topology, placement, traffic, queueing), chosen so
//! registry-assembled labels are
//! byte-identical to the historical hand-formatted ones; the cartesian
//! *expansion* order lives in
//! [`ScenarioMatrix::scenarios`](super::ScenarioMatrix::scenarios).
//!
//! `ds sweep --help`, the strict unknown-flag rejection, and the
//! Sweep-file schema are all projections of [`sweep_flags`]; the
//! consistency test in `rust/tests/scenario_api.rs` pins that nothing
//! else defines them.

use anyhow::{anyhow, bail, ensure, Result};

use crate::aws::ec2::{AllocationStrategy, InstanceSlot, Volatility};
use crate::aws::s3::dataplane::NetProfile;
use crate::coordinator::autoscale::{ScalingMode, DEFAULT_TARGET_PER_UNIT};
use crate::cli::Args;
use crate::json::Value;
use crate::sim::clock::{fmt_dur, from_secs_f64};
use crate::topology::{ClusterTopology, Placement};
use crate::traffic::{QueueingPolicy, TrafficSpec};
use crate::workflow::{SharingMode, WorkflowSpec};
use crate::workloads::DurationModel;

use super::{volatility_name, CellInputs, Scenario, ScenarioMatrix};

/// One documented command-line flag: name, value placeholder (empty =
/// boolean), help text, and the Sweep-file key it corresponds to
/// (`None` = CLI-only, never a file key).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlagSpec {
    pub flag: &'static str,
    pub value: &'static str,
    pub help: &'static str,
    pub file_key: Option<&'static str>,
}

/// One sweep axis: a typed slice through every layer of the scenario
/// surface.  All methods read/write the axis's own fields of
/// [`ScenarioMatrix`] / [`Scenario`] / [`CellInputs`] and nothing else.
pub trait Axis: Sync {
    /// Primary Sweep-file key (also the scenario-JSON key).
    fn key(&self) -> &'static str;
    /// CLI flags this axis owns (the first is the axis list flag;
    /// extras, like the duration model's scalar knobs, follow).
    fn flags(&self) -> &'static [FlagSpec];
    /// Whether `ds run` exposes this axis.  Fleet- and Config-file-owned
    /// axes (machines, visibility, allocation, instance set) are
    /// sweep-only: a single run reads them from its files.
    fn in_run(&self) -> bool {
        true
    }
    /// Values this axis contributes to the cartesian product.
    fn len(&self, m: &ScenarioMatrix) -> usize;
    /// Human-readable rendering of the axis values (`--dry-run`).
    fn describe(&self, m: &ScenarioMatrix) -> String;
    /// Overlay CLI flags onto the matrix (absent flags leave it as-is,
    /// so file keys and defaults show through).
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()>;
    /// Overlay this axis's Sweep-file keys onto the matrix (absent keys
    /// leave it as-is).
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()>;
    /// Render the matrix's values for this axis as Sweep-file keys.
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)>;
    /// Overlay one scenario's value for this axis onto a cell's inputs.
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs);
    /// Label fragment for `sc`; `None` when the axis is unused (the
    /// only-label-when-used rule keeps historical labels byte-stable).
    fn label(&self, sc: &Scenario) -> Option<String>;
    /// JSON value of the scenario's coordinate on this axis (same
    /// only-when-used rule as [`Self::label`]).
    fn json_value(&self, sc: &Scenario) -> Option<Value>;
}

/// The registry, in label order.  Everything that enumerates axes —
/// help text, Sweep-file schema, labels, scenario JSON, overlays —
/// walks this slice.
pub static AXES: &[&dyn Axis] = &[
    &MachinesAxis,
    &VisibilityAxis,
    &VolatilityAxis,
    &DurationAxis,
    &AllocationAxis,
    &InstanceSetAxis,
    &InputMbAxis,
    &NetProfileAxis,
    &ScalingAxis,
    &ScalingTargetAxis,
    &WorkflowAxis,
    &SharingAxis,
    &TopologyAxis,
    &PlacementAxis,
    &TrafficAxis,
    &QueueingAxis,
];

// ---------------------------------------------------------------------------
// Shared parsing helpers
// ---------------------------------------------------------------------------

/// Strict string-list flag: absent -> `None`; present with no value or
/// only separators -> error (a forgotten value must never run a
/// different study than asked for).  `String: FromStr` is infallible,
/// so this is exactly [`Args::try_parse_list`]'s contract — one
/// implementation of strictness, not two.
fn cli_list(args: &Args, name: &str) -> Result<Option<Vec<String>>> {
    cli_typed_list::<String>(args, name)
}

/// Strict typed-list flag via [`Args::try_parse_list`].
fn cli_typed_list<T: std::str::FromStr>(args: &Args, name: &str) -> Result<Option<Vec<T>>> {
    args.try_parse_list(name).map_err(|e| anyhow!(e))
}

/// A Sweep-file axis value's items: an array, or a bare scalar treated
/// as a one-element axis.
fn file_items(v: &Value) -> Vec<&Value> {
    match v.as_arr() {
        Some(items) => items.iter().collect(),
        None => vec![v],
    }
}

/// Non-empty items of a Sweep-file axis value.
fn file_list(file: &Value, key: &'static str) -> Result<Option<Vec<&Value>>> {
    let Some(v) = file.get(key) else {
        return Ok(None);
    };
    let items = file_items(v);
    ensure!(!items.is_empty(), "{key} must list at least one value");
    Ok(Some(items))
}

fn item_f64(v: &Value, key: &'static str) -> Result<f64> {
    v.as_f64()
        .ok_or_else(|| anyhow!("bad value for {key} (expected a number)"))
}

fn item_u32(v: &Value, key: &'static str) -> Result<u32> {
    v.as_u64()
        .and_then(|n| u32::try_from(n).ok())
        .ok_or_else(|| anyhow!("bad value for {key} (expected a non-negative integer)"))
}

fn item_str<'v>(v: &'v Value, key: &'static str) -> Result<&'v str> {
    v.as_str()
        .ok_or_else(|| anyhow!("bad value for {key} (expected a string)"))
}

fn num_arr<I: Into<Value>>(items: impl IntoIterator<Item = I>) -> Value {
    Value::Arr(items.into_iter().map(Into::into).collect())
}

fn join<T: std::fmt::Display>(items: impl IntoIterator<Item = T>) -> String {
    items
        .into_iter()
        .map(|i| i.to_string())
        .collect::<Vec<_>>()
        .join(", ")
}

/// Parse a volatility level name.
pub fn parse_volatility(s: &str) -> Result<Volatility> {
    Ok(match s {
        "low" => Volatility::Low,
        "medium" => Volatility::Medium,
        "high" => Volatility::High,
        other => bail!("volatility must be low|medium|high, got '{other}'"),
    })
}

/// Parse a network profile name.
pub fn parse_net_profile(s: &str) -> Result<NetProfile> {
    NetProfile::parse(s)
        .ok_or_else(|| anyhow!("net-profile must be wide|standard|narrow, got '{s}'"))
}

/// Parse a scaling mode name.
pub fn parse_scaling(s: &str) -> Result<ScalingMode> {
    ScalingMode::parse(s)
        .ok_or_else(|| anyhow!("scaling must be none|target-tracking|step, got '{s}'"))
}

/// Parse an allocation strategy name.
pub fn parse_allocation(s: &str) -> Result<AllocationStrategy> {
    AllocationStrategy::parse(s).ok_or_else(|| {
        anyhow!("allocation must be lowest-price|diversified|capacity-optimized, got '{s}'")
    })
}

/// Parse one instance set: types '+'-joined, each `name[:weight]`
/// (e.g. `m5.large+c5.xlarge:2`).  Empty means "inherit the plan's
/// fleet file / Config types".
pub fn parse_instance_set(s: &str) -> Result<Vec<InstanceSlot>> {
    s.split('+')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| InstanceSlot::parse(t).map_err(|e| anyhow!(e)))
        .collect()
}

/// Render one instance set in the same `a+b:2` grammar ("" = inherit).
pub fn render_instance_set(set: &[InstanceSlot]) -> String {
    set.iter().map(InstanceSlot::render).collect::<Vec<_>>().join("+")
}

/// Whether every model shares the first one's shape knobs (cv, stall,
/// fail) — the predicate that picks the scalar-keys Sweep-file form and
/// the compact `--dry-run` description.
fn models_homogeneous(models: &[DurationModel]) -> bool {
    let proto = models.first().cloned().unwrap_or_default();
    models.iter().all(|mdl| {
        mdl.cv == proto.cv
            && mdl.stall_prob == proto.stall_prob
            && mdl.fail_prob == proto.fail_prob
    })
}

// ---------------------------------------------------------------------------
// The axes
// ---------------------------------------------------------------------------

/// `CLUSTER_MACHINES` (weighted units) — `--machines` / `MACHINES`.
pub struct MachinesAxis;

impl Axis for MachinesAxis {
    fn key(&self) -> &'static str {
        "MACHINES"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "machines",
            value: "N,N,..",
            help: "CLUSTER_MACHINES axis (weighted units)",
            file_key: Some("MACHINES"),
        }]
    }
    fn in_run(&self) -> bool {
        false
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.cluster_machines.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(&m.cluster_machines)
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(machines) = cli_typed_list::<u32>(args, "machines")? {
            m.cluster_machines = machines;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "MACHINES")? {
            m.cluster_machines = items
                .iter()
                .map(|v| item_u32(v, "MACHINES"))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![("MACHINES", num_arr(m.cluster_machines.iter().copied()))]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.cfg.cluster_machines = sc.machines;
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        Some(format!("m={}", sc.machines))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        Some(Value::from(sc.machines))
    }
}

/// `SQS_MESSAGE_VISIBILITY` — `--visibility-s` / `VISIBILITY_S`
/// (seconds in both surfaces, milliseconds internally).
pub struct VisibilityAxis;

impl Axis for VisibilityAxis {
    fn key(&self) -> &'static str {
        "VISIBILITY_S"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "visibility-s",
            value: "S,S,..",
            help: "SQS_MESSAGE_VISIBILITY axis, seconds",
            file_key: Some("VISIBILITY_S"),
        }]
    }
    fn in_run(&self) -> bool {
        false
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.visibilities.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(m.visibilities.iter().map(|&v| fmt_dur(v)))
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(secs) = cli_typed_list::<f64>(args, "visibility-s")? {
            m.visibilities = secs.into_iter().map(from_secs_f64).collect();
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "VISIBILITY_S")? {
            m.visibilities = items
                .iter()
                .map(|v| item_f64(v, "VISIBILITY_S").map(from_secs_f64))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "VISIBILITY_S",
            num_arr(m.visibilities.iter().map(|&v| v as f64 / 1000.0)),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.cfg.sqs_message_visibility = sc.visibility;
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        Some(format!("vis={}", fmt_dur(sc.visibility)))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        Some(Value::from(sc.visibility as f64 / 1000.0))
    }
}

/// Spot-market volatility — `--volatility` / `VOLATILITY`.
pub struct VolatilityAxis;

impl Axis for VolatilityAxis {
    fn key(&self) -> &'static str {
        "VOLATILITY"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "volatility",
            value: "V,V,..",
            help: "market axis: low|medium|high",
            file_key: Some("VOLATILITY"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.volatilities.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(m.volatilities.iter().map(|&v| volatility_name(v)))
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "volatility")? {
            m.volatilities = items
                .iter()
                .map(|s| parse_volatility(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "VOLATILITY")? {
            m.volatilities = items
                .iter()
                .map(|v| item_str(v, "VOLATILITY").and_then(parse_volatility))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "VOLATILITY",
            Value::Arr(
                m.volatilities
                    .iter()
                    .map(|&v| Value::from(volatility_name(v)))
                    .collect(),
            ),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.opts.volatility = sc.volatility;
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        Some(format!("vol={}", volatility_name(sc.volatility)))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        Some(Value::from(volatility_name(sc.volatility)))
    }
}

/// Modeled duration distribution — the mean axis `--job-mean-s` /
/// `JOB_MEAN_S`, plus the scalar shape knobs `--job-cv`, `--stall-prob`,
/// `--fail-prob` (`JOB_CV` / `STALL_PROB` / `FAIL_PROB`) applied to
/// every mean.  A `JOB_MEAN_S` file item may also be a full object
/// (`{"MEAN_S": .., "CV": .., "STALL_PROB": .., "FAIL_PROB": ..}`) for
/// heterogeneous models, which is how builder plans round-trip.  A
/// file-level scalar next to object entries is rejected (it would
/// silently clobber their spelled-out shapes); CLI scalar flags still
/// override either form — CLI-over-file is the documented layering.
pub struct DurationAxis;

impl Axis for DurationAxis {
    fn key(&self) -> &'static str {
        "JOB_MEAN_S"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[
            FlagSpec {
                flag: "job-mean-s",
                value: "S,S,..",
                help: "modeled mean job duration axis, seconds (default 90)",
                file_key: Some("JOB_MEAN_S"),
            },
            FlagSpec {
                flag: "job-cv",
                value: "X",
                help: "duration coefficient of variation (default 0.3)",
                file_key: Some("JOB_CV"),
            },
            FlagSpec {
                flag: "stall-prob",
                value: "P",
                help: "per-job stall probability (default 0)",
                file_key: Some("STALL_PROB"),
            },
            FlagSpec {
                flag: "fail-prob",
                value: "P",
                help: "per-job fast-failure probability (default 0)",
                file_key: Some("FAIL_PROB"),
            },
        ]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.models.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        let proto = m.models.first().cloned().unwrap_or_default();
        if models_homogeneous(&m.models) {
            let means = join(m.models.iter().map(|mdl| format!("{:.0}s", mdl.mean_s)));
            format!(
                "{means} (cv {:.2}, stall {:.2}, fail {:.2})",
                proto.cv, proto.stall_prob, proto.fail_prob
            )
        } else {
            // Heterogeneous models: show each model's own shape so a
            // --dry-run never misrepresents the matrix.
            join(m.models.iter().map(|mdl| {
                format!(
                    "{:.0}s(cv {:.2}, stall {:.2}, fail {:.2})",
                    mdl.mean_s, mdl.cv, mdl.stall_prob, mdl.fail_prob
                )
            }))
        }
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(means) = cli_typed_list::<f64>(args, "job-mean-s")? {
            let proto = m.models.first().cloned().unwrap_or_default();
            m.models = means
                .into_iter()
                .map(|mean_s| DurationModel {
                    mean_s,
                    ..proto.clone()
                })
                .collect();
        }
        let scalars: [(&str, fn(&mut DurationModel, f64)); 3] = [
            ("job-cv", |mdl, x| mdl.cv = x),
            ("stall-prob", |mdl, x| mdl.stall_prob = x),
            ("fail-prob", |mdl, x| mdl.fail_prob = x),
        ];
        for (flag, set) in scalars {
            if args.flag(flag) {
                let x = args.try_parse(flag, 0.0f64).map_err(|e| anyhow!(e))?;
                for mdl in &mut m.models {
                    set(mdl, x);
                }
            }
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        let mut object_form = false;
        if let Some(items) = file_list(file, "JOB_MEAN_S")? {
            object_form = items.iter().any(|v| v.as_f64().is_none());
            let proto = m.models.first().cloned().unwrap_or_default();
            m.models = items
                .iter()
                .map(|v| match v.as_f64() {
                    Some(mean_s) => Ok(DurationModel {
                        mean_s,
                        ..proto.clone()
                    }),
                    None => {
                        // Object entries are as strict as the top-level
                        // schema: unknown inner keys and non-numeric
                        // values must not silently fall back to
                        // defaults.
                        let fields = v.as_obj().ok_or_else(|| {
                            anyhow!("JOB_MEAN_S items must be numbers or objects with MEAN_S")
                        })?;
                        for (k, _) in fields {
                            ensure!(
                                matches!(k.as_str(), "MEAN_S" | "CV" | "STALL_PROB" | "FAIL_PROB"),
                                "unknown key '{k}' in JOB_MEAN_S object (valid: MEAN_S, CV, STALL_PROB, FAIL_PROB)"
                            );
                        }
                        let field = |key: &'static str, default: f64| -> Result<f64> {
                            match v.get(key) {
                                None => Ok(default),
                                Some(x) => item_f64(x, key),
                            }
                        };
                        let mean_s = item_f64(
                            v.get("MEAN_S").ok_or_else(|| {
                                anyhow!("JOB_MEAN_S object missing MEAN_S")
                            })?,
                            "MEAN_S",
                        )?;
                        Ok(DurationModel {
                            mean_s,
                            cv: field("CV", proto.cv)?,
                            stall_prob: field("STALL_PROB", proto.stall_prob)?,
                            fail_prob: field("FAIL_PROB", proto.fail_prob)?,
                        })
                    }
                })
                .collect::<Result<_>>()?;
        }
        let scalars: [(&'static str, fn(&mut DurationModel, f64)); 3] = [
            ("JOB_CV", |mdl, x| mdl.cv = x),
            ("STALL_PROB", |mdl, x| mdl.stall_prob = x),
            ("FAIL_PROB", |mdl, x| mdl.fail_prob = x),
        ];
        for (key, set) in scalars {
            if let Some(v) = file.get(key) {
                // A file-level scalar would silently clobber the CVs the
                // object entries spelled out — reject the conflict.
                ensure!(
                    !object_form,
                    "{key} has no effect when JOB_MEAN_S entries are objects — set it inside each object"
                );
                let x = item_f64(v, key)?;
                for mdl in &mut m.models {
                    set(mdl, x);
                }
            }
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        let proto = m.models.first().cloned().unwrap_or_default();
        if models_homogeneous(&m.models) {
            vec![
                ("JOB_MEAN_S", num_arr(m.models.iter().map(|mdl| mdl.mean_s))),
                ("JOB_CV", Value::from(proto.cv)),
                ("STALL_PROB", Value::from(proto.stall_prob)),
                ("FAIL_PROB", Value::from(proto.fail_prob)),
            ]
        } else {
            vec![(
                "JOB_MEAN_S",
                Value::Arr(
                    m.models
                        .iter()
                        .map(|mdl| {
                            Value::obj()
                                .with("MEAN_S", mdl.mean_s)
                                .with("CV", mdl.cv)
                                .with("STALL_PROB", mdl.stall_prob)
                                .with("FAIL_PROB", mdl.fail_prob)
                        })
                        .collect(),
                ),
            )]
        }
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.model = sc.model.clone();
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        Some(format!("mean={:.0}s", sc.model.mean_s))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        Some(Value::from(sc.model.mean_s))
    }
}

/// Fleet allocation strategy — `--allocation` / `ALLOCATION`.
pub struct AllocationAxis;

impl Axis for AllocationAxis {
    fn key(&self) -> &'static str {
        "ALLOCATION"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "allocation",
            value: "A,A,..",
            help: "fleet allocation axis: lowest-price|diversified|capacity-optimized",
            file_key: Some("ALLOCATION"),
        }]
    }
    fn in_run(&self) -> bool {
        false
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.allocations.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(m.allocations.iter().map(|a| a.name()))
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "allocation")? {
            m.allocations = items
                .iter()
                .map(|s| parse_allocation(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "ALLOCATION")? {
            m.allocations = items
                .iter()
                .map(|v| item_str(v, "ALLOCATION").and_then(parse_allocation))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "ALLOCATION",
            Value::Arr(m.allocations.iter().map(|a| Value::from(a.name())).collect()),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.fleet.allocation_strategy = sc.allocation;
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        Some(format!("alloc={}", sc.allocation.name()))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        Some(Value::from(sc.allocation.name()))
    }
}

/// Instance sets — `--instance-types` / `INSTANCE_TYPES`.  Sets are
/// comma-separated on the CLI and array items in the file; inside a set
/// types are '+'-joined `name[:weight]` specs.  An empty set (`""` in
/// the file) inherits the plan's fleet file / Config types.
pub struct InstanceSetAxis;

impl Axis for InstanceSetAxis {
    fn key(&self) -> &'static str {
        "INSTANCE_TYPES"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "instance-types",
            value: "T+T,..",
            help: "instance-set axis; sets comma-separated, types '+'-joined, each 'name[:weight]' (e.g. m5.large+c5.xlarge:2)",
            file_key: Some("INSTANCE_TYPES"),
        }]
    }
    fn in_run(&self) -> bool {
        false
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.instance_sets.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(m.instance_sets.iter().map(|set| {
            if set.is_empty() {
                "(inherit)".to_string()
            } else {
                render_instance_set(set)
            }
        }))
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "instance-types")? {
            m.instance_sets = items
                .iter()
                .map(|set| {
                    let slots = parse_instance_set(set)?;
                    ensure!(!slots.is_empty(), "empty instance set in --instance-types");
                    Ok(slots)
                })
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "INSTANCE_TYPES")? {
            m.instance_sets = items
                .iter()
                .map(|v| item_str(v, "INSTANCE_TYPES").and_then(parse_instance_set))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "INSTANCE_TYPES",
            Value::Arr(
                m.instance_sets
                    .iter()
                    .map(|set| Value::from(render_instance_set(set)))
                    .collect(),
            ),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        if !sc.instance_set.is_empty() {
            cell.fleet.instance_types = sc.instance_set.clone();
        }
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        if sc.instance_set.is_empty() {
            None
        } else {
            Some(format!("set={}", render_instance_set(&sc.instance_set)))
        }
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        if sc.instance_set.is_empty() {
            None
        } else {
            Some(Value::Arr(
                sc.instance_set
                    .iter()
                    .map(|s| Value::from(s.render()))
                    .collect(),
            ))
        }
    }
}

/// Mean input MB per job — `--input-mb` / `INPUT_MB`.  Non-zero values
/// overlay a per-job data shape on the plan's Job file.
pub struct InputMbAxis;

impl Axis for InputMbAxis {
    fn key(&self) -> &'static str {
        "INPUT_MB"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "input-mb",
            value: "MB,MB,..",
            help: "mean input MB per job axis; non-zero adds download/compute/upload phases on the S3 data plane (default 0)",
            file_key: Some("INPUT_MB"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.input_mbs.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(&m.input_mbs)
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(mbs) = cli_typed_list::<f64>(args, "input-mb")? {
            m.input_mbs = mbs;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "INPUT_MB")? {
            m.input_mbs = items
                .iter()
                .map(|v| item_f64(v, "INPUT_MB"))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![("INPUT_MB", num_arr(m.input_mbs.iter().copied()))]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.input_mb = sc.input_mb;
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        // Data axes only label cells that use them, so zero-data sweeps
        // keep their historical labels.
        (sc.input_mb > 0.0).then(|| format!("in={}MB", sc.input_mb))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        (sc.input_mb > 0.0).then(|| Value::from(sc.input_mb))
    }
}

/// Bucket network profile — `--net-profile` / `NET_PROFILE`.
pub struct NetProfileAxis;

impl Axis for NetProfileAxis {
    fn key(&self) -> &'static str {
        "NET_PROFILE"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "net-profile",
            value: "P,P,..",
            help: "network profile axis: wide|standard|narrow (bucket throughput + first-byte latency)",
            file_key: Some("NET_PROFILE"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.net_profiles.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(m.net_profiles.iter().map(|p| p.name))
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "net-profile")? {
            m.net_profiles = items
                .iter()
                .map(|s| parse_net_profile(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "NET_PROFILE")? {
            m.net_profiles = items
                .iter()
                .map(|v| item_str(v, "NET_PROFILE").and_then(parse_net_profile))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "NET_PROFILE",
            Value::Arr(m.net_profiles.iter().map(|p| Value::from(p.name)).collect()),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.opts.net = sc.net.clone();
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        (sc.net != NetProfile::default()).then(|| format!("net={}", sc.net.name))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        (sc.net != NetProfile::default()).then(|| Value::from(sc.net.name))
    }
}

/// Autoscaling policy mode — `--scaling` / `SCALING`.  `none` is the
/// paper's fixed fleet; `target-tracking` and `step` engage the
/// monitor's closed-loop controller
/// ([`crate::coordinator::autoscale`]).
pub struct ScalingAxis;

impl Axis for ScalingAxis {
    fn key(&self) -> &'static str {
        "SCALING"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "scaling",
            value: "P,P,..",
            help: "autoscaling policy axis: none|target-tracking|step (alarm-driven monitor scaling)",
            file_key: Some("SCALING"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.scalings.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(m.scalings.iter().map(|s| s.name()))
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "scaling")? {
            m.scalings = items
                .iter()
                .map(|s| parse_scaling(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "SCALING")? {
            m.scalings = items
                .iter()
                .map(|v| item_str(v, "SCALING").and_then(parse_scaling))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "SCALING",
            Value::Arr(m.scalings.iter().map(|s| Value::from(s.name())).collect()),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        // The mode picks the canonical policy; the scaling-target axis
        // (registered after this one) overrides the target knob.
        cell.opts.scaling = sc.scaling.policy(DEFAULT_TARGET_PER_UNIT);
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        // Fixed-fleet cells stay unlabeled, so historical labels are
        // byte-stable (the only-label-when-used rule).
        (sc.scaling != ScalingMode::None).then(|| format!("scale={}", sc.scaling.name()))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        (sc.scaling != ScalingMode::None).then(|| Value::from(sc.scaling.name()))
    }
}

/// Scaling-policy backlog target — `--scaling-target` /
/// `SCALING_TARGET`: desired backlog (visible + in-flight jobs) per
/// weighted capacity unit.  Labeled (and serialized into scenario JSON)
/// only when a scaling policy is engaged.
pub struct ScalingTargetAxis;

impl Axis for ScalingTargetAxis {
    fn key(&self) -> &'static str {
        "SCALING_TARGET"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "scaling-target",
            value: "B,B,..",
            help: "target backlog per capacity unit for --scaling (default 4)",
            file_key: Some("SCALING_TARGET"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.scaling_targets.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(&m.scaling_targets)
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(targets) = cli_typed_list::<f64>(args, "scaling-target")? {
            ensure!(
                targets.iter().all(|t| *t > 0.0),
                "--scaling-target values must be > 0"
            );
            m.scaling_targets = targets;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "SCALING_TARGET")? {
            let targets: Vec<f64> = items
                .iter()
                .map(|v| item_f64(v, "SCALING_TARGET"))
                .collect::<Result<_>>()?;
            ensure!(
                targets.iter().all(|t| *t > 0.0),
                "SCALING_TARGET values must be > 0"
            );
            m.scaling_targets = targets;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![("SCALING_TARGET", num_arr(m.scaling_targets.iter().copied()))]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        if let Some(policy) = &mut cell.opts.scaling {
            policy.target_per_unit = sc.scaling_target;
        }
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        (sc.scaling != ScalingMode::None).then(|| format!("tgt={}", sc.scaling_target))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        (sc.scaling != ScalingMode::None).then(|| Value::from(sc.scaling_target))
    }
}

/// DAG workflow replacing the flat job list — `--workflow` /
/// `WORKFLOW`.  CLI items are canonical shape names
/// ([`crate::workloads::dag::SHAPES`]), Workflow-file paths, or `none`
/// (flat submission).  Sweep files additionally accept inline workflow
/// objects, and [`Axis::render_file`] always inlines the full spec so a
/// rendered plan stays hermetic (shard workers never chase file paths).
pub struct WorkflowAxis;

/// Parse one CLI/file workflow item: `none` for flat submission, else a
/// shape name or Workflow-file path resolved by [`WorkflowSpec::resolve`].
fn parse_workflow(s: &str) -> Result<Option<WorkflowSpec>> {
    if s == "none" {
        return Ok(None);
    }
    WorkflowSpec::resolve(s).map(Some).map_err(|e| anyhow!(e))
}

impl Axis for WorkflowAxis {
    fn key(&self) -> &'static str {
        "WORKFLOW"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "workflow",
            value: "W,W,..",
            help: "DAG workflow axis: none|diamond|fanout|linear|mosaic or a Workflow-file path",
            file_key: Some("WORKFLOW"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.workflows.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(
            m.workflows
                .iter()
                .map(|w| w.as_ref().map_or("none", |s| s.name.as_str())),
        )
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "workflow")? {
            m.workflows = items
                .iter()
                .map(|s| parse_workflow(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "WORKFLOW")? {
            m.workflows = items
                .iter()
                .map(|v| match v {
                    Value::Obj(_) => WorkflowSpec::from_json(v).map(Some).map_err(|e| anyhow!(e)),
                    _ => item_str(v, "WORKFLOW").and_then(parse_workflow),
                })
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "WORKFLOW",
            Value::Arr(
                m.workflows
                    .iter()
                    .map(|w| w.as_ref().map_or(Value::from("none"), |s| s.to_json()))
                    .collect(),
            ),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.opts.workflow = sc.workflow.clone();
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        // Flat-submission cells stay unlabeled (only-label-when-used).
        sc.workflow.as_ref().map(|w| format!("wf={}", w.name))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        sc.workflow.as_ref().map(|w| Value::from(w.name.as_str()))
    }
}

/// Artifact sharing mode for workflow cells — `--sharing` / `SHARING`:
/// where intermediate artifacts live and what moving them costs
/// (S3 staging, producer-node pull, or a shared filesystem).  Labeled
/// (and serialized into scenario JSON) only when it departs from the
/// default S3 staging.
pub struct SharingAxis;

fn parse_sharing(s: &str) -> Result<SharingMode> {
    SharingMode::parse(s).ok_or_else(|| anyhow!("sharing must be s3|node-local|shared-fs, got {s}"))
}

impl Axis for SharingAxis {
    fn key(&self) -> &'static str {
        "SHARING"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "sharing",
            value: "S,S,..",
            help: "workflow artifact sharing axis: s3|node-local|shared-fs",
            file_key: Some("SHARING"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.sharings.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(m.sharings.iter().map(|s| s.name()))
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "sharing")? {
            m.sharings = items
                .iter()
                .map(|s| parse_sharing(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "SHARING")? {
            m.sharings = items
                .iter()
                .map(|v| item_str(v, "SHARING").and_then(parse_sharing))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "SHARING",
            Value::Arr(m.sharings.iter().map(|s| Value::from(s.name())).collect()),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.opts.sharing = sc.sharing;
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        (sc.sharing != SharingMode::S3Staging).then(|| format!("share={}", sc.sharing.name()))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        (sc.sharing != SharingMode::S3Staging).then(|| Value::from(sc.sharing.name()))
    }
}

/// Failure-domain layout — `--topology` / `TOPOLOGY`.  CLI items are
/// built-in shape names ([`ClusterTopology::SHAPES`]), TOPOLOGY-file
/// paths, or `single` (the implicit pre-topology cluster, parsed to
/// "no topology installed").  Sweep files additionally accept inline
/// topology objects, and [`Axis::render_file`] always inlines the full
/// spec so a rendered plan stays hermetic (shard workers never chase
/// file paths).  Labeled and serialized only when a topology is
/// installed, so legacy labels and sweep JSON stay byte-stable.
pub struct TopologyAxis;

/// Parse one CLI/file topology item: `single` for the legacy
/// single-domain world, else a shape name or TOPOLOGY-file path
/// resolved by [`ClusterTopology::resolve`].
fn parse_topology(s: &str) -> Result<Option<ClusterTopology>> {
    if s == "single" {
        return Ok(None);
    }
    ClusterTopology::resolve(s).map(Some).map_err(|e| anyhow!(e))
}

impl Axis for TopologyAxis {
    fn key(&self) -> &'static str {
        "TOPOLOGY"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "topology",
            value: "T,T,..",
            help: "failure-domain axis: single|three-az|two-region or a TOPOLOGY-file path",
            file_key: Some("TOPOLOGY"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.topologies.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(
            m.topologies
                .iter()
                .map(|t| t.as_ref().map_or("single", |s| s.name.as_str())),
        )
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "topology")? {
            m.topologies = items
                .iter()
                .map(|s| parse_topology(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "TOPOLOGY")? {
            m.topologies = items
                .iter()
                .map(|v| match v {
                    Value::Obj(_) => ClusterTopology::from_json(v)
                        .map(Some)
                        .map_err(|e| anyhow!(e)),
                    _ => item_str(v, "TOPOLOGY").and_then(parse_topology),
                })
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "TOPOLOGY",
            Value::Arr(
                m.topologies
                    .iter()
                    .map(|t| t.as_ref().map_or(Value::from("single"), |s| s.to_json()))
                    .collect(),
            ),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.opts.topology = sc.topology.clone();
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        // Single-domain cells stay unlabeled (only-label-when-used).
        sc.topology.as_ref().map(|t| format!("topo={}", t.name))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        sc.topology.as_ref().map(|t| Value::from(t.name.as_str()))
    }
}

/// Placement policy for topology cells — `--placement` / `PLACEMENT`:
/// how the fleet spreads capacity across failure domains (pack the home
/// domain, spread round-robin, or chase the cheapest pool anywhere).
/// Labeled (and serialized into scenario JSON) only when it departs
/// from the default pack policy.
pub struct PlacementAxis;

fn parse_placement(s: &str) -> Result<Placement> {
    Placement::parse(s).ok_or_else(|| anyhow!("placement must be pack|spread|cheapest, got {s}"))
}

impl Axis for PlacementAxis {
    fn key(&self) -> &'static str {
        "PLACEMENT"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "placement",
            value: "P,P,..",
            help: "domain placement axis: pack|spread|cheapest",
            file_key: Some("PLACEMENT"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.placements.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(m.placements.iter().map(|p| p.name()))
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "placement")? {
            m.placements = items
                .iter()
                .map(|s| parse_placement(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "PLACEMENT")? {
            m.placements = items
                .iter()
                .map(|v| item_str(v, "PLACEMENT").and_then(parse_placement))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "PLACEMENT",
            Value::Arr(m.placements.iter().map(|p| Value::from(p.name())).collect()),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.opts.placement = sc.placement;
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        (sc.placement != Placement::Pack).then(|| format!("place={}", sc.placement.name()))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        (sc.placement != Placement::Pack).then(|| Value::from(sc.placement.name()))
    }
}

/// Multi-tenant traffic — `--traffic` / `TRAFFIC`.  CLI items are
/// built-in shape names ([`TrafficSpec::SHAPES`]), TRAFFIC-file paths,
/// or `single` (the implicit one-submitter world, parsed to "no
/// traffic installed").  Sweep files additionally accept inline
/// traffic objects, and [`Axis::render_file`] always inlines the full
/// spec so a rendered plan stays hermetic (shard workers never chase
/// file paths).  Labeled and serialized only when a traffic spec is
/// installed, so legacy labels and sweep JSON stay byte-stable.
pub struct TrafficAxis;

/// Parse one CLI/file traffic item: `single` for the legacy
/// one-submitter world, else a shape name or TRAFFIC-file path
/// resolved by [`TrafficSpec::resolve`].
fn parse_traffic(s: &str) -> Result<Option<TrafficSpec>> {
    if s == "single" {
        return Ok(None);
    }
    TrafficSpec::resolve(s).map(Some).map_err(|e| anyhow!(e))
}

impl Axis for TrafficAxis {
    fn key(&self) -> &'static str {
        "TRAFFIC"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "traffic",
            value: "T,T,..",
            help: "tenant-traffic axis: single|two-tenant|noisy-neighbor or a TRAFFIC-file path",
            file_key: Some("TRAFFIC"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.traffics.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(
            m.traffics
                .iter()
                .map(|t| t.as_ref().map_or("single", |s| s.name.as_str())),
        )
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "traffic")? {
            m.traffics = items
                .iter()
                .map(|s| parse_traffic(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "TRAFFIC")? {
            m.traffics = items
                .iter()
                .map(|v| match v {
                    Value::Obj(_) => TrafficSpec::from_json(v)
                        .map(Some)
                        .map_err(|e| anyhow!(e)),
                    _ => item_str(v, "TRAFFIC").and_then(parse_traffic),
                })
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "TRAFFIC",
            Value::Arr(
                m.traffics
                    .iter()
                    .map(|t| t.as_ref().map_or(Value::from("single"), |s| s.to_json()))
                    .collect(),
            ),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.opts.traffic = sc.traffic.clone();
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        // Single-tenant cells stay unlabeled (only-label-when-used).
        sc.traffic.as_ref().map(|t| format!("traffic={}", t.name))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        sc.traffic.as_ref().map(|t| Value::from(t.name.as_str()))
    }
}

/// Queueing policy for traffic cells — `--queueing` / `QUEUEING`: how
/// the coordinator arbitrates tenants at the queue head (strict FIFO,
/// weighted-deficit fair share, or strict priority tiers).  Labeled
/// (and serialized into scenario JSON) only when it departs from the
/// default FIFO policy.
pub struct QueueingAxis;

fn parse_queueing(s: &str) -> Result<QueueingPolicy> {
    QueueingPolicy::parse(s)
        .ok_or_else(|| anyhow!("queueing must be fifo|fair-share|priority, got {s}"))
}

impl Axis for QueueingAxis {
    fn key(&self) -> &'static str {
        "QUEUEING"
    }
    fn flags(&self) -> &'static [FlagSpec] {
        &[FlagSpec {
            flag: "queueing",
            value: "Q,Q,..",
            help: "tenant-queueing axis: fifo|fair-share|priority",
            file_key: Some("QUEUEING"),
        }]
    }
    fn len(&self, m: &ScenarioMatrix) -> usize {
        m.queueings.len()
    }
    fn describe(&self, m: &ScenarioMatrix) -> String {
        join(m.queueings.iter().map(|q| q.name()))
    }
    fn parse_cli(&self, args: &Args, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = cli_list(args, "queueing")? {
            m.queueings = items
                .iter()
                .map(|s| parse_queueing(s))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn parse_file(&self, file: &Value, m: &mut ScenarioMatrix) -> Result<()> {
        if let Some(items) = file_list(file, "QUEUEING")? {
            m.queueings = items
                .iter()
                .map(|v| item_str(v, "QUEUEING").and_then(parse_queueing))
                .collect::<Result<_>>()?;
        }
        Ok(())
    }
    fn render_file(&self, m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
        vec![(
            "QUEUEING",
            Value::Arr(m.queueings.iter().map(|q| Value::from(q.name())).collect()),
        )]
    }
    fn overlay(&self, sc: &Scenario, cell: &mut CellInputs) {
        cell.opts.queueing = sc.queueing;
    }
    fn label(&self, sc: &Scenario) -> Option<String> {
        (sc.queueing != QueueingPolicy::Fifo).then(|| format!("queue={}", sc.queueing.name()))
    }
    fn json_value(&self, sc: &Scenario) -> Option<Value> {
        (sc.queueing != QueueingPolicy::Fifo).then(|| Value::from(sc.queueing.name()))
    }
}

// ---------------------------------------------------------------------------
// The flag tables (generated surfaces)
// ---------------------------------------------------------------------------

/// Plan-level sweep flags rendered before the axis flags.
static SWEEP_PLAN_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        flag: "config",
        value: "FILE",
        help: "base Config file (default: built-in defaults)",
        file_key: Some("CONFIG"),
    },
    FlagSpec {
        flag: "job",
        value: "FILE",
        help: "Job file replayed by every cell (default: synthetic plate)",
        file_key: Some("JOB"),
    },
    FlagSpec {
        flag: "fleet",
        value: "FILE",
        help: "Fleet file (default: built-in us-east-1 template)",
        file_key: Some("FLEET"),
    },
    FlagSpec {
        flag: "plan",
        value: "FILE",
        help: "Sweep file declaring the whole matrix (KEY-value JSON, like Config/Job/Fleet); CLI flags override file keys",
        file_key: None,
    },
    FlagSpec {
        flag: "dry-run",
        value: "",
        help: "print the expanded matrix (axes, scenarios, cells) and exit without running",
        file_key: None,
    },
    FlagSpec {
        flag: "plate",
        value: "NAME",
        help: "synthetic plate name when no --job (default P1)",
        file_key: Some("PLATE"),
    },
    FlagSpec {
        flag: "wells",
        value: "N",
        help: "synthetic plate wells when no --job (default 24)",
        file_key: Some("WELLS"),
    },
    FlagSpec {
        flag: "sites",
        value: "N",
        help: "synthetic plate sites/well when no --job (default 2)",
        file_key: Some("SITES"),
    },
    FlagSpec {
        flag: "seeds",
        value: "N",
        help: "replicate seeds per scenario (default 4; Sweep-file SEEDS also accepts an explicit seed list)",
        file_key: Some("SEEDS"),
    },
    FlagSpec {
        flag: "seed-base",
        value: "N",
        help: "first seed value (default 0)",
        file_key: Some("SEED_BASE"),
    },
    FlagSpec {
        flag: "on-demand-base",
        value: "N",
        help: "weighted units kept on-demand in every cell (default: Fleet file's)",
        file_key: Some("ON_DEMAND_BASE"),
    },
];

/// Sweep flags rendered after the axis flags (execution/output knobs —
/// never Sweep-file keys, since the plan is thread- and format-agnostic).
static SWEEP_EXEC_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        flag: "threads",
        value: "N",
        help: "worker threads (default: available cores; with --shards: threads per shard)",
        file_key: None,
    },
    FlagSpec {
        flag: "shards",
        value: "N",
        help: "split the sweep across N worker shards (default 0 = single process; see --shard-exec)",
        file_key: None,
    },
    FlagSpec {
        flag: "shard-exec",
        value: "MODE",
        help: "shard executor: process (a fresh `ds shard-worker` child per shard) | inproc (default process)",
        file_key: None,
    },
    FlagSpec {
        flag: "shard-timeout-s",
        value: "S",
        help: "per-shard worker timeout in seconds before a fresh retry (default 600)",
        file_key: None,
    },
    FlagSpec {
        flag: "shard-retries",
        value: "N",
        help: "extra attempts per failed shard before the sweep fails (default 2)",
        file_key: None,
    },
    FlagSpec {
        flag: "json",
        value: "",
        help: "emit the report as JSON on stdout (chatter to stderr)",
        file_key: None,
    },
    FlagSpec {
        flag: "help",
        value: "",
        help: "show this help",
        file_key: None,
    },
];

/// `ds run` flags rendered before the shared axis flags.
static RUN_ONLY_PRE: &[FlagSpec] = &[
    FlagSpec {
        flag: "config",
        value: "FILE",
        help: "Config file (required)",
        file_key: None,
    },
    FlagSpec {
        flag: "job",
        value: "FILE",
        help: "Job file (required)",
        file_key: None,
    },
    FlagSpec {
        flag: "fleet",
        value: "FILE",
        help: "Fleet file (required)",
        file_key: None,
    },
    FlagSpec {
        flag: "seed",
        value: "N",
        help: "simulation seed (default 42)",
        file_key: None,
    },
];

/// `ds run` flags rendered after the shared axis flags.
static RUN_ONLY_POST: &[FlagSpec] = &[
    FlagSpec {
        flag: "no-monitor",
        value: "",
        help: "skip the Step-4 monitor (leaks resources, as in the paper)",
        file_key: None,
    },
    FlagSpec {
        flag: "cheapest",
        value: "",
        help: "monitor cheapest mode (downscale requested capacity after 15 min; excludes --queue-downscale)",
        file_key: None,
    },
    FlagSpec {
        flag: "queue-downscale",
        value: "",
        help: "monitor terminates surplus machines as the queue drains, cheapest pool last (excludes --cheapest)",
        file_key: None,
    },
    FlagSpec {
        flag: "crash-mttf-min",
        value: "M",
        help: "mean minutes to instance crash (default: no crashes)",
        file_key: None,
    },
    FlagSpec {
        flag: "pjrt",
        value: "DIR",
        help: "run real AOT artifacts from DIR instead of the modeled executor",
        file_key: None,
    },
    FlagSpec {
        flag: "time-scale",
        value: "X",
        help: "PJRT wall-time to sim-time scale (default 1.0)",
        file_key: None,
    },
    FlagSpec {
        flag: "json",
        value: "",
        help: "emit the run report as JSON on stdout (chatter to stderr)",
        file_key: None,
    },
    FlagSpec {
        flag: "help",
        value: "",
        help: "show this help",
        file_key: None,
    },
];

/// Every flag `ds sweep` reads, generated from the registry: plan-level
/// flags, then each axis's flags in registry order, then execution
/// flags.  The help text, the unknown-flag rejection, and the Sweep-file
/// key set are all projections of this one list.
pub fn sweep_flags() -> Vec<&'static FlagSpec> {
    let mut out: Vec<&'static FlagSpec> = SWEEP_PLAN_FLAGS.iter().collect();
    for ax in AXES {
        out.extend(ax.flags());
    }
    out.extend(SWEEP_EXEC_FLAGS.iter());
    out
}

/// Every flag `ds run` documents: run-only flags plus the axes `ds run`
/// shares with `ds sweep` ([`Axis::in_run`]), which accept a single
/// value there.
pub fn run_flags() -> Vec<&'static FlagSpec> {
    let mut out: Vec<&'static FlagSpec> = RUN_ONLY_PRE.iter().collect();
    for ax in AXES {
        if ax.in_run() {
            out.extend(ax.flags());
        }
    }
    out.extend(RUN_ONLY_POST.iter());
    out
}

/// The keys a Sweep file may contain (the `file_key` projection of
/// [`sweep_flags`]).
pub fn sweep_file_keys() -> Vec<&'static str> {
    sweep_flags().iter().filter_map(|f| f.file_key).collect()
}

/// Every axis's Sweep-file entries for `m`, in registry order — the
/// shared body of `SweepFile::render`, the `--json` dry run, and the
/// round-trip tests, so the serialized axis schema cannot drift between
/// surfaces.
pub fn render_matrix_entries(m: &ScenarioMatrix) -> Vec<(&'static str, Value)> {
    AXES.iter().flat_map(|ax| ax.render_file(m)).collect()
}

/// Render a flag table for help text.
pub fn render_flag_specs(flags: &[&FlagSpec]) -> String {
    let mut out = String::new();
    for f in flags {
        let lhs = if f.value.is_empty() {
            format!("--{}", f.flag)
        } else {
            format!("--{} {}", f.flag, f.value)
        };
        out.push_str(&format!("  {lhs:<28} {}\n", f.help));
    }
    out
}

/// Render the matrix one axis per line (the `--dry-run` body): Sweep-file
/// key, CLI flag, and the axis's values.
pub fn describe_matrix(m: &ScenarioMatrix) -> String {
    let mut out = String::new();
    for ax in AXES {
        out.push_str(&format!(
            "  {:<14} {:<18} [{}] {}\n",
            ax.key(),
            format!("(--{})", ax.flags()[0].flag),
            ax.len(m),
            ax.describe(m)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn registry_covers_every_matrix_axis() {
        // The product of per-axis lengths is the scenario count: no
        // matrix field escapes the registry.
        let m = ScenarioMatrix {
            cluster_machines: vec![1, 2, 4],
            volatilities: vec![Volatility::Low, Volatility::High],
            input_mbs: vec![0.0, 64.0],
            ..Default::default()
        };
        let product: usize = AXES.iter().map(|ax| ax.len(&m)).product();
        assert_eq!(product, m.scenarios().len());
        // The allocation-free count agrees with the expansion.
        assert_eq!(m.scenario_count(), m.scenarios().len());
        assert_eq!(m.cell_count(), m.scenarios().len() * m.seeds.len());
    }

    #[test]
    fn axis_keys_and_flags_are_unique() {
        let mut keys: Vec<&str> = AXES.iter().map(|ax| ax.key()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), AXES.len());
        let mut flags: Vec<&str> = sweep_flags().iter().map(|f| f.flag).collect();
        flags.sort_unstable();
        flags.dedup();
        assert_eq!(flags.len(), sweep_flags().len(), "duplicate sweep flag");
    }

    #[test]
    fn cli_overlay_only_touches_present_flags() {
        let mut m = ScenarioMatrix::default();
        let args = parse("sweep --machines 2,4 --volatility high");
        for ax in AXES {
            ax.parse_cli(&args, &mut m).unwrap();
        }
        assert_eq!(m.cluster_machines, vec![2, 4]);
        assert_eq!(m.volatilities, vec![Volatility::High]);
        // Untouched axes keep their defaults.
        assert_eq!(m.visibilities, ScenarioMatrix::default().visibilities);
        assert_eq!(m.input_mbs, vec![0.0]);
    }

    #[test]
    fn cli_rejects_bad_and_valueless_axis_values() {
        let mut m = ScenarioMatrix::default();
        let args = parse("sweep --machines 8x");
        let err = MachinesAxis.parse_cli(&args, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("bad value '8x' for --machines"), "{err:#}");
        let args = parse("sweep --volatility --json");
        let err = VolatilityAxis.parse_cli(&args, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("missing value for --volatility"), "{err:#}");
    }

    #[test]
    fn duration_scalars_apply_to_every_mean() {
        let mut m = ScenarioMatrix::default();
        let args = parse("sweep --job-mean-s 60,120 --job-cv 0.5 --fail-prob 0.1");
        DurationAxis.parse_cli(&args, &mut m).unwrap();
        assert_eq!(m.models.len(), 2);
        for mdl in &m.models {
            assert_eq!(mdl.cv, 0.5);
            assert_eq!(mdl.fail_prob, 0.1);
            assert_eq!(mdl.stall_prob, 0.0);
        }
        assert_eq!(m.models[0].mean_s, 60.0);
        assert_eq!(m.models[1].mean_s, 120.0);
    }

    #[test]
    fn file_round_trips_every_axis() {
        let m = ScenarioMatrix {
            seeds: vec![1, 2],
            cluster_machines: vec![2, 8],
            visibilities: vec![90_000, 600_000],
            volatilities: vec![Volatility::Medium],
            allocations: vec![AllocationStrategy::Diversified],
            instance_sets: vec![
                Vec::new(),
                vec![
                    InstanceSlot::new("m5.large"),
                    InstanceSlot {
                        name: "c5.xlarge".into(),
                        weight: 2,
                    },
                ],
            ],
            input_mbs: vec![0.0, 64.0],
            net_profiles: vec![NetProfile::narrow()],
            scalings: vec![ScalingMode::None, ScalingMode::TargetTracking],
            scaling_targets: vec![2.0, 6.0],
            models: vec![DurationModel {
                mean_s: 45.0,
                cv: 0.5,
                stall_prob: 0.01,
                fail_prob: 0.02,
            }],
            workflows: vec![None, Some(crate::workloads::dag::diamond())],
            sharings: vec![SharingMode::S3Staging, SharingMode::NodeLocal],
            topologies: vec![None, ClusterTopology::shape("three-az")],
            placements: vec![Placement::Pack, Placement::Spread],
            traffics: vec![None, TrafficSpec::shape("noisy-neighbor")],
            queueings: vec![QueueingPolicy::Fifo, QueueingPolicy::Priority],
        };
        let mut file = Value::obj();
        for (k, v) in render_matrix_entries(&m) {
            file = file.with(k, v);
        }
        // Parse into a fresh default matrix: every axis must come back.
        let mut back = ScenarioMatrix {
            seeds: m.seeds.clone(),
            ..Default::default()
        };
        for ax in AXES {
            ax.parse_file(&file, &mut back).unwrap();
        }
        assert_eq!(format!("{m:?}"), format!("{back:?}"));
        let labels: Vec<String> = m.scenarios().iter().map(Scenario::label).collect();
        let back_labels: Vec<String> = back.scenarios().iter().map(Scenario::label).collect();
        assert_eq!(labels, back_labels);
    }

    #[test]
    fn heterogeneous_models_render_as_objects() {
        let m = ScenarioMatrix {
            models: vec![
                DurationModel {
                    mean_s: 30.0,
                    cv: 0.1,
                    ..Default::default()
                },
                DurationModel {
                    mean_s: 60.0,
                    cv: 0.9,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        let rendered = DurationAxis.render_file(&m);
        assert_eq!(rendered.len(), 1, "heterogeneous models use the object form");
        let mut back = ScenarioMatrix::default();
        let mut file = Value::obj();
        for (k, v) in rendered {
            file = file.with(k, v);
        }
        DurationAxis.parse_file(&file, &mut back).unwrap();
        assert_eq!(format!("{:?}", m.models), format!("{:?}", back.models));
    }

    #[test]
    fn job_mean_s_object_entries_are_strict() {
        // Inner typos and non-numeric values must error, not silently
        // fall back to the default shape.
        let mut m = ScenarioMatrix::default();
        let file = crate::json::parse(r#"{"JOB_MEAN_S": [{"MEAN_S": 60, "CVV": 0.9}]}"#).unwrap();
        let err = DurationAxis.parse_file(&file, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("CVV"), "{err:#}");
        let file = crate::json::parse(r#"{"JOB_MEAN_S": [{"MEAN_S": 60, "CV": "0.9"}]}"#).unwrap();
        let err = DurationAxis.parse_file(&file, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("CV"), "{err:#}");
        let file = crate::json::parse(r#"{"JOB_MEAN_S": [{"CV": 0.9}]}"#).unwrap();
        let err = DurationAxis.parse_file(&file, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("MEAN_S"), "{err:#}");
    }

    #[test]
    fn scaling_axes_parse_expand_and_label_when_used() {
        let mut m = ScenarioMatrix::default();
        let args = parse("sweep --scaling none,target-tracking --scaling-target 2,8");
        ScalingAxis.parse_cli(&args, &mut m).unwrap();
        ScalingTargetAxis.parse_cli(&args, &mut m).unwrap();
        assert_eq!(
            m.scalings,
            vec![ScalingMode::None, ScalingMode::TargetTracking]
        );
        assert_eq!(m.scaling_targets, vec![2.0, 8.0]);
        let scs = m.scenarios();
        assert_eq!(scs.len(), 4);
        // Fixed-fleet cells stay unlabeled (historical labels stable);
        // engaged cells carry both fragments and both JSON keys.
        assert!(ScalingAxis.label(&scs[0]).is_none());
        assert!(ScalingTargetAxis.label(&scs[0]).is_none());
        assert!(ScalingAxis.json_value(&scs[1]).is_none());
        assert_eq!(
            ScalingAxis.label(&scs[2]).as_deref(),
            Some("scale=target-tracking")
        );
        assert_eq!(ScalingTargetAxis.label(&scs[2]).as_deref(), Some("tgt=2"));
        assert_eq!(
            ScalingTargetAxis.json_value(&scs[3]).and_then(|v| v.as_f64()),
            Some(8.0)
        );
        // Bad values are rejected, not defaulted.
        let args = parse("sweep --scaling sometimes");
        assert!(ScalingAxis.parse_cli(&args, &mut m).is_err());
        let args = parse("sweep --scaling-target 0");
        assert!(ScalingTargetAxis.parse_cli(&args, &mut m).is_err());
        let file = crate::json::parse(r#"{"SCALING_TARGET": [-1]}"#).unwrap();
        assert!(ScalingTargetAxis.parse_file(&file, &mut m).is_err());
    }

    #[test]
    fn scaling_overlay_builds_the_policy() {
        use crate::config::{AppConfig, FleetSpec};
        use crate::coordinator::run::RunOptions;
        let m = ScenarioMatrix {
            scalings: vec![ScalingMode::Step],
            scaling_targets: vec![6.0],
            ..Default::default()
        };
        let sc = m.scenarios().remove(0);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let cell = sc.cell_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        let p = cell.opts.scaling.expect("policy engaged");
        assert_eq!(p.mode(), ScalingMode::Step);
        assert_eq!(p.target_per_unit, 6.0);
        // `ds run` shares the axes (they are opts-owned, not file-owned).
        let cell = sc.run_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert!(cell.opts.scaling.is_some());
        // A none-mode scenario leaves the options untouched.
        let m = ScenarioMatrix::default();
        let sc = m.scenarios().remove(0);
        let cell = sc.cell_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert!(cell.opts.scaling.is_none());
    }

    #[test]
    fn workflow_axis_parses_shapes_and_labels_when_used() {
        let mut m = ScenarioMatrix::default();
        let args = parse("sweep --workflow none,diamond --sharing s3,node-local");
        WorkflowAxis.parse_cli(&args, &mut m).unwrap();
        SharingAxis.parse_cli(&args, &mut m).unwrap();
        assert_eq!(m.workflows.len(), 2);
        assert!(m.workflows[0].is_none());
        assert_eq!(m.workflows[1].as_ref().unwrap().name, "diamond");
        assert_eq!(
            m.sharings,
            vec![SharingMode::S3Staging, SharingMode::NodeLocal]
        );
        let scs = m.scenarios();
        assert_eq!(scs.len(), 4);
        // Flat cells and default-sharing cells stay unlabeled; engaged
        // cells carry both fragments and both JSON keys.
        assert!(WorkflowAxis.label(&scs[0]).is_none());
        assert!(SharingAxis.label(&scs[0]).is_none());
        assert_eq!(
            SharingAxis.label(&scs[1]).as_deref(),
            Some("share=node-local")
        );
        assert_eq!(WorkflowAxis.label(&scs[2]).as_deref(), Some("wf=diamond"));
        assert_eq!(
            WorkflowAxis
                .json_value(&scs[3])
                .and_then(|v| v.as_str().map(String::from))
                .as_deref(),
            Some("diamond")
        );
        assert_eq!(
            SharingAxis
                .json_value(&scs[3])
                .and_then(|v| v.as_str().map(String::from))
                .as_deref(),
            Some("node-local")
        );
        // Bad values are rejected, not defaulted.
        let args = parse("sweep --workflow no-such-shape");
        assert!(WorkflowAxis.parse_cli(&args, &mut m).is_err());
        let args = parse("sweep --sharing nfs");
        let err = SharingAxis.parse_cli(&args, &mut m).unwrap_err();
        assert!(
            format!("{err:#}").contains("s3|node-local|shared-fs"),
            "{err:#}"
        );
    }

    #[test]
    fn workflow_file_accepts_inline_objects_and_rejects_bad_specs() {
        let mut m = ScenarioMatrix::default();
        let inline = crate::workloads::dag::linear().to_json().pretty();
        let file =
            crate::json::parse(&format!(r#"{{"WORKFLOW": ["none", {inline}]}}"#)).unwrap();
        WorkflowAxis.parse_file(&file, &mut m).unwrap();
        assert_eq!(m.workflows.len(), 2);
        assert_eq!(
            format!("{:?}", m.workflows[1].as_ref().unwrap()),
            format!("{:?}", crate::workloads::dag::linear())
        );
        // A cyclic inline spec surfaces the typed validation error.
        let file = crate::json::parse(
            r#"{"WORKFLOW": [{"NAME": "loop",
                "JOBS": [{"NAME": "a", "OUTPUT_BYTES": 1}, {"NAME": "b", "OUTPUT_BYTES": 1}],
                "EDGES": [{"FROM": "a", "TO": "b", "ARTIFACT": "x"},
                          {"FROM": "b", "TO": "a", "ARTIFACT": "y"}]}]}"#,
        )
        .unwrap();
        let err = WorkflowAxis.parse_file(&file, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("cycle"), "{err:#}");
    }

    #[test]
    fn workflow_overlay_reaches_run_options() {
        use crate::config::{AppConfig, FleetSpec};
        use crate::coordinator::run::RunOptions;
        let m = ScenarioMatrix {
            workflows: vec![Some(crate::workloads::dag::fan_out_in())],
            sharings: vec![SharingMode::SharedFs],
            ..Default::default()
        };
        let sc = m.scenarios().remove(0);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let cell = sc.cell_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert_eq!(cell.opts.workflow.as_ref().unwrap().name, "fanout");
        assert_eq!(cell.opts.sharing, SharingMode::SharedFs);
        // `ds run` shares the axes (opts-owned, not file-owned).
        let cell = sc.run_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert!(cell.opts.workflow.is_some());
        // Flat scenarios leave the options untouched.
        let m = ScenarioMatrix::default();
        let sc = m.scenarios().remove(0);
        let cell = sc.cell_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert!(cell.opts.workflow.is_none());
        assert_eq!(cell.opts.sharing, SharingMode::S3Staging);
    }

    #[test]
    fn topology_axis_parses_shapes_and_labels_when_used() {
        let mut m = ScenarioMatrix::default();
        let args = parse("sweep --topology single,two-region --placement pack,spread");
        TopologyAxis.parse_cli(&args, &mut m).unwrap();
        PlacementAxis.parse_cli(&args, &mut m).unwrap();
        assert_eq!(m.topologies.len(), 2);
        assert!(m.topologies[0].is_none(), "single parses to no topology");
        assert_eq!(m.topologies[1].as_ref().unwrap().name, "two-region");
        assert_eq!(m.placements, vec![Placement::Pack, Placement::Spread]);
        let scs = m.scenarios();
        assert_eq!(scs.len(), 4);
        // Single-domain cells and pack cells stay unlabeled (historical
        // labels stable); engaged cells carry fragments and JSON keys.
        assert!(TopologyAxis.label(&scs[0]).is_none());
        assert!(PlacementAxis.label(&scs[0]).is_none());
        assert_eq!(PlacementAxis.label(&scs[1]).as_deref(), Some("place=spread"));
        assert_eq!(TopologyAxis.label(&scs[2]).as_deref(), Some("topo=two-region"));
        assert_eq!(
            TopologyAxis
                .json_value(&scs[3])
                .and_then(|v| v.as_str().map(String::from))
                .as_deref(),
            Some("two-region")
        );
        assert_eq!(
            PlacementAxis
                .json_value(&scs[3])
                .and_then(|v| v.as_str().map(String::from))
                .as_deref(),
            Some("spread")
        );
        // Bad values are rejected, not defaulted.
        let args = parse("sweep --topology no-such-shape");
        assert!(TopologyAxis.parse_cli(&args, &mut m).is_err());
        let args = parse("sweep --placement scatter");
        let err = PlacementAxis.parse_cli(&args, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("pack|spread|cheapest"), "{err:#}");
    }

    #[test]
    fn topology_file_accepts_inline_objects_and_rejects_bad_specs() {
        let mut m = ScenarioMatrix::default();
        let inline = ClusterTopology::shape("three-az").unwrap().render();
        let file =
            crate::json::parse(&format!(r#"{{"TOPOLOGY": ["single", {inline}]}}"#)).unwrap();
        TopologyAxis.parse_file(&file, &mut m).unwrap();
        assert_eq!(m.topologies.len(), 2);
        assert!(m.topologies[0].is_none());
        assert_eq!(
            format!("{:?}", m.topologies[1].as_ref().unwrap()),
            format!("{:?}", ClusterTopology::shape("three-az").unwrap())
        );
        // An inline spec with a fault on an undeclared domain surfaces
        // the typed validation error.
        let file = crate::json::parse(
            r#"{"TOPOLOGY": [{"NAME": "t",
                "DOMAINS": [{"name": "a", "region": "r1"}],
                "FAULTS": [{"kind": "az-outage", "domain": "ghost",
                            "at_min": 0, "duration_min": 10, "magnitude": 1.0}]}]}"#,
        )
        .unwrap();
        let err = TopologyAxis.parse_file(&file, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("ghost"), "{err:#}");
    }

    #[test]
    fn topology_overlay_reaches_run_options() {
        use crate::config::{AppConfig, FleetSpec};
        use crate::coordinator::run::RunOptions;
        let m = ScenarioMatrix {
            topologies: vec![ClusterTopology::shape("two-region")],
            placements: vec![Placement::Cheapest],
            ..Default::default()
        };
        let sc = m.scenarios().remove(0);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let cell = sc.cell_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert_eq!(cell.opts.topology.as_ref().unwrap().name, "two-region");
        assert_eq!(cell.opts.placement, Placement::Cheapest);
        // `ds run` shares the axes (opts-owned, not file-owned).
        let cell = sc.run_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert!(cell.opts.topology.is_some());
        // Single-domain scenarios leave the options untouched.
        let m = ScenarioMatrix::default();
        let sc = m.scenarios().remove(0);
        let cell = sc.cell_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert!(cell.opts.topology.is_none());
        assert_eq!(cell.opts.placement, Placement::Pack);
    }

    #[test]
    fn traffic_axis_parses_shapes_and_labels_when_used() {
        let mut m = ScenarioMatrix::default();
        let args = parse("sweep --traffic single,noisy-neighbor --queueing fifo,fair-share");
        TrafficAxis.parse_cli(&args, &mut m).unwrap();
        QueueingAxis.parse_cli(&args, &mut m).unwrap();
        assert_eq!(m.traffics.len(), 2);
        assert!(m.traffics[0].is_none(), "single parses to no traffic");
        assert_eq!(m.traffics[1].as_ref().unwrap().name, "noisy-neighbor");
        assert_eq!(
            m.queueings,
            vec![QueueingPolicy::Fifo, QueueingPolicy::FairShare]
        );
        let scs = m.scenarios();
        assert_eq!(scs.len(), 4);
        // Single-tenant cells and FIFO cells stay unlabeled (historical
        // labels stable); engaged cells carry fragments and JSON keys.
        assert!(TrafficAxis.label(&scs[0]).is_none());
        assert!(QueueingAxis.label(&scs[0]).is_none());
        assert_eq!(QueueingAxis.label(&scs[1]).as_deref(), Some("queue=fair-share"));
        assert_eq!(
            TrafficAxis.label(&scs[2]).as_deref(),
            Some("traffic=noisy-neighbor")
        );
        assert_eq!(
            TrafficAxis
                .json_value(&scs[3])
                .and_then(|v| v.as_str().map(String::from))
                .as_deref(),
            Some("noisy-neighbor")
        );
        assert_eq!(
            QueueingAxis
                .json_value(&scs[3])
                .and_then(|v| v.as_str().map(String::from))
                .as_deref(),
            Some("fair-share")
        );
        // Bad values are rejected, not defaulted.
        let args = parse("sweep --traffic no-such-shape");
        assert!(TrafficAxis.parse_cli(&args, &mut m).is_err());
        let args = parse("sweep --queueing lifo");
        let err = QueueingAxis.parse_cli(&args, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("fifo|fair-share|priority"), "{err:#}");
    }

    #[test]
    fn traffic_file_accepts_inline_objects_and_rejects_bad_specs() {
        let mut m = ScenarioMatrix::default();
        let inline = TrafficSpec::shape("two-tenant").unwrap().render();
        let file =
            crate::json::parse(&format!(r#"{{"TRAFFIC": ["single", {inline}]}}"#)).unwrap();
        TrafficAxis.parse_file(&file, &mut m).unwrap();
        assert_eq!(m.traffics.len(), 2);
        assert!(m.traffics[0].is_none());
        assert_eq!(m.traffics[1], TrafficSpec::shape("two-tenant"));
        // An inline spec with an arrival for an undeclared tenant
        // surfaces the typed validation error.
        let file = crate::json::parse(
            r#"{"TRAFFIC": [{"NAME": "t",
                "TENANTS": [{"name": "a", "jobs": 4, "weight": 1,
                             "priority": 0, "slo_wait_s": 60}],
                "ARRIVALS": [{"tenant": "ghost", "process": "poisson",
                              "rate_per_min": 1.0}]}]}"#,
        )
        .unwrap();
        let err = TrafficAxis.parse_file(&file, &mut m).unwrap_err();
        assert!(format!("{err:#}").contains("ghost"), "{err:#}");
    }

    #[test]
    fn traffic_overlay_reaches_run_options() {
        use crate::config::{AppConfig, FleetSpec};
        use crate::coordinator::run::RunOptions;
        let m = ScenarioMatrix {
            traffics: vec![TrafficSpec::shape("two-tenant")],
            queueings: vec![QueueingPolicy::Priority],
            ..Default::default()
        };
        let sc = m.scenarios().remove(0);
        let fleet = FleetSpec::template("us-east-1").unwrap();
        let cell = sc.cell_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert_eq!(cell.opts.traffic.as_ref().unwrap().name, "two-tenant");
        assert_eq!(cell.opts.queueing, QueueingPolicy::Priority);
        // `ds run` shares the axes (opts-owned, not file-owned).
        let cell = sc.run_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert!(cell.opts.traffic.is_some());
        // Single-tenant scenarios leave the options untouched.
        let m = ScenarioMatrix::default();
        let sc = m.scenarios().remove(0);
        let cell = sc.cell_inputs(&AppConfig::default(), &fleet, &RunOptions::default());
        assert!(cell.opts.traffic.is_none());
        assert_eq!(cell.opts.queueing, QueueingPolicy::Fifo);
    }

    #[test]
    fn instance_set_grammar_round_trips() {
        let set = parse_instance_set("m5.large+c5.xlarge:2").unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(render_instance_set(&set), "m5.large+c5.xlarge:2");
        assert!(parse_instance_set("").unwrap().is_empty());
        assert!(parse_instance_set("bad::::").is_err());
    }
}
