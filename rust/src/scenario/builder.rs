//! Fluent [`SweepPlan`] construction for library users.
//!
//! The builder mirrors the CLI's layering: *axes* you don't set
//! collapse to the same single-value defaults
//! (`ScenarioMatrix::defaults_from` on the plan's config), so a builder
//! plan, a flag-built plan, and a Sweep-file plan with the same axis
//! inputs are the same plan — the round-trip property test in
//! `rust/tests/scenario_api.rs` pins this against
//! [`SweepFile`](super::SweepFile).  One deliberate difference:
//! *seeds* left unset default to the matrix's single seed `[1]`, not
//! the CLI's four replicates — library studies choose their replication
//! explicitly ([`SweepPlanBuilder::seeds`] /
//! [`SweepPlanBuilder::seed_count`]).
//!
//! ```
//! use ds_rs::aws::ec2::Volatility;
//! use ds_rs::config::JobSpec;
//! use ds_rs::coordinator::sweep::SweepPlan;
//!
//! let plan = SweepPlan::builder()
//!     .jobs(JobSpec::plate("P", 4, 2, vec![]))
//!     .seeds([41, 42, 43])
//!     .machines([2, 4, 8])
//!     .volatilities([Volatility::Low, Volatility::High])
//!     .build()
//!     .unwrap();
//! assert_eq!(plan.matrix.scenarios().len(), 6);
//! assert_eq!(plan.matrix.cell_count(), 18);
//! ```

use anyhow::{anyhow, ensure, Result};

use crate::aws::ec2::{AllocationStrategy, InstanceSlot, Volatility};
use crate::aws::s3::dataplane::NetProfile;
use crate::coordinator::autoscale::ScalingMode;
use crate::config::{AppConfig, FleetSpec, JobSpec};
use crate::coordinator::run::RunOptions;
use crate::sim::SimTime;
use crate::topology::{ClusterTopology, Placement};
use crate::traffic::{QueueingPolicy, TrafficSpec};
use crate::workflow::{SharingMode, WorkflowSpec};
use crate::workloads::DurationModel;

use super::{ScenarioMatrix, SweepPlan};

/// Builder returned by [`SweepPlan::builder`].  Unset axes inherit the
/// defaults the CLI would use; `jobs(…)` is the only required call.
#[derive(Debug, Default)]
pub struct SweepPlanBuilder {
    cfg: Option<AppConfig>,
    jobs: Option<JobSpec>,
    fleet: Option<FleetSpec>,
    opts: Option<RunOptions>,
    seeds: Option<Vec<u64>>,
    machines: Option<Vec<u32>>,
    visibilities: Option<Vec<SimTime>>,
    volatilities: Option<Vec<Volatility>>,
    allocations: Option<Vec<AllocationStrategy>>,
    instance_sets: Option<Vec<Vec<InstanceSlot>>>,
    input_mbs: Option<Vec<f64>>,
    net_profiles: Option<Vec<NetProfile>>,
    scalings: Option<Vec<ScalingMode>>,
    scaling_targets: Option<Vec<f64>>,
    models: Option<Vec<DurationModel>>,
    workflows: Option<Vec<Option<WorkflowSpec>>>,
    sharings: Option<Vec<SharingMode>>,
    topologies: Option<Vec<Option<ClusterTopology>>>,
    placements: Option<Vec<Placement>>,
    traffics: Option<Vec<Option<TrafficSpec>>>,
    queueings: Option<Vec<QueueingPolicy>>,
}

impl SweepPlanBuilder {
    /// Base Config the scenario knobs are overlaid on (default:
    /// `AppConfig::default()`).
    pub fn config(mut self, cfg: AppConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// The Job file every cell replays (required).
    pub fn jobs(mut self, jobs: JobSpec) -> Self {
        self.jobs = Some(jobs);
        self
    }

    /// The Fleet file (default: built-in us-east-1 template).
    pub fn fleet(mut self, fleet: FleetSpec) -> Self {
        self.fleet = Some(fleet);
        self
    }

    /// Base run options; seed, volatility, and net profile are
    /// overridden per cell by the corresponding axes.
    pub fn options(mut self, opts: RunOptions) -> Self {
        self.opts = Some(opts);
        self
    }

    /// Explicit replicate seeds (default: `[1]`, like the matrix).
    pub fn seeds(mut self, seeds: impl IntoIterator<Item = u64>) -> Self {
        self.seeds = Some(seeds.into_iter().collect());
        self
    }

    /// `n` consecutive seeds starting at `base` (the CLI's
    /// `--seeds/--seed-base` shape).
    pub fn seed_count(self, n: u64, base: u64) -> Self {
        self.seeds((0..n.max(1)).map(|i| base + i))
    }

    /// `CLUSTER_MACHINES` axis (default: the config's value).
    pub fn machines(mut self, machines: impl IntoIterator<Item = u32>) -> Self {
        self.machines = Some(machines.into_iter().collect());
        self
    }

    /// `SQS_MESSAGE_VISIBILITY` axis in sim-time ms (default: the
    /// config's value).
    pub fn visibilities(mut self, visibilities: impl IntoIterator<Item = SimTime>) -> Self {
        self.visibilities = Some(visibilities.into_iter().collect());
        self
    }

    /// Market volatility axis (default: low).
    pub fn volatilities(mut self, volatilities: impl IntoIterator<Item = Volatility>) -> Self {
        self.volatilities = Some(volatilities.into_iter().collect());
        self
    }

    /// Fleet allocation-strategy axis (default: lowest-price).
    pub fn allocations(mut self, allocations: impl IntoIterator<Item = AllocationStrategy>) -> Self {
        self.allocations = Some(allocations.into_iter().collect());
        self
    }

    /// Instance-set axis; an empty set inherits the plan's fleet file /
    /// Config types (default: one empty set).
    pub fn instance_sets(
        mut self,
        sets: impl IntoIterator<Item = Vec<InstanceSlot>>,
    ) -> Self {
        self.instance_sets = Some(sets.into_iter().collect());
        self
    }

    /// Mean-input-MB axis; 0 = no data plane (default: `[0.0]`).
    pub fn input_mbs(mut self, input_mbs: impl IntoIterator<Item = f64>) -> Self {
        self.input_mbs = Some(input_mbs.into_iter().collect());
        self
    }

    /// Network-profile axis (default: standard).
    pub fn net_profiles(mut self, profiles: impl IntoIterator<Item = NetProfile>) -> Self {
        self.net_profiles = Some(profiles.into_iter().collect());
        self
    }

    /// Autoscaling policy axis (default: none, the fixed fleet).
    pub fn scalings(mut self, scalings: impl IntoIterator<Item = ScalingMode>) -> Self {
        self.scalings = Some(scalings.into_iter().collect());
        self
    }

    /// Scaling backlog-per-unit target axis (default: 4).
    pub fn scaling_targets(mut self, targets: impl IntoIterator<Item = f64>) -> Self {
        self.scaling_targets = Some(targets.into_iter().collect());
        self
    }

    /// Duration-model axis (default: one `DurationModel::default()`).
    pub fn models(mut self, models: impl IntoIterator<Item = DurationModel>) -> Self {
        self.models = Some(models.into_iter().collect());
        self
    }

    /// Convenience for the common case: one model per mean, sharing the
    /// default cv and failure knobs.
    pub fn job_mean_s(self, means: impl IntoIterator<Item = f64>) -> Self {
        self.models(means.into_iter().map(|mean_s| DurationModel {
            mean_s,
            ..Default::default()
        }))
    }

    /// DAG-workflow axis; `None` entries keep flat submission (default:
    /// `[None]`).
    pub fn workflows(
        mut self,
        workflows: impl IntoIterator<Item = Option<WorkflowSpec>>,
    ) -> Self {
        self.workflows = Some(workflows.into_iter().collect());
        self
    }

    /// Artifact sharing-mode axis for workflow cells (default: S3
    /// staging).
    pub fn sharings(mut self, sharings: impl IntoIterator<Item = SharingMode>) -> Self {
        self.sharings = Some(sharings.into_iter().collect());
        self
    }

    /// Cluster-topology axis; `None` entries are the implicit
    /// single-domain cluster (default: `[None]`).
    pub fn topologies(
        mut self,
        topologies: impl IntoIterator<Item = Option<ClusterTopology>>,
    ) -> Self {
        self.topologies = Some(topologies.into_iter().collect());
        self
    }

    /// Placement-policy axis for topology cells (default: pack).
    pub fn placements(mut self, placements: impl IntoIterator<Item = Placement>) -> Self {
        self.placements = Some(placements.into_iter().collect());
        self
    }

    /// Multi-tenant traffic axis; `None` entries keep the legacy single
    /// submitter (default: `[None]`).
    pub fn traffics(
        mut self,
        traffics: impl IntoIterator<Item = Option<TrafficSpec>>,
    ) -> Self {
        self.traffics = Some(traffics.into_iter().collect());
        self
    }

    /// Queueing-policy axis for traffic cells (default: FIFO).
    pub fn queueings(mut self, queueings: impl IntoIterator<Item = QueueingPolicy>) -> Self {
        self.queueings = Some(queueings.into_iter().collect());
        self
    }

    /// Assemble the plan.  Errors on missing jobs or any explicitly
    /// empty axis (an empty axis would silently erase the whole matrix).
    pub fn build(self) -> Result<SweepPlan> {
        let cfg = self.cfg.unwrap_or_default();
        let jobs = self
            .jobs
            .ok_or_else(|| anyhow!("SweepPlan::builder() requires jobs(…)"))?;
        let fleet = match self.fleet {
            Some(f) => f,
            None => FleetSpec::template("us-east-1").expect("builtin fleet template"),
        };
        let mut matrix = ScenarioMatrix::defaults_from(&cfg);
        macro_rules! set_axis {
            ($field:ident, $target:ident) => {
                if let Some(values) = self.$field {
                    ensure!(!values.is_empty(), "{} axis is empty", stringify!($field));
                    matrix.$target = values;
                }
            };
        }
        set_axis!(seeds, seeds);
        set_axis!(machines, cluster_machines);
        set_axis!(visibilities, visibilities);
        set_axis!(volatilities, volatilities);
        set_axis!(allocations, allocations);
        set_axis!(instance_sets, instance_sets);
        set_axis!(input_mbs, input_mbs);
        set_axis!(net_profiles, net_profiles);
        set_axis!(scalings, scalings);
        set_axis!(scaling_targets, scaling_targets);
        set_axis!(models, models);
        set_axis!(workflows, workflows);
        set_axis!(sharings, sharings);
        set_axis!(topologies, topologies);
        set_axis!(placements, placements);
        set_axis!(traffics, traffics);
        set_axis!(queueings, queueings);
        Ok(SweepPlan {
            base_cfg: cfg,
            jobs,
            fleet,
            base_opts: self.opts.unwrap_or_default(),
            matrix,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MINUTE;

    #[test]
    fn builder_defaults_match_the_cli_defaults() {
        let cfg = AppConfig {
            cluster_machines: 7,
            sqs_message_visibility: 3 * MINUTE,
            ..Default::default()
        };
        let plan = SweepPlan::builder()
            .config(cfg.clone())
            .jobs(JobSpec::plate("P", 2, 1, vec![]))
            .build()
            .unwrap();
        // Machines and visibility inherit the config, like `ds sweep`
        // without those flags.
        assert_eq!(plan.matrix.cluster_machines, vec![7]);
        assert_eq!(plan.matrix.visibilities, vec![3 * MINUTE]);
        assert_eq!(plan.matrix.scenarios().len(), 1);
    }

    #[test]
    fn builder_requires_jobs_and_rejects_empty_axes() {
        assert!(SweepPlan::builder().build().is_err());
        let err = SweepPlan::builder()
            .jobs(JobSpec::plate("P", 2, 1, vec![]))
            .machines(Vec::new())
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("machines"), "{err:#}");
    }

    #[test]
    fn seed_count_matches_cli_shape() {
        let plan = SweepPlan::builder()
            .jobs(JobSpec::plate("P", 2, 1, vec![]))
            .seed_count(4, 10)
            .build()
            .unwrap();
        assert_eq!(plan.matrix.seeds, vec![10, 11, 12, 13]);
    }
}
