//! Multi-tenant open-loop traffic: arrival processes, queueing disciplines,
//! and the per-tenant accounting that threads through RunReport and sweeps.
//!
//! A [`TrafficSpec`] is the seventh paper-style input file (after job spec,
//! fleet, workload model, data shape, workflow, and topology): a `NAME`, a
//! `TENANTS` table (jobs, weight, priority, SLO), and an `ARRIVALS` table
//! binding each tenant to an open-loop arrival process. Arrivals are drawn
//! from a dedicated fork of the run's seeded RNG, so the schedule is
//! deterministic and engine-invariant by construction.
//!
//! The coordinator pairs the spec with a [`QueueingPolicy`] — plain FIFO,
//! weighted deficit round-robin fair sharing, or strict priority tiers — and
//! reports a [`TenantBreakdown`] per run. See DESIGN.md §13.

use std::fmt;
use std::fs;

use crate::json::Value;
use crate::sim::rng::SimRng;
use crate::sim::clock::{SimTime, MINUTE};

/// Errors raised while parsing or validating a traffic spec.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum TrafficError {
    /// The spec text was not the JSON shape we expect.
    #[error("traffic spec: {0}")]
    Parse(String),
    /// A spec must declare at least one tenant.
    #[error("traffic '{traffic}' declares no tenants")]
    Empty { traffic: String },
    /// Tenant names must be unique within a spec.
    #[error("traffic '{traffic}' declares tenant '{tenant}' twice")]
    DuplicateTenant { traffic: String, tenant: String },
    /// Every tenant must bring at least one job.
    #[error("traffic '{traffic}' tenant '{tenant}' declares zero jobs")]
    NoJobs { traffic: String, tenant: String },
    /// Fair-share weights must be at least 1.
    #[error("traffic '{traffic}' tenant '{tenant}' declares weight 0")]
    BadWeight { traffic: String, tenant: String },
    /// An arrival row names a tenant the spec does not declare.
    #[error("traffic '{traffic}' arrival names unknown tenant '{tenant}'")]
    UnknownTenant { traffic: String, tenant: String },
    /// Each tenant gets exactly one arrival process.
    #[error("traffic '{traffic}' declares two arrival processes for tenant '{tenant}'")]
    DuplicateArrival { traffic: String, tenant: String },
    /// Each tenant gets exactly one arrival process.
    #[error("traffic '{traffic}' tenant '{tenant}' has no arrival process")]
    MissingArrival { traffic: String, tenant: String },
    /// An arrival process has out-of-range parameters.
    #[error("traffic '{traffic}' tenant '{tenant}' arrival is invalid: {why}")]
    BadProcess {
        traffic: String,
        tenant: String,
        why: String,
    },
    /// A `--traffic` value that is neither a shape name nor a readable file.
    #[error("{0}")]
    Unknown(String),
}

fn parse_err(msg: impl Into<String>) -> TrafficError {
    TrafficError::Parse(msg.into())
}

/// One tenant row: how many jobs it will submit over the run, its fair-share
/// weight, its strict-priority tier (higher wins), and its wait-time SLO.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    /// Tenant name, unique within the spec.
    pub name: String,
    /// Total jobs this tenant submits before its generator goes quiet.
    pub jobs: u64,
    /// Weighted-deficit-round-robin weight (fair-share policy); must be >= 1.
    pub weight: u64,
    /// Strict-priority tier (priority policy); higher tiers are served first.
    pub priority: u32,
    /// Wait-time SLO in seconds; jobs dispatched within it count as attained.
    pub slo_wait_s: u64,
}

/// One arrival row: the open-loop process that spaces a tenant's submissions.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalSpec {
    /// Name of the tenant this process drives.
    pub tenant: String,
    /// The inter-arrival process.
    pub process: ArrivalProcess,
}

/// An open-loop inter-arrival process. All rates are per simulated minute.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant rate: exponential inter-arrival
    /// times with mean `1 / rate_per_min` minutes.
    Poisson { rate_per_min: f64 },
    /// A sinusoidal day/night cycle sampled by thinning: the instantaneous
    /// rate swings from `base_per_min` (at t = 0) up to `peak_per_min` and
    /// back over each `period_min` minutes, averaging `(base + peak) / 2`.
    Diurnal {
        base_per_min: f64,
        peak_per_min: f64,
        period_min: u64,
    },
    /// Pareto inter-arrival times: `scale_min * U^(-1/alpha)` minutes, a
    /// heavy tail of quiet gaps punctuated by dense bursts. Mean exists only
    /// for `alpha > 1`.
    HeavyTailed { alpha: f64, scale_min: f64 },
}

impl ArrivalProcess {
    /// Short process-kind name used in spec files and reports.
    pub fn kind(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Diurnal { .. } => "diurnal",
            ArrivalProcess::HeavyTailed { .. } => "heavy-tailed",
        }
    }

    /// Long-run mean arrival rate in jobs per minute (0 when the mean
    /// diverges, i.e. a heavy tail with `alpha <= 1`).
    pub fn mean_rate_per_min(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate_per_min } => *rate_per_min,
            ArrivalProcess::Diurnal {
                base_per_min,
                peak_per_min,
                ..
            } => (base_per_min + peak_per_min) / 2.0,
            ArrivalProcess::HeavyTailed { alpha, scale_min } => {
                if *alpha > 1.0 {
                    (*alpha - 1.0) / (*alpha * *scale_min)
                } else {
                    0.0
                }
            }
        }
    }

    /// Draw the delay until the next arrival, in sim milliseconds (>= 1).
    ///
    /// `now` matters only for the diurnal process, whose instantaneous rate
    /// depends on the phase of the cycle; the other processes are stationary.
    pub fn next_delay_ms(&self, rng: &mut SimRng, now: SimTime) -> SimTime {
        let minutes = match self {
            ArrivalProcess::Poisson { rate_per_min } => rng.exp(1.0 / rate_per_min),
            ArrivalProcess::Diurnal {
                base_per_min,
                peak_per_min,
                period_min,
            } => {
                // Thinning against the constant peak envelope: propose
                // candidate points at the peak rate, accept each with
                // probability rate(t) / peak. rate(t) starts at base (t = 0)
                // and crests at peak half a period later.
                let mut t = now as f64 / MINUTE as f64;
                let mut dt = 0.0;
                loop {
                    let step = rng.exp(1.0 / peak_per_min);
                    dt += step;
                    t += step;
                    let phase = 2.0 * std::f64::consts::PI * (t / *period_min as f64);
                    let rate = base_per_min + (peak_per_min - base_per_min) * 0.5 * (1.0 - phase.cos());
                    if rng.f64() * peak_per_min <= rate {
                        break;
                    }
                }
                dt
            }
            ArrivalProcess::HeavyTailed { alpha, scale_min } => {
                let u = 1.0 - rng.f64();
                scale_min * u.powf(-1.0 / alpha)
            }
        };
        ((minutes * MINUTE as f64).round() as SimTime).max(1)
    }
}

/// A named multi-tenant traffic model: tenants plus their arrival processes.
///
/// Specs render to and parse from the same paper-style JSON file shape as the
/// other six input files, and the rendered bytes round-trip exactly:
///
/// ```
/// use ds_rs::traffic::TrafficSpec;
///
/// let spec = TrafficSpec::builder("demo")
///     .tenant("batch", 24, 2, 0, 900)
///     .tenant("interactive", 16, 1, 1, 120)
///     .poisson("batch", 2.0)
///     .diurnal("interactive", 0.5, 2.0, 120)
///     .build()
///     .unwrap();
///
/// let text = spec.render();
/// let back = TrafficSpec::parse(&text).unwrap();
/// assert_eq!(spec, back);
/// assert_eq!(text, back.render());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficSpec {
    /// Spec name, used in labels and reports.
    pub name: String,
    /// The tenant table.
    pub tenants: Vec<TenantSpec>,
    /// One arrival process per tenant.
    pub arrivals: Vec<ArrivalSpec>,
}

impl TrafficSpec {
    /// Built-in shape names accepted by [`TrafficSpec::resolve`].
    pub const SHAPES: [&'static str; 3] = ["single", "two-tenant", "noisy-neighbor"];

    /// Build a validated spec from parts.
    pub fn new(
        name: impl Into<String>,
        tenants: Vec<TenantSpec>,
        arrivals: Vec<ArrivalSpec>,
    ) -> Result<Self, TrafficError> {
        let spec = TrafficSpec {
            name: name.into(),
            tenants,
            arrivals,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Start a fluent builder.
    pub fn builder(name: impl Into<String>) -> TrafficBuilder {
        TrafficBuilder {
            name: name.into(),
            tenants: Vec::new(),
            arrivals: Vec::new(),
        }
    }

    /// Check the structural invariants: at least one tenant, unique names,
    /// positive job counts and weights, exactly one well-formed arrival
    /// process per tenant.
    pub fn validate(&self) -> Result<(), TrafficError> {
        if self.tenants.is_empty() {
            return Err(TrafficError::Empty {
                traffic: self.name.clone(),
            });
        }
        for (i, t) in self.tenants.iter().enumerate() {
            if self.tenants[..i].iter().any(|o| o.name == t.name) {
                return Err(TrafficError::DuplicateTenant {
                    traffic: self.name.clone(),
                    tenant: t.name.clone(),
                });
            }
            if t.jobs == 0 {
                return Err(TrafficError::NoJobs {
                    traffic: self.name.clone(),
                    tenant: t.name.clone(),
                });
            }
            if t.weight == 0 {
                return Err(TrafficError::BadWeight {
                    traffic: self.name.clone(),
                    tenant: t.name.clone(),
                });
            }
        }
        for (i, a) in self.arrivals.iter().enumerate() {
            if !self.tenants.iter().any(|t| t.name == a.tenant) {
                return Err(TrafficError::UnknownTenant {
                    traffic: self.name.clone(),
                    tenant: a.tenant.clone(),
                });
            }
            if self.arrivals[..i].iter().any(|o| o.tenant == a.tenant) {
                return Err(TrafficError::DuplicateArrival {
                    traffic: self.name.clone(),
                    tenant: a.tenant.clone(),
                });
            }
            let bad = |why: &str| TrafficError::BadProcess {
                traffic: self.name.clone(),
                tenant: a.tenant.clone(),
                why: why.to_string(),
            };
            match &a.process {
                ArrivalProcess::Poisson { rate_per_min } => {
                    if !(*rate_per_min > 0.0) {
                        return Err(bad("poisson rate must be positive"));
                    }
                }
                ArrivalProcess::Diurnal {
                    base_per_min,
                    peak_per_min,
                    period_min,
                } => {
                    if !(*peak_per_min > 0.0) {
                        return Err(bad("diurnal peak rate must be positive"));
                    }
                    if !(*base_per_min >= 0.0) {
                        return Err(bad("diurnal base rate must be non-negative"));
                    }
                    if *base_per_min > *peak_per_min {
                        return Err(bad("diurnal base rate must not exceed the peak"));
                    }
                    if *period_min == 0 {
                        return Err(bad("diurnal period must be positive"));
                    }
                }
                ArrivalProcess::HeavyTailed { alpha, scale_min } => {
                    if !(*alpha > 0.0) {
                        return Err(bad("pareto alpha must be positive"));
                    }
                    if !(*scale_min > 0.0) {
                        return Err(bad("pareto scale must be positive"));
                    }
                }
            }
        }
        for t in &self.tenants {
            if !self.arrivals.iter().any(|a| a.tenant == t.name) {
                return Err(TrafficError::MissingArrival {
                    traffic: self.name.clone(),
                    tenant: t.name.clone(),
                });
            }
        }
        Ok(())
    }

    /// Number of tenants.
    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    /// Total jobs across every tenant.
    pub fn total_jobs(&self) -> u64 {
        self.tenants.iter().map(|t| t.jobs).sum()
    }

    /// Index of the named tenant, if declared.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.tenants.iter().position(|t| t.name == name)
    }

    /// The arrival process of the tenant at `index`.
    pub fn process_of(&self, index: usize) -> &ArrivalProcess {
        let name = &self.tenants[index].name;
        &self
            .arrivals
            .iter()
            .find(|a| &a.tenant == name)
            .expect("validated spec has one arrival per tenant")
            .process
    }

    /// Render as the paper-style JSON object (NAME / TENANTS / ARRIVALS).
    pub fn to_json(&self) -> Value {
        let tenants: Vec<Value> = self
            .tenants
            .iter()
            .map(|t| {
                Value::obj()
                    .with("name", t.name.as_str())
                    .with("jobs", t.jobs)
                    .with("weight", t.weight)
                    .with("priority", t.priority as u64)
                    .with("slo_wait_s", t.slo_wait_s)
            })
            .collect();
        let arrivals: Vec<Value> = self
            .arrivals
            .iter()
            .map(|a| {
                let row = Value::obj()
                    .with("tenant", a.tenant.as_str())
                    .with("process", a.process.kind());
                match &a.process {
                    ArrivalProcess::Poisson { rate_per_min } => row.with("rate_per_min", *rate_per_min),
                    ArrivalProcess::Diurnal {
                        base_per_min,
                        peak_per_min,
                        period_min,
                    } => row
                        .with("base_per_min", *base_per_min)
                        .with("peak_per_min", *peak_per_min)
                        .with("period_min", *period_min),
                    ArrivalProcess::HeavyTailed { alpha, scale_min } => {
                        row.with("alpha", *alpha).with("scale_min", *scale_min)
                    }
                }
            })
            .collect();
        Value::obj()
            .with("NAME", self.name.as_str())
            .with("TENANTS", Value::Arr(tenants))
            .with("ARRIVALS", Value::Arr(arrivals))
    }

    /// Strictly decode a spec from its JSON object form. Unknown keys and
    /// parameters that do not belong to the declared process kind are errors.
    pub fn from_json(v: &Value) -> Result<Self, TrafficError> {
        let obj = v.as_obj().ok_or_else(|| parse_err("expected an object"))?;
        let mut name = None;
        let mut tenants: Option<Vec<TenantSpec>> = None;
        let mut arrivals: Option<Vec<ArrivalSpec>> = None;
        for (k, val) in obj {
            match k.as_str() {
                "NAME" => {
                    name = Some(
                        val.as_str()
                            .ok_or_else(|| parse_err("NAME must be a string"))?
                            .to_string(),
                    );
                }
                "TENANTS" => {
                    let rows = val
                        .as_arr()
                        .ok_or_else(|| parse_err("TENANTS must be an array"))?;
                    let mut out = Vec::with_capacity(rows.len());
                    for row in rows {
                        out.push(tenant_from_json(row)?);
                    }
                    tenants = Some(out);
                }
                "ARRIVALS" => {
                    let rows = val
                        .as_arr()
                        .ok_or_else(|| parse_err("ARRIVALS must be an array"))?;
                    let mut out = Vec::with_capacity(rows.len());
                    for row in rows {
                        out.push(arrival_from_json(row)?);
                    }
                    arrivals = Some(out);
                }
                other => return Err(parse_err(format!("unknown key '{other}'"))),
            }
        }
        let spec = TrafficSpec {
            name: name.ok_or_else(|| parse_err("missing NAME"))?,
            tenants: tenants.ok_or_else(|| parse_err("missing TENANTS"))?,
            arrivals: arrivals.ok_or_else(|| parse_err("missing ARRIVALS"))?,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from file text.
    pub fn parse(text: &str) -> Result<Self, TrafficError> {
        let v = crate::json::parse(text).map_err(|e| parse_err(e.to_string()))?;
        Self::from_json(&v)
    }

    /// Render as pretty-printed file text; `parse(render())` is bit-exact.
    pub fn render(&self) -> String {
        self.to_json().pretty()
    }

    /// The built-in shape with the given name, if any.
    pub fn shape(name: &str) -> Option<TrafficSpec> {
        let spec = match name {
            "single" => TrafficSpec::builder("single")
                .tenant("solo", 24, 1, 0, 600)
                .poisson("solo", 2.0)
                .build(),
            "two-tenant" => TrafficSpec::builder("two-tenant")
                .tenant("batch", 24, 2, 0, 900)
                .tenant("interactive", 16, 1, 1, 120)
                .poisson("batch", 2.0)
                .diurnal("interactive", 0.5, 2.0, 120)
                .build(),
            "noisy-neighbor" => TrafficSpec::builder("noisy-neighbor")
                .tenant("victim", 24, 1, 1, 300)
                .tenant("noisy", 96, 1, 0, 3600)
                .poisson("victim", 1.0)
                .heavy_tailed("noisy", 1.5, 0.1)
                .build(),
            _ => return None,
        };
        Some(spec.expect("built-in shapes validate"))
    }

    /// Resolve a `--traffic` value: a built-in shape name, or a path to a
    /// readable TRAFFIC file.
    pub fn resolve(value: &str) -> Result<TrafficSpec, TrafficError> {
        if let Some(spec) = TrafficSpec::shape(value) {
            return Ok(spec);
        }
        match fs::read_to_string(value) {
            Ok(text) => TrafficSpec::parse(&text),
            Err(_) => Err(TrafficError::Unknown(format!(
                "unknown traffic '{value}': expected a shape name — single, two-tenant, \
                 noisy-neighbor — or a readable TRAFFIC file path"
            ))),
        }
    }
}

fn tenant_from_json(v: &Value) -> Result<TenantSpec, TrafficError> {
    let obj = v
        .as_obj()
        .ok_or_else(|| parse_err("TENANTS rows must be objects"))?;
    let mut name = None;
    let mut jobs = None;
    let mut weight = None;
    let mut priority = None;
    let mut slo_wait_s = None;
    for (k, val) in obj {
        match k.as_str() {
            "name" => {
                name = Some(
                    val.as_str()
                        .ok_or_else(|| parse_err("tenant name must be a string"))?
                        .to_string(),
                );
            }
            "jobs" => {
                jobs = Some(
                    val.as_u64()
                        .ok_or_else(|| parse_err("tenant jobs must be an integer"))?,
                );
            }
            "weight" => {
                weight = Some(
                    val.as_u64()
                        .ok_or_else(|| parse_err("tenant weight must be an integer"))?,
                );
            }
            "priority" => {
                let p = val
                    .as_u64()
                    .ok_or_else(|| parse_err("tenant priority must be an integer"))?;
                priority = Some(u32::try_from(p).map_err(|_| parse_err("tenant priority too large"))?);
            }
            "slo_wait_s" => {
                slo_wait_s = Some(
                    val.as_u64()
                        .ok_or_else(|| parse_err("tenant slo_wait_s must be an integer"))?,
                );
            }
            other => return Err(parse_err(format!("unknown tenant key '{other}'"))),
        }
    }
    Ok(TenantSpec {
        name: name.ok_or_else(|| parse_err("tenant row missing name"))?,
        jobs: jobs.ok_or_else(|| parse_err("tenant row missing jobs"))?,
        weight: weight.ok_or_else(|| parse_err("tenant row missing weight"))?,
        priority: priority.ok_or_else(|| parse_err("tenant row missing priority"))?,
        slo_wait_s: slo_wait_s.ok_or_else(|| parse_err("tenant row missing slo_wait_s"))?,
    })
}

fn arrival_from_json(v: &Value) -> Result<ArrivalSpec, TrafficError> {
    let obj = v
        .as_obj()
        .ok_or_else(|| parse_err("ARRIVALS rows must be objects"))?;
    let mut tenant = None;
    let mut kind = None;
    let mut rate_per_min = None;
    let mut base_per_min = None;
    let mut peak_per_min = None;
    let mut period_min = None;
    let mut alpha = None;
    let mut scale_min = None;
    for (k, val) in obj {
        match k.as_str() {
            "tenant" => {
                tenant = Some(
                    val.as_str()
                        .ok_or_else(|| parse_err("arrival tenant must be a string"))?
                        .to_string(),
                );
            }
            "process" => {
                kind = Some(
                    val.as_str()
                        .ok_or_else(|| parse_err("arrival process must be a string"))?
                        .to_string(),
                );
            }
            "rate_per_min" => {
                rate_per_min = Some(
                    val.as_f64()
                        .ok_or_else(|| parse_err("rate_per_min must be a number"))?,
                );
            }
            "base_per_min" => {
                base_per_min = Some(
                    val.as_f64()
                        .ok_or_else(|| parse_err("base_per_min must be a number"))?,
                );
            }
            "peak_per_min" => {
                peak_per_min = Some(
                    val.as_f64()
                        .ok_or_else(|| parse_err("peak_per_min must be a number"))?,
                );
            }
            "period_min" => {
                period_min = Some(
                    val.as_u64()
                        .ok_or_else(|| parse_err("period_min must be an integer"))?,
                );
            }
            "alpha" => {
                alpha = Some(
                    val.as_f64()
                        .ok_or_else(|| parse_err("alpha must be a number"))?,
                );
            }
            "scale_min" => {
                scale_min = Some(
                    val.as_f64()
                        .ok_or_else(|| parse_err("scale_min must be a number"))?,
                );
            }
            other => return Err(parse_err(format!("unknown arrival key '{other}'"))),
        }
    }
    let tenant = tenant.ok_or_else(|| parse_err("arrival row missing tenant"))?;
    let kind = kind.ok_or_else(|| parse_err("arrival row missing process"))?;
    let stray = |params: &[(&str, bool)]| -> Result<(), TrafficError> {
        for (name, present) in params {
            if *present {
                return Err(parse_err(format!(
                    "arrival key '{name}' does not belong to process '{kind}'"
                )));
            }
        }
        Ok(())
    };
    let process = match kind.as_str() {
        "poisson" => {
            stray(&[
                ("base_per_min", base_per_min.is_some()),
                ("peak_per_min", peak_per_min.is_some()),
                ("period_min", period_min.is_some()),
                ("alpha", alpha.is_some()),
                ("scale_min", scale_min.is_some()),
            ])?;
            ArrivalProcess::Poisson {
                rate_per_min: rate_per_min
                    .ok_or_else(|| parse_err("poisson arrival missing rate_per_min"))?,
            }
        }
        "diurnal" => {
            stray(&[
                ("rate_per_min", rate_per_min.is_some()),
                ("alpha", alpha.is_some()),
                ("scale_min", scale_min.is_some()),
            ])?;
            ArrivalProcess::Diurnal {
                base_per_min: base_per_min
                    .ok_or_else(|| parse_err("diurnal arrival missing base_per_min"))?,
                peak_per_min: peak_per_min
                    .ok_or_else(|| parse_err("diurnal arrival missing peak_per_min"))?,
                period_min: period_min
                    .ok_or_else(|| parse_err("diurnal arrival missing period_min"))?,
            }
        }
        "heavy-tailed" => {
            stray(&[
                ("rate_per_min", rate_per_min.is_some()),
                ("base_per_min", base_per_min.is_some()),
                ("peak_per_min", peak_per_min.is_some()),
                ("period_min", period_min.is_some()),
            ])?;
            ArrivalProcess::HeavyTailed {
                alpha: alpha.ok_or_else(|| parse_err("heavy-tailed arrival missing alpha"))?,
                scale_min: scale_min
                    .ok_or_else(|| parse_err("heavy-tailed arrival missing scale_min"))?,
            }
        }
        other => {
            return Err(parse_err(format!(
                "unknown arrival process '{other}': expected poisson, diurnal, or heavy-tailed"
            )))
        }
    };
    Ok(ArrivalSpec { tenant, process })
}

/// Fluent builder for [`TrafficSpec`].
#[derive(Debug, Clone)]
pub struct TrafficBuilder {
    name: String,
    tenants: Vec<TenantSpec>,
    arrivals: Vec<ArrivalSpec>,
}

impl TrafficBuilder {
    /// Add a tenant row.
    pub fn tenant(
        mut self,
        name: impl Into<String>,
        jobs: u64,
        weight: u64,
        priority: u32,
        slo_wait_s: u64,
    ) -> Self {
        self.tenants.push(TenantSpec {
            name: name.into(),
            jobs,
            weight,
            priority,
            slo_wait_s,
        });
        self
    }

    /// Bind a Poisson arrival process to a tenant.
    pub fn poisson(mut self, tenant: impl Into<String>, rate_per_min: f64) -> Self {
        self.arrivals.push(ArrivalSpec {
            tenant: tenant.into(),
            process: ArrivalProcess::Poisson { rate_per_min },
        });
        self
    }

    /// Bind a diurnal arrival process to a tenant.
    pub fn diurnal(
        mut self,
        tenant: impl Into<String>,
        base_per_min: f64,
        peak_per_min: f64,
        period_min: u64,
    ) -> Self {
        self.arrivals.push(ArrivalSpec {
            tenant: tenant.into(),
            process: ArrivalProcess::Diurnal {
                base_per_min,
                peak_per_min,
                period_min,
            },
        });
        self
    }

    /// Bind a heavy-tailed (Pareto) arrival process to a tenant.
    pub fn heavy_tailed(
        mut self,
        tenant: impl Into<String>,
        alpha: f64,
        scale_min: f64,
    ) -> Self {
        self.arrivals.push(ArrivalSpec {
            tenant: tenant.into(),
            process: ArrivalProcess::HeavyTailed { alpha, scale_min },
        });
        self
    }

    /// Validate and produce the spec.
    pub fn build(self) -> Result<TrafficSpec, TrafficError> {
        TrafficSpec::new(self.name, self.tenants, self.arrivals)
    }
}

/// How the coordinator picks among tenants' queued messages.
///
/// ```
/// use ds_rs::traffic::QueueingPolicy;
///
/// assert_eq!(QueueingPolicy::parse("fair-share"), Some(QueueingPolicy::FairShare));
/// assert_eq!(QueueingPolicy::FairShare.name(), "fair-share");
/// assert_eq!(QueueingPolicy::default(), QueueingPolicy::Fifo);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueingPolicy {
    /// Serve messages strictly in enqueue order, tenant-blind.
    #[default]
    Fifo,
    /// Weighted deficit round-robin across tenants: each tenant spends
    /// credits equal to its weight per round, so a backlogged tenant cannot
    /// starve the others.
    FairShare,
    /// Strict priority tiers: a higher-priority tenant's messages always go
    /// first; FIFO order within a tier.
    Priority,
}

impl QueueingPolicy {
    /// Every policy, in declaration order.
    pub const ALL: [QueueingPolicy; 3] = [
        QueueingPolicy::Fifo,
        QueueingPolicy::FairShare,
        QueueingPolicy::Priority,
    ];

    /// Stable lowercase name used in flags, labels, and JSON.
    pub fn name(&self) -> &'static str {
        match self {
            QueueingPolicy::Fifo => "fifo",
            QueueingPolicy::FairShare => "fair-share",
            QueueingPolicy::Priority => "priority",
        }
    }

    /// Parse a policy name.
    pub fn parse(s: &str) -> Option<QueueingPolicy> {
        QueueingPolicy::ALL.iter().copied().find(|p| p.name() == s)
    }
}

impl fmt::Display for QueueingPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Pure per-tenant dispatch arithmetic for the queueing policies.
///
/// `choose` is handed, for each tenant, the queue position of its
/// head-of-line visible message (`None` when the tenant has nothing queued)
/// and returns the position to serve next. The struct owns the mutable
/// fair-share state (credits and the round-robin pointer) so the decision is
/// deterministic given the call sequence.
#[derive(Debug, Clone)]
pub struct DispatchState {
    policy: QueueingPolicy,
    weights: Vec<u64>,
    priorities: Vec<u32>,
    credits: Vec<u64>,
    rr: usize,
}

impl DispatchState {
    /// Build dispatch state for a spec under a policy.
    pub fn new(spec: &TrafficSpec, policy: QueueingPolicy) -> DispatchState {
        let weights: Vec<u64> = spec.tenants.iter().map(|t| t.weight).collect();
        let priorities = spec.tenants.iter().map(|t| t.priority).collect();
        let credits = weights.clone();
        DispatchState {
            policy,
            weights,
            priorities,
            credits,
            rr: 0,
        }
    }

    /// Pick the queue position to serve, given each tenant's head-of-line
    /// position. Returns `None` only when no tenant has a message queued.
    pub fn choose(&mut self, heads: &[Option<usize>]) -> Option<usize> {
        match self.policy {
            QueueingPolicy::Fifo => heads.iter().flatten().copied().min(),
            QueueingPolicy::Priority => {
                let top = heads
                    .iter()
                    .enumerate()
                    .filter(|(_, h)| h.is_some())
                    .map(|(t, _)| self.priorities[t])
                    .max()?;
                heads
                    .iter()
                    .enumerate()
                    .filter(|(t, h)| h.is_some() && self.priorities[*t] == top)
                    .filter_map(|(_, h)| *h)
                    .min()
            }
            QueueingPolicy::FairShare => {
                if heads.iter().all(|h| h.is_none()) {
                    return None;
                }
                let n = heads.len();
                // Scan from the round-robin pointer for a backlogged tenant
                // with credit; if a full pass finds none, refill everyone's
                // credits from their weights and scan once more.
                for _ in 0..=1 {
                    for k in 0..n {
                        let t = (self.rr + k) % n;
                        if let Some(pos) = heads[t] {
                            if self.credits[t] > 0 {
                                self.credits[t] -= 1;
                                self.rr = t;
                                return Some(pos);
                            }
                        }
                    }
                    self.credits.copy_from_slice(&self.weights);
                    self.rr = (self.rr + 1) % n;
                }
                heads.iter().flatten().copied().min()
            }
        }
    }
}

/// Per-tenant outcome slice inside a [`TenantBreakdown`].
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSlice {
    /// Tenant name.
    pub tenant: String,
    /// Fair-share weight, echoed from the spec.
    pub weight: u64,
    /// Priority tier, echoed from the spec.
    pub priority: u32,
    /// Jobs this tenant submitted onto the queue.
    pub submitted: u64,
    /// Jobs that completed.
    pub completed: u64,
    /// Median queue wait (enqueue → dispatch) in ms.
    pub wait_p50_ms: u64,
    /// 95th-percentile queue wait in ms.
    pub wait_p95_ms: u64,
    /// The tenant's SLO target in ms.
    pub slo_target_ms: u64,
    /// Completed jobs whose wait met the SLO target.
    pub slo_attained: u64,
    /// This tenant's share of the run's bill, by completed-job fraction.
    pub billed_usd: f64,
}

/// Per-tenant rollup attached to every run report. Traffic-free runs carry
/// the default ("single"/"fifo", no tenant rows) and emit nothing extra in
/// summaries or JSON.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantBreakdown {
    /// Traffic spec name ("single" for traffic-free runs).
    pub traffic: String,
    /// Queueing policy name.
    pub queueing: String,
    /// One slice per tenant, in spec order.
    pub tenants: Vec<TenantSlice>,
}

impl Default for TenantBreakdown {
    fn default() -> Self {
        TenantBreakdown {
            traffic: "single".to_string(),
            queueing: "fifo".to_string(),
            tenants: Vec::new(),
        }
    }
}

/// Nearest-rank percentile over an ascending-sorted slice of waits; 0 when
/// empty. Matches the rounding used by `Aggregate::from_values`.
pub fn wait_percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> TrafficSpec {
        TrafficSpec::builder("demo")
            .tenant("batch", 24, 2, 0, 900)
            .tenant("interactive", 16, 1, 1, 120)
            .poisson("batch", 2.0)
            .diurnal("interactive", 0.5, 2.0, 120)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_and_queries() {
        let spec = demo();
        assert_eq!(spec.tenant_count(), 2);
        assert_eq!(spec.total_jobs(), 40);
        assert_eq!(spec.index_of("interactive"), Some(1));
        assert_eq!(spec.index_of("nobody"), None);
        assert_eq!(spec.process_of(0).kind(), "poisson");
        assert_eq!(spec.process_of(1).kind(), "diurnal");
        assert!((spec.process_of(0).mean_rate_per_min() - 2.0).abs() < 1e-12);
        assert!((spec.process_of(1).mean_rate_per_min() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let empty = TrafficSpec {
            name: "e".into(),
            tenants: vec![],
            arrivals: vec![],
        };
        assert_eq!(
            empty.validate(),
            Err(TrafficError::Empty { traffic: "e".into() })
        );

        let dup = TrafficSpec::builder("d")
            .tenant("a", 1, 1, 0, 60)
            .tenant("a", 1, 1, 0, 60)
            .poisson("a", 1.0)
            .build();
        assert_eq!(
            dup,
            Err(TrafficError::DuplicateTenant {
                traffic: "d".into(),
                tenant: "a".into()
            })
        );

        let no_jobs = TrafficSpec::builder("n")
            .tenant("a", 0, 1, 0, 60)
            .poisson("a", 1.0)
            .build();
        assert_eq!(
            no_jobs,
            Err(TrafficError::NoJobs {
                traffic: "n".into(),
                tenant: "a".into()
            })
        );

        let bad_weight = TrafficSpec::builder("w")
            .tenant("a", 1, 0, 0, 60)
            .poisson("a", 1.0)
            .build();
        assert_eq!(
            bad_weight,
            Err(TrafficError::BadWeight {
                traffic: "w".into(),
                tenant: "a".into()
            })
        );

        let unknown = TrafficSpec::builder("u")
            .tenant("a", 1, 1, 0, 60)
            .poisson("a", 1.0)
            .poisson("ghost", 1.0)
            .build();
        assert_eq!(
            unknown,
            Err(TrafficError::UnknownTenant {
                traffic: "u".into(),
                tenant: "ghost".into()
            })
        );

        let dup_arrival = TrafficSpec::builder("da")
            .tenant("a", 1, 1, 0, 60)
            .poisson("a", 1.0)
            .poisson("a", 2.0)
            .build();
        assert_eq!(
            dup_arrival,
            Err(TrafficError::DuplicateArrival {
                traffic: "da".into(),
                tenant: "a".into()
            })
        );

        let missing = TrafficSpec::builder("m")
            .tenant("a", 1, 1, 0, 60)
            .tenant("b", 1, 1, 0, 60)
            .poisson("a", 1.0)
            .build();
        assert_eq!(
            missing,
            Err(TrafficError::MissingArrival {
                traffic: "m".into(),
                tenant: "b".into()
            })
        );

        let bad_rate = TrafficSpec::builder("r")
            .tenant("a", 1, 1, 0, 60)
            .poisson("a", 0.0)
            .build();
        assert!(matches!(bad_rate, Err(TrafficError::BadProcess { .. })));

        let bad_diurnal = TrafficSpec::builder("di")
            .tenant("a", 1, 1, 0, 60)
            .diurnal("a", 3.0, 2.0, 60)
            .build();
        assert!(matches!(bad_diurnal, Err(TrafficError::BadProcess { .. })));

        let bad_period = TrafficSpec::builder("p")
            .tenant("a", 1, 1, 0, 60)
            .diurnal("a", 0.5, 2.0, 0)
            .build();
        assert!(matches!(bad_period, Err(TrafficError::BadProcess { .. })));

        let bad_alpha = TrafficSpec::builder("al")
            .tenant("a", 1, 1, 0, 60)
            .heavy_tailed("a", 0.0, 0.1)
            .build();
        assert!(matches!(bad_alpha, Err(TrafficError::BadProcess { .. })));
    }

    #[test]
    fn render_parse_round_trip_is_bit_identical() {
        for shape in TrafficSpec::SHAPES {
            let spec = match TrafficSpec::shape(shape) {
                Some(s) => s,
                None => continue,
            };
            let text = spec.render();
            let back = TrafficSpec::parse(&text).unwrap();
            assert_eq!(spec, back, "{shape} round trip changed the spec");
            assert_eq!(text, back.render(), "{shape} render is not bit-stable");
        }
        let spec = demo();
        let text = spec.render();
        assert_eq!(TrafficSpec::parse(&text).unwrap().render(), text);
    }

    #[test]
    fn parse_rejects_unknown_keys_and_bad_shapes() {
        assert!(matches!(
            TrafficSpec::parse("[1, 2]"),
            Err(TrafficError::Parse(_))
        ));
        assert!(matches!(
            TrafficSpec::parse(r#"{"NAME": "x", "WAT": 1}"#),
            Err(TrafficError::Parse(_))
        ));
        // A poisson arrival must not smuggle diurnal parameters.
        let mixed = r#"{
            "NAME": "x",
            "TENANTS": [{"name": "a", "jobs": 1, "weight": 1, "priority": 0, "slo_wait_s": 60}],
            "ARRIVALS": [{"tenant": "a", "process": "poisson", "rate_per_min": 1.0, "period_min": 60}]
        }"#;
        assert!(matches!(TrafficSpec::parse(mixed), Err(TrafficError::Parse(_))));
        let bad_kind = r#"{
            "NAME": "x",
            "TENANTS": [{"name": "a", "jobs": 1, "weight": 1, "priority": 0, "slo_wait_s": 60}],
            "ARRIVALS": [{"tenant": "a", "process": "uniform", "rate_per_min": 1.0}]
        }"#;
        assert!(matches!(TrafficSpec::parse(bad_kind), Err(TrafficError::Parse(_))));
        assert!(matches!(
            TrafficSpec::resolve("no-such-shape-or-file"),
            Err(TrafficError::Unknown(_))
        ));
    }

    #[test]
    fn shapes_resolve_and_validate() {
        for shape in TrafficSpec::SHAPES {
            let spec = TrafficSpec::resolve(shape).unwrap();
            assert_eq!(spec.name, shape);
            spec.validate().unwrap();
            assert!(spec.total_jobs() > 0);
        }
        assert_eq!(TrafficSpec::shape("single").unwrap().tenant_count(), 1);
        assert_eq!(TrafficSpec::shape("noisy-neighbor").unwrap().tenant_count(), 2);
    }

    #[test]
    fn policy_names_round_trip() {
        for p in QueueingPolicy::ALL {
            assert_eq!(QueueingPolicy::parse(p.name()), Some(p));
            assert_eq!(format!("{p}"), p.name());
        }
        assert_eq!(QueueingPolicy::parse("lifo"), None);
        assert_eq!(QueueingPolicy::default(), QueueingPolicy::Fifo);
    }

    #[test]
    fn breakdown_default_is_the_flat_run() {
        let b = TenantBreakdown::default();
        assert_eq!(b.traffic, "single");
        assert_eq!(b.queueing, "fifo");
        assert!(b.tenants.is_empty());
    }

    #[test]
    fn fifo_dispatch_serves_the_oldest_message() {
        let spec = demo();
        let mut d = DispatchState::new(&spec, QueueingPolicy::Fifo);
        assert_eq!(d.choose(&[Some(3), Some(1)]), Some(1));
        assert_eq!(d.choose(&[Some(0), None]), Some(0));
        assert_eq!(d.choose(&[None, None]), None);
    }

    #[test]
    fn priority_dispatch_serves_higher_tiers_first() {
        // demo(): batch has priority 0, interactive priority 1.
        let spec = demo();
        let mut d = DispatchState::new(&spec, QueueingPolicy::Priority);
        assert_eq!(d.choose(&[Some(0), Some(5)]), Some(5));
        assert_eq!(d.choose(&[Some(0), None]), Some(0));
        assert_eq!(d.choose(&[None, None]), None);
    }

    #[test]
    fn fair_share_dispatch_honors_weights() {
        // demo(): batch weight 2, interactive weight 1 → 2:1 service ratio.
        let spec = demo();
        let mut d = DispatchState::new(&spec, QueueingPolicy::FairShare);
        let mut served = [0u64, 0u64];
        for _ in 0..300 {
            // Both tenants always backlogged; positions are arbitrary but
            // distinct so we can tell who got served.
            let pick = d.choose(&[Some(0), Some(1)]).unwrap();
            served[pick] += 1;
        }
        assert_eq!(served[0], 200, "weight-2 tenant should get 2/3 of service");
        assert_eq!(served[1], 100, "weight-1 tenant should get 1/3 of service");
    }

    #[test]
    fn fair_share_dispatch_falls_through_to_backlogged_tenant() {
        let spec = demo();
        let mut d = DispatchState::new(&spec, QueueingPolicy::FairShare);
        // Only one tenant has work: it must be served every time, credits
        // refilling as needed.
        for _ in 0..10 {
            assert_eq!(d.choose(&[None, Some(4)]), Some(4));
        }
        assert_eq!(d.choose(&[None, None]), None);
    }

    #[test]
    fn arrival_draws_are_seed_stable_and_positive() {
        for process in [
            ArrivalProcess::Poisson { rate_per_min: 2.0 },
            ArrivalProcess::Diurnal {
                base_per_min: 0.5,
                peak_per_min: 2.0,
                period_min: 120,
            },
            ArrivalProcess::HeavyTailed {
                alpha: 1.5,
                scale_min: 0.1,
            },
        ] {
            let draw = |seed: u64| -> Vec<SimTime> {
                let mut rng = SimRng::new(seed);
                let mut now: SimTime = 0;
                let mut out = Vec::new();
                for _ in 0..64 {
                    let d = process.next_delay_ms(&mut rng, now);
                    assert!(d >= 1, "{} drew a non-positive delay", process.kind());
                    now += d;
                    out.push(d);
                }
                out
            };
            assert_eq!(draw(7), draw(7), "{} is not seed-stable", process.kind());
            assert_ne!(draw(7), draw(8), "{} ignores its seed", process.kind());
        }
    }

    #[test]
    fn wait_percentile_matches_nearest_rank() {
        assert_eq!(wait_percentile(&[], 0.95), 0);
        assert_eq!(wait_percentile(&[42], 0.5), 42);
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(wait_percentile(&v, 0.5), 50);
        assert_eq!(wait_percentile(&v, 0.95), 95);
        assert_eq!(wait_percentile(&v, 1.0), 100);
    }
}
