//! Test substrate: the mini property-testing harness plus the shared
//! fixture layer.
//!
//! [`forall`] runs a property over `n` generated cases from a seeded
//! [`SimRng`]; on failure it reports the seed and case index so the case
//! replays deterministically.  Generators are plain closures over the
//! RNG — no shrinking, but failures are reproducible, which is what
//! matters for CI.
//!
//! [`fixtures`] holds the canonical config/fleet/job/executor builders
//! that used to be copy-pasted across the integration suites — one
//! definition of the "small test rig", so a knob change (or a new
//! required field) is one edit, not seven.
//!
//! [`shard_exec`] holds the fault-injecting executor double the
//! sharded-sweep supervision tests script their worker failures with.

use crate::sim::SimRng;

pub mod fixtures {
    //! Canonical builders for the small simulated rig the test suites
    //! share.  Everything returns plain owned values; override fields
    //! after construction when a test needs a different knob
    //! (`let mut cfg = quick_cfg(2); cfg.sqs_message_visibility = …`).

    use crate::cli::Args;
    use crate::config::{AppConfig, FleetSpec, JobSpec};
    use crate::sim::MINUTE;
    use crate::workloads::{DurationModel, ModeledExecutor};

    /// The canonical small rig: `machines` m5.xlarge machines, 2
    /// containers × 2 cores each, $0.10/h bid, 5-minute visibility.
    pub fn quick_cfg(machines: u32) -> AppConfig {
        AppConfig {
            cluster_machines: machines,
            tasks_per_machine: 2,
            docker_cores: 2,
            machine_types: vec!["m5.xlarge".into()],
            machine_price: 0.10,
            sqs_message_visibility: 5 * MINUTE,
            ..Default::default()
        }
    }

    /// The built-in us-east-1 template Fleet file.
    pub fn template_fleet() -> FleetSpec {
        FleetSpec::template("us-east-1").expect("builtin fleet template")
    }

    /// A synthetic plate named `P1`: `wells × sites` zero-data jobs.
    pub fn plate_jobs(wells: u32, sites: u32) -> JobSpec {
        JobSpec::plate("P1", wells, sites, vec![])
    }

    /// Modeled executor with the canonical 0.2 duration cv and no
    /// failure modes.
    pub fn modeled(mean_s: f64) -> ModeledExecutor {
        shaped(mean_s, 0.2, 0.0, 0.0)
    }

    /// Modeled executor with explicit shape knobs.
    pub fn shaped(mean_s: f64, cv: f64, stall_prob: f64, fail_prob: f64) -> ModeledExecutor {
        ModeledExecutor {
            model: DurationModel {
                mean_s,
                cv,
                stall_prob,
                fail_prob,
            },
            ..Default::default()
        }
    }

    /// Parse a whitespace-separated command line (the suites' shared
    /// `cli()` helper).
    pub fn args(line: &str) -> Args {
        Args::parse(line.split_whitespace().map(String::from))
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn builders_have_the_canonical_shape() {
            let cfg = quick_cfg(3);
            assert_eq!(cfg.cluster_machines, 3);
            assert_eq!(cfg.tasks_per_machine, 2);
            assert_eq!(cfg.docker_cores, 2);
            assert_eq!(cfg.sqs_message_visibility, 5 * MINUTE);
            cfg.validate().expect("canonical config validates");
            template_fleet().validate().expect("template validates");
            assert_eq!(plate_jobs(4, 2).groups.len(), 8);
            assert_eq!(modeled(30.0).model.cv, 0.2);
            let a = args("sweep --machines 2,4 --json");
            assert!(a.flag("json"));
            assert_eq!(a.get("machines"), Some("2,4"));
        }
    }
}

pub mod shard_exec {
    //! Fault-injecting [`ShardExecutor`] double for the sharded-sweep
    //! supervision tests: wrap a real executor, script exactly which
    //! (shard, attempt) pairs misbehave and how, and assert the parent
    //! retries or fails typed — without OS processes or signals.

    use std::collections::HashMap;
    use std::sync::Mutex;
    use std::time::Duration;

    use crate::coordinator::shard::{ExecFailure, ShardExecutor, WIRE_VERSION};
    use crate::json::Value;

    /// One scripted misbehavior.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Fault {
        /// The worker dies mid-shard (signal-style crash, stderr
        /// attached).
        Kill,
        /// The worker prints bytes that are not JSON at all.
        Garbage,
        /// The worker's real output is cut off mid-stream (pipe closed
        /// early, partial write).
        Truncate,
        /// The worker hangs past the executor's timeout.
        Hang,
        /// The worker answers with a result envelope from a future wire
        /// version.
        VersionBump,
    }

    /// Wraps an inner executor and applies the scripted [`Fault`] when
    /// `(shard, attempt)` matches; other attempts pass through.  Attempt
    /// numbering starts at 0 per shard.  Thread-safe: the parent
    /// dispatches shards from scoped threads.
    pub struct FaultyExecutor<E> {
        inner: E,
        faults: HashMap<(usize, usize), Fault>,
        attempts: Mutex<HashMap<usize, usize>>,
    }

    impl<E: ShardExecutor> FaultyExecutor<E> {
        pub fn new(inner: E) -> Self {
            Self {
                inner,
                faults: HashMap::new(),
                attempts: Mutex::new(HashMap::new()),
            }
        }

        /// Script `fault` for the given shard's `attempt` (0-based).
        #[must_use]
        pub fn fault(mut self, shard: usize, attempt: usize, fault: Fault) -> Self {
            self.faults.insert((shard, attempt), fault);
            self
        }

        /// How many attempts the parent has made against `shard`.
        pub fn attempts(&self, shard: usize) -> usize {
            self.attempts.lock().unwrap().get(&shard).copied().unwrap_or(0)
        }

        fn shard_of(request_json: &str) -> usize {
            crate::json::parse(request_json)
                .ok()
                .and_then(|v| {
                    v.get("assignment")
                        .and_then(|a| a.get("index"))
                        .and_then(Value::as_u64)
                })
                .and_then(|n| usize::try_from(n).ok())
                .expect("request envelope carries assignment.index")
        }
    }

    impl<E: ShardExecutor> ShardExecutor for FaultyExecutor<E> {
        fn run_shard(&self, request_json: &str) -> Result<String, ExecFailure> {
            let shard = Self::shard_of(request_json);
            let attempt = {
                let mut attempts = self.attempts.lock().unwrap();
                let n = attempts.entry(shard).or_insert(0);
                let attempt = *n;
                *n += 1;
                attempt
            };
            match self.faults.get(&(shard, attempt)) {
                None => self.inner.run_shard(request_json),
                Some(Fault::Kill) => Err(ExecFailure::Crashed {
                    status: "signal: 9 (injected kill)".to_string(),
                    stderr: "worker killed mid-shard (injected)".to_string(),
                }),
                Some(Fault::Hang) => Err(ExecFailure::Timeout(Duration::from_secs(1))),
                Some(Fault::Garbage) => Ok("{\"cells\": [tru".to_string()),
                Some(Fault::Truncate) => {
                    let out = self.inner.run_shard(request_json)?;
                    Ok(out.chars().take(out.len() / 2).collect())
                }
                Some(Fault::VersionBump) => {
                    let out = self.inner.run_shard(request_json)?;
                    let v = crate::json::parse(&out).expect("inner executor emits JSON");
                    let bumped = match v {
                        Value::Obj(fields) => Value::Obj(
                            fields
                                .into_iter()
                                .map(|(k, val)| {
                                    if k == "version" {
                                        (k, Value::from(WIRE_VERSION + 1))
                                    } else {
                                        (k, val)
                                    }
                                })
                                .collect(),
                        ),
                        other => other,
                    };
                    Ok(bumped.pretty())
                }
            }
        }
    }
}

/// Run `prop` over `n` cases generated by `gen`.  Panics with the
/// case-replay seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    n: u32,
    base_seed: u64,
    mut gen: impl FnMut(&mut SimRng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for i in 0..n {
        let case_seed = base_seed.wrapping_add(u64::from(i)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = SimRng::new(case_seed);
        let case = gen(&mut rng);
        if !prop(&case) {
            panic!(
                "property '{name}' failed on case {i} (seed {case_seed:#x}): {case:?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` for a
/// richer failure message.
pub fn forall_r<T: std::fmt::Debug>(
    name: &str,
    n: u32,
    base_seed: u64,
    mut gen: impl FnMut(&mut SimRng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for i in 0..n {
        let case_seed = base_seed.wrapping_add(u64::from(i)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = SimRng::new(case_seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property '{name}' failed on case {i} (seed {case_seed:#x}): {msg}\ncase: {case:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum-commutes", 50, 1, |r| (r.below(100), r.below(100)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_panics_with_name() {
        forall("always-false", 10, 2, |r| r.below(10), |_| false);
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        forall("collect", 10, 3, |r| r.next_u64(), |&x| {
            first.push(x);
            true
        });
        let mut second: Vec<u64> = Vec::new();
        forall("collect", 10, 3, |r| r.next_u64(), |&x| {
            second.push(x);
            true
        });
        assert_eq!(first, second);
    }
}
