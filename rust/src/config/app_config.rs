//! `config.py` analog: every knob the paper's Step 1 documents, same
//! names, same semantics, JSON instead of Python.

use crate::json::{parse, Value};
use crate::sim::clock::{from_secs_f64, SimTime};

use super::{invalid, ConfigError};

/// The CHECK_IF_DONE block: "whether or not to check the output folder
/// before proceeding" plus the three qualifiers.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckIfDone {
    pub enabled: bool,
    /// EXPECTED_NUMBER_FILES: files required to call a job complete.
    pub expected_number_files: u32,
    /// MIN_FILE_SIZE_BYTES: smaller objects don't count (corruption guard).
    pub min_file_size_bytes: u64,
    /// NECESSARY_STRING: must appear in the key to count ("" = any).
    pub necessary_string: String,
}

impl Default for CheckIfDone {
    fn default() -> Self {
        Self {
            enabled: true,
            expected_number_files: 1,
            min_file_size_bytes: 0,
            necessary_string: String::new(),
        }
    }
}

/// The Config file.  Field names mirror the paper's config.py variables.
#[derive(Debug, Clone, PartialEq)]
pub struct AppConfig {
    /// APP_NAME: ties clusters, tasks, services, logs, alarms together.
    pub app_name: String,
    /// DOCKERHUB_TAG analog: which AOT workload artifact to run.
    pub workload_id: String,

    // EC2 AND ECS INFORMATION
    /// ECS_CLUSTER.
    pub ecs_cluster: String,
    /// CLUSTER_MACHINES: EC2 instances in the spot fleet.
    pub cluster_machines: u32,
    /// TASKS_PER_MACHINE: Docker containers per machine.
    pub tasks_per_machine: u32,
    /// MACHINE_TYPE: acceptable instance types (each weight 1).  The
    /// Fleet file's `INSTANCE_TYPES` key overrides this list when
    /// non-empty, adding per-type capacity weights.
    pub machine_types: Vec<String>,
    /// MACHINE_PRICE: spot bid, USD/hour.
    pub machine_price: f64,
    /// EBS_VOL_SIZE in GB (minimum 22, per the paper).
    pub ebs_vol_size_gb: u32,

    // DOCKER INSTANCE RUNNING ENVIRONMENT
    /// DOCKER_CORES: copies of the worker per container.
    pub docker_cores: u32,
    /// CPU_SHARES: 1024 = one vCPU.
    pub cpu_shares: u32,
    /// MEMORY: MB per container.
    pub memory_mb: u64,
    /// SECONDS_TO_START: stagger between core startups.
    pub seconds_to_start: SimTime,

    // SQS QUEUE INFORMATION
    /// SQS_QUEUE_NAME.
    pub sqs_queue_name: String,
    /// SQS_MESSAGE_VISIBILITY.
    pub sqs_message_visibility: SimTime,
    /// SQS_DEAD_LETTER_QUEUE.
    pub sqs_dead_letter_queue: String,
    /// Receives before dead-lettering (AWS redrive maxReceiveCount).
    pub max_receive_count: u32,

    // LOG GROUP INFORMATION
    /// LOG_GROUP_NAME.
    pub log_group_name: String,

    // REDUNDANCY CHECKS
    pub check_if_done: CheckIfDone,

    /// VARIABLE: extra env passed through to the worker, verbatim.
    pub variables: Vec<(String, String)>,
}

impl Default for AppConfig {
    fn default() -> Self {
        Self {
            app_name: "MyApp".into(),
            workload_id: "cp_256_b1".into(),
            ecs_cluster: "default".into(),
            cluster_machines: 4,
            tasks_per_machine: 2,
            machine_types: vec!["m5.xlarge".into()],
            machine_price: 0.10,
            ebs_vol_size_gb: 22,
            docker_cores: 2,
            cpu_shares: 2048,
            memory_mb: 7_500,
            seconds_to_start: 0,
            sqs_queue_name: "MyApp-queue".into(),
            sqs_message_visibility: 10 * crate::sim::MINUTE,
            sqs_dead_letter_queue: "MyApp-deadletter".into(),
            max_receive_count: 5,
            log_group_name: "MyApp".into(),
            check_if_done: CheckIfDone::default(),
            variables: vec![],
        }
    }
}

fn req<'v>(v: &'v Value, key: &'static str) -> Result<&'v Value, ConfigError> {
    v.get(key).ok_or(ConfigError::Missing(key))
}

fn req_str(v: &Value, key: &'static str) -> Result<String, ConfigError> {
    req(v, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| invalid(key, "expected string"))
}

fn req_u32(v: &Value, key: &'static str) -> Result<u32, ConfigError> {
    req(v, key)?
        .as_u64()
        .and_then(|n| u32::try_from(n).ok())
        .ok_or_else(|| invalid(key, "expected non-negative integer"))
}

fn req_f64(v: &Value, key: &'static str) -> Result<f64, ConfigError> {
    req(v, key)?
        .as_f64()
        .ok_or_else(|| invalid(key, "expected number"))
}

impl AppConfig {
    /// Parse and validate a Config file.
    pub fn from_json(text: &str) -> Result<Self, ConfigError> {
        let v = parse(text)?;
        let cid = v.get("CHECK_IF_DONE");
        let check_if_done = match cid {
            Some(c) => CheckIfDone {
                enabled: c.get("ENABLED").and_then(Value::as_bool).unwrap_or(true),
                expected_number_files: c
                    .get("EXPECTED_NUMBER_FILES")
                    .and_then(Value::as_u64)
                    .unwrap_or(1) as u32,
                min_file_size_bytes: c
                    .get("MIN_FILE_SIZE_BYTES")
                    .and_then(Value::as_u64)
                    .unwrap_or(0),
                necessary_string: c
                    .get("NECESSARY_STRING")
                    .and_then(Value::as_str)
                    .unwrap_or("")
                    .to_string(),
            },
            None => CheckIfDone::default(),
        };
        let machine_types = req(&v, "MACHINE_TYPE")?
            .as_arr()
            .ok_or_else(|| invalid("MACHINE_TYPE", "expected array"))?
            .iter()
            .map(|t| {
                t.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| invalid("MACHINE_TYPE", "expected strings"))
            })
            .collect::<Result<Vec<_>, _>>()?;
        let variables = v
            .get("VARIABLES")
            .and_then(Value::as_obj)
            .map(|fields| {
                fields
                    .iter()
                    .filter_map(|(k, val)| val.as_str().map(|s| (k.clone(), s.to_string())))
                    .collect()
            })
            .unwrap_or_default();
        let cfg = Self {
            app_name: req_str(&v, "APP_NAME")?,
            workload_id: req_str(&v, "WORKLOAD_ID")?,
            ecs_cluster: v
                .get("ECS_CLUSTER")
                .and_then(Value::as_str)
                .unwrap_or("default")
                .to_string(),
            cluster_machines: req_u32(&v, "CLUSTER_MACHINES")?,
            tasks_per_machine: req_u32(&v, "TASKS_PER_MACHINE")?,
            machine_types,
            machine_price: req_f64(&v, "MACHINE_PRICE")?,
            ebs_vol_size_gb: v.get("EBS_VOL_SIZE").and_then(Value::as_u64).unwrap_or(22) as u32,
            docker_cores: req_u32(&v, "DOCKER_CORES")?,
            cpu_shares: req_u32(&v, "CPU_SHARES")?,
            memory_mb: req(&v, "MEMORY")?
                .as_u64()
                .ok_or_else(|| invalid("MEMORY", "expected MB integer"))?,
            seconds_to_start: from_secs_f64(
                v.get("SECONDS_TO_START").and_then(Value::as_f64).unwrap_or(0.0),
            ),
            sqs_queue_name: req_str(&v, "SQS_QUEUE_NAME")?,
            sqs_message_visibility: from_secs_f64(req_f64(&v, "SQS_MESSAGE_VISIBILITY")?),
            sqs_dead_letter_queue: req_str(&v, "SQS_DEAD_LETTER_QUEUE")?,
            max_receive_count: v
                .get("MAX_RECEIVE_COUNT")
                .and_then(Value::as_u64)
                .unwrap_or(5) as u32,
            log_group_name: req_str(&v, "LOG_GROUP_NAME")?,
            check_if_done,
            variables,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize back to the Config file format.
    pub fn to_json(&self) -> Value {
        let mut vars = Value::obj();
        for (k, val) in &self.variables {
            vars = vars.with(k, val.as_str());
        }
        Value::obj()
            .with("APP_NAME", self.app_name.as_str())
            .with("WORKLOAD_ID", self.workload_id.as_str())
            .with("ECS_CLUSTER", self.ecs_cluster.as_str())
            .with("CLUSTER_MACHINES", u64::from(self.cluster_machines))
            .with("TASKS_PER_MACHINE", u64::from(self.tasks_per_machine))
            .with(
                "MACHINE_TYPE",
                Value::Arr(self.machine_types.iter().map(|t| Value::from(t.as_str())).collect()),
            )
            .with("MACHINE_PRICE", self.machine_price)
            .with("EBS_VOL_SIZE", u64::from(self.ebs_vol_size_gb))
            .with("DOCKER_CORES", u64::from(self.docker_cores))
            .with("CPU_SHARES", u64::from(self.cpu_shares))
            .with("MEMORY", self.memory_mb)
            .with("SECONDS_TO_START", self.seconds_to_start as f64 / 1000.0)
            .with("SQS_QUEUE_NAME", self.sqs_queue_name.as_str())
            .with(
                "SQS_MESSAGE_VISIBILITY",
                self.sqs_message_visibility as f64 / 1000.0,
            )
            .with("SQS_DEAD_LETTER_QUEUE", self.sqs_dead_letter_queue.as_str())
            .with("MAX_RECEIVE_COUNT", u64::from(self.max_receive_count))
            .with("LOG_GROUP_NAME", self.log_group_name.as_str())
            .with(
                "CHECK_IF_DONE",
                Value::obj()
                    .with("ENABLED", self.check_if_done.enabled)
                    .with(
                        "EXPECTED_NUMBER_FILES",
                        u64::from(self.check_if_done.expected_number_files),
                    )
                    .with(
                        "MIN_FILE_SIZE_BYTES",
                        self.check_if_done.min_file_size_bytes,
                    )
                    .with(
                        "NECESSARY_STRING",
                        self.check_if_done.necessary_string.as_str(),
                    ),
            )
            .with("VARIABLES", vars)
    }

    /// Cross-field validation, mirroring the paper's documented limits.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.app_name.is_empty() {
            return Err(invalid("APP_NAME", "must be non-empty"));
        }
        if self.cluster_machines == 0 {
            return Err(invalid("CLUSTER_MACHINES", "must be >= 1"));
        }
        if self.tasks_per_machine == 0 {
            return Err(invalid("TASKS_PER_MACHINE", "must be >= 1"));
        }
        if self.docker_cores == 0 {
            return Err(invalid("DOCKER_CORES", "must be >= 1"));
        }
        if self.machine_types.is_empty() {
            return Err(invalid("MACHINE_TYPE", "need at least one type"));
        }
        for (i, t) in self.machine_types.iter().enumerate() {
            if crate::aws::ec2::instance_type(t).is_none() {
                return Err(invalid("MACHINE_TYPE", format!("unknown type '{t}'")));
            }
            if self.machine_types[..i].contains(t) {
                return Err(invalid("MACHINE_TYPE", format!("duplicate type '{t}'")));
            }
        }
        if self.machine_price <= 0.0 {
            return Err(invalid("MACHINE_PRICE", "bid must be positive"));
        }
        if self.ebs_vol_size_gb < 22 {
            return Err(invalid("EBS_VOL_SIZE", "minimum allowed is 22 GB"));
        }
        if self.sqs_message_visibility == 0 {
            return Err(invalid("SQS_MESSAGE_VISIBILITY", "must be positive"));
        }
        if self.sqs_queue_name == self.sqs_dead_letter_queue {
            return Err(invalid(
                "SQS_DEAD_LETTER_QUEUE",
                "must differ from SQS_QUEUE_NAME",
            ));
        }
        if self.max_receive_count == 0 {
            return Err(invalid("MAX_RECEIVE_COUNT", "must be >= 1"));
        }
        Ok(())
    }

    /// Derived names, matching DS's conventions.
    pub fn task_family(&self) -> String {
        format!("{}-taskdef", self.app_name)
    }
    pub fn service_name(&self) -> String {
        format!("{}-service", self.app_name)
    }
    /// Per-instance log group ("perinstance logs in CloudWatch").
    pub fn instance_log_group(&self) -> String {
        format!("{}_perInstance", self.log_group_name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        AppConfig::default().validate().unwrap();
    }

    #[test]
    fn roundtrip_json() {
        let mut cfg = AppConfig::default();
        cfg.app_name = "NuclearSegmentation_Drosophila".into();
        cfg.variables = vec![("MY_FLAG".into(), "on".into())];
        cfg.check_if_done.expected_number_files = 5;
        let text = cfg.to_json().pretty();
        let back = AppConfig::from_json(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn missing_field_reported() {
        let err = AppConfig::from_json(r#"{"APP_NAME": "x"}"#).unwrap_err();
        assert!(matches!(err, ConfigError::Missing(_)));
    }

    #[test]
    fn rejects_unknown_machine_type() {
        let mut cfg = AppConfig::default();
        cfg.machine_types = vec!["warp9.mega".into()];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_duplicate_machine_type() {
        let mut cfg = AppConfig::default();
        cfg.machine_types = vec!["m5.xlarge".into(), "m5.large".into(), "m5.xlarge".into()];
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn rejects_small_ebs() {
        let mut cfg = AppConfig::default();
        cfg.ebs_vol_size_gb = 10;
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("22"));
    }

    #[test]
    fn rejects_queue_same_as_dlq() {
        let mut cfg = AppConfig::default();
        cfg.sqs_dead_letter_queue = cfg.sqs_queue_name.clone();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn derived_names() {
        let cfg = AppConfig::default();
        assert_eq!(cfg.task_family(), "MyApp-taskdef");
        assert_eq!(cfg.service_name(), "MyApp-service");
        assert_eq!(cfg.instance_log_group(), "MyApp_perInstance");
    }

    #[test]
    fn check_if_done_defaults_when_absent() {
        let mut cfg = AppConfig::default();
        cfg.check_if_done = CheckIfDone::default();
        let mut v = cfg.to_json();
        // Remove the CHECK_IF_DONE key entirely.
        if let Value::Obj(fields) = &mut v {
            fields.retain(|(k, _)| k != "CHECK_IF_DONE");
        }
        let back = AppConfig::from_json(&v.pretty()).unwrap();
        assert_eq!(back.check_if_done, CheckIfDone::default());
    }
}
