//! The three human-readable files a DS run is configured by.
//!
//! * [`AppConfig`] — `config.py` analog: app name, machine shapes and
//!   counts, bid price, queue names, CHECK_IF_DONE policy, workload knobs.
//! * [`JobSpec`] — `exampleJob.json` analog: shared keys + a `groups`
//!   list; `submitJob` expands one SQS message per group.
//! * [`FleetSpec`] — `exampleFleet.json` analog: account-specific ARNs and
//!   network config (validated but inert in simulation) plus the
//!   fleet-shaping keys `INSTANCE_TYPES`, `ALLOCATION_STRATEGY`, and
//!   `ON_DEMAND_BASE` that drive heterogeneous spot fleets.
//!
//! The later file kinds follow the same paper-style shape (SCREAMING
//! keys, strict parse, bit-exact render) but live with their subsystems:
//! the Sweep plan (`coordinator::sweep`), the Workflow DAG
//! (`crate::workflow`), the failure-domain TOPOLOGY file
//! (`crate::topology`), and the multi-tenant TRAFFIC file
//! (`crate::traffic`).

pub mod app_config;
pub mod fleet_spec;
pub mod job_spec;

pub use app_config::AppConfig;
pub use fleet_spec::FleetSpec;
pub use job_spec::JobSpec;

/// Error for any of the three files.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("invalid json: {0}")]
    Json(#[from] crate::json::ParseError),
    #[error("missing field: {0}")]
    Missing(&'static str),
    #[error("invalid value for {field}: {why}")]
    Invalid { field: &'static str, why: String },
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub(crate) fn invalid(field: &'static str, why: impl Into<String>) -> ConfigError {
    ConfigError::Invalid {
        field,
        why: why.into(),
    }
}
