//! `exampleJob.json` analog: shared keys + `groups`.
//!
//! "When you submit your jobs … DS adds a job to your SQS queue for each
//! item in `groups`.  Each job contains the shared variables common to
//! all jobs, listed … above the `groups` key."

use crate::json::{parse, Value};

use super::{invalid, ConfigError};

/// A parsed Job file.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Keys shared by every job (input/output locations, pipeline name…).
    pub shared: Vec<(String, Value)>,
    /// One entry per parallel task; each is an object of job-specific keys.
    pub groups: Vec<Vec<(String, Value)>>,
}

impl JobSpec {
    pub fn from_json(text: &str) -> Result<Self, ConfigError> {
        let v = parse(text)?;
        let obj = v
            .as_obj()
            .ok_or_else(|| invalid("job file", "expected an object"))?;
        let mut shared = Vec::new();
        let mut groups = None;
        for (k, val) in obj {
            if k == "groups" {
                let arr = val
                    .as_arr()
                    .ok_or_else(|| invalid("groups", "expected an array"))?;
                let mut gs = Vec::with_capacity(arr.len());
                for g in arr {
                    let fields = g
                        .as_obj()
                        .ok_or_else(|| invalid("groups", "each group must be an object"))?;
                    gs.push(fields.to_vec());
                }
                groups = Some(gs);
            } else {
                shared.push((k.clone(), val.clone()));
            }
        }
        let groups = groups.ok_or(ConfigError::Missing("groups"))?;
        if groups.is_empty() {
            return Err(invalid("groups", "must list at least one group"));
        }
        Ok(Self { shared, groups })
    }

    pub fn to_json(&self) -> Value {
        let mut fields = self.shared.clone();
        fields.push((
            "groups".to_string(),
            Value::Arr(self.groups.iter().map(|g| Value::Obj(g.clone())).collect()),
        ));
        Value::Obj(fields)
    }

    /// Expand into one message body per group: shared keys merged with the
    /// group's keys (group wins on conflict), serialized as JSON.
    pub fn to_messages(&self) -> Vec<String> {
        self.groups
            .iter()
            .map(|g| {
                let mut fields: Vec<(String, Value)> = self
                    .shared
                    .iter()
                    .filter(|(k, _)| !g.iter().any(|(gk, _)| gk == k))
                    .cloned()
                    .collect();
                fields.extend(g.iter().cloned());
                Value::Obj(fields).pretty()
            })
            .collect()
    }

    /// Look up a numeric key in one group, falling back to the shared
    /// keys (the same merge [`to_messages`](Self::to_messages) performs).
    fn merged_u64(&self, group: &[(String, Value)], key: &str) -> u64 {
        group
            .iter()
            .find(|(k, _)| k == key)
            .or_else(|| self.shared.iter().find(|(k, _)| k == key))
            .and_then(|(_, v)| v.as_u64())
            .unwrap_or(0)
    }

    /// Total `(input_bytes, output_bytes)` across all groups — the job
    /// file's data footprint, printed by `ds describe --job`.
    pub fn data_footprint(&self) -> (u64, u64) {
        self.groups.iter().fold((0, 0), |(i, o), g| {
            (
                i + self.merged_u64(g, "input_bytes"),
                o + self.merged_u64(g, "output_bytes"),
            )
        })
    }

    /// Give every group the same `input_bytes`/`output_bytes` (exact
    /// sizes, no distribution) — the building block for property tests
    /// and hand-written storage studies.
    pub fn with_uniform_data(mut self, input_bytes: u64, output_bytes: u64) -> Self {
        for g in &mut self.groups {
            g.retain(|(k, _)| k != "input_bytes" && k != "output_bytes");
            g.push(("input_bytes".to_string(), Value::from(input_bytes)));
            g.push(("output_bytes".to_string(), Value::from(output_bytes)));
        }
        self
    }

    /// Give every group a realistic data shape: per-job sizes drawn from
    /// [`crate::workloads::synth::job_data_shape`] around
    /// `mean_input_bytes` (log-normal inputs, ~8:1 reductions out),
    /// deterministic in `(seed, group index)`.
    pub fn with_data_shape(mut self, mean_input_bytes: u64, seed: u64) -> Self {
        for (i, g) in self.groups.iter_mut().enumerate() {
            let (input, output) = crate::workloads::synth::job_data_shape(
                seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                mean_input_bytes,
            );
            g.retain(|(k, _)| k != "input_bytes" && k != "output_bytes");
            g.push(("input_bytes".to_string(), Value::from(input)));
            g.push(("output_bytes".to_string(), Value::from(output)));
        }
        self
    }

    /// Convenience builder: a plate of `wells` × `sites` imaging jobs (the
    /// canonical Distributed-CellProfiler grouping).
    pub fn plate(plate: &str, wells: u32, sites: u32, shared: Vec<(String, Value)>) -> Self {
        let mut groups = Vec::new();
        for w in 0..wells {
            let row = char::from(b'A' + (w / 12) as u8);
            let col = w % 12 + 1;
            let well = format!("{row}{col:02}");
            for s in 0..sites {
                groups.push(vec![
                    ("Metadata_Plate".to_string(), Value::from(plate)),
                    ("Metadata_Well".to_string(), Value::from(well.as_str())),
                    ("Metadata_Site".to_string(), Value::from(u64::from(s))),
                ]);
            }
        }
        Self { shared, groups }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const JOB: &str = r#"{
        "pipeline": "segment.cppipe",
        "input": "s3://bkt/images",
        "output": "s3://bkt/results",
        "groups": [
            {"Metadata_Well": "A01"},
            {"Metadata_Well": "A02", "pipeline": "special.cppipe"}
        ]
    }"#;

    #[test]
    fn parses_shared_and_groups() {
        let j = JobSpec::from_json(JOB).unwrap();
        assert_eq!(j.shared.len(), 3);
        assert_eq!(j.groups.len(), 2);
    }

    #[test]
    fn messages_merge_shared_with_group_winning() {
        let j = JobSpec::from_json(JOB).unwrap();
        let msgs = j.to_messages();
        assert_eq!(msgs.len(), 2);
        let m0 = parse(&msgs[0]).unwrap();
        assert_eq!(m0.get("pipeline").unwrap().as_str(), Some("segment.cppipe"));
        assert_eq!(m0.get("Metadata_Well").unwrap().as_str(), Some("A01"));
        let m1 = parse(&msgs[1]).unwrap();
        // group key overrides shared
        assert_eq!(m1.get("pipeline").unwrap().as_str(), Some("special.cppipe"));
    }

    #[test]
    fn requires_groups() {
        assert!(JobSpec::from_json(r#"{"a": 1}"#).is_err());
        assert!(JobSpec::from_json(r#"{"groups": []}"#).is_err());
        assert!(JobSpec::from_json(r#"{"groups": [1]}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let j = JobSpec::from_json(JOB).unwrap();
        let back = JobSpec::from_json(&j.to_json().pretty()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn uniform_data_shape_and_footprint() {
        let j = JobSpec::plate("P", 2, 2, vec![]).with_uniform_data(1_000, 100);
        assert_eq!(j.data_footprint(), (4_000, 400));
        // Survives the JSON round trip and lands in every message.
        let back = JobSpec::from_json(&j.to_json().pretty()).unwrap();
        assert_eq!(back.data_footprint(), (4_000, 400));
        for m in j.to_messages() {
            let v = parse(&m).unwrap();
            assert_eq!(v.get("input_bytes").and_then(Value::as_u64), Some(1_000));
            assert_eq!(v.get("output_bytes").and_then(Value::as_u64), Some(100));
        }
        // Re-shaping replaces, never duplicates.
        let j2 = j.with_uniform_data(500, 50);
        assert_eq!(j2.data_footprint(), (2_000, 200));
    }

    #[test]
    fn data_shape_deterministic_and_shared_fallback() {
        let a = JobSpec::plate("P", 4, 2, vec![]).with_data_shape(64_000_000, 9);
        let b = JobSpec::plate("P", 4, 2, vec![]).with_data_shape(64_000_000, 9);
        assert_eq!(a, b);
        let (input, output) = a.data_footprint();
        assert!(input > 0 && output > 0 && output < input);
        // Shared keys count when a group doesn't override them.
        let shared = vec![("input_bytes".to_string(), Value::from(7u64))];
        let s = JobSpec::plate("P", 1, 3, shared);
        assert_eq!(s.data_footprint(), (21, 0));
    }

    #[test]
    fn plate_builder_layout() {
        let j = JobSpec::plate("P1", 96, 4, vec![]);
        assert_eq!(j.groups.len(), 384);
        // Well names span A01..H12.
        let first = &j.groups[0];
        assert_eq!(first[1].1.as_str(), Some("A01"));
        let last = &j.groups[383];
        assert_eq!(last[1].1.as_str(), Some("H12"));
    }
}
