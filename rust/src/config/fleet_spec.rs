//! `exampleFleet.json` analog: account-specific spot-fleet boilerplate
//! plus the fleet-shaping knobs.
//!
//! "exampleFleet.json does not need to be changed depending on your
//! implementation … each AWS account … will need to update the Fleet file
//! with configuration specific to their account."  In simulation the
//! account fields are inert, but they are parsed and validated with the
//! same shape so the four-command UX (and its failure modes: missing
//! role ARN, wrong region AMI) is preserved.
//!
//! Three keys *do* shape the simulated fleet (see
//! [`crate::aws::ec2::fleet`]):
//!
//! * `INSTANCE_TYPES` — launch specifications, `"name"` or
//!   `"name:weight"`.  Empty means "inherit the Config file's
//!   `MACHINE_TYPE` list at weight 1".
//! * `ALLOCATION_STRATEGY` — `"lowest-price"` (default),
//!   `"diversified"`, or `"capacity-optimized"`.
//! * `ON_DEMAND_BASE` — weighted units kept on-demand (flat-billed,
//!   never interrupted).  Default 0.

use crate::aws::ec2::{instance_type, AllocationStrategy, InstanceSlot};
use crate::json::{parse, Value};

use super::{invalid, ConfigError};

/// Region-keyed AMI template table ("We provide templates for multiple
/// regions").
pub const REGION_AMIS: &[(&str, &str, &str)] = &[
    ("us-east-1", "ami-0ds00000000000001", "snap-0ds0000000000001"),
    ("us-west-2", "ami-0ds00000000000002", "snap-0ds0000000000002"),
    ("eu-west-1", "ami-0ds00000000000003", "snap-0ds0000000000003"),
];

/// The Fleet file.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub iam_fleet_role: String,
    pub iam_instance_profile: String,
    pub key_name: String,
    pub subnet_id: String,
    pub security_groups: Vec<String>,
    pub image_id: String,
    pub snapshot_id: String,
    pub region: String,
    /// INSTANCE_TYPES: launch specifications (`"name"` / `"name:weight"`).
    /// Empty inherits the Config file's MACHINE_TYPE list at weight 1.
    pub instance_types: Vec<InstanceSlot>,
    /// ALLOCATION_STRATEGY: how the fleet splits its deficit across
    /// pools.
    pub allocation_strategy: AllocationStrategy,
    /// ON_DEMAND_BASE: weighted units kept on-demand.
    pub on_demand_base: u32,
}

impl FleetSpec {
    /// A ready-to-edit template for `region` (run `ds make-fleet-file`).
    pub fn template(region: &str) -> Option<Self> {
        let (_, ami, snap) = REGION_AMIS.iter().find(|(r, _, _)| *r == region)?;
        Some(Self {
            iam_fleet_role: "arn:aws:iam::123456789012:role/aws-ec2-spot-fleet-tagging-role"
                .into(),
            iam_instance_profile: "arn:aws:iam::123456789012:instance-profile/ecsInstanceRole"
                .into(),
            key_name: "your-key".into(),
            subnet_id: "subnet-REPLACE".into(),
            security_groups: vec!["sg-REPLACE".into()],
            image_id: (*ami).into(),
            snapshot_id: (*snap).into(),
            region: region.into(),
            instance_types: Vec::new(),
            allocation_strategy: AllocationStrategy::LowestPrice,
            on_demand_base: 0,
        })
    }

    pub fn from_json(text: &str) -> Result<Self, ConfigError> {
        let v = parse(text)?;
        let s = |key: &'static str| -> Result<String, ConfigError> {
            v.get(key)
                .and_then(Value::as_str)
                .map(str::to_string)
                .ok_or(ConfigError::Missing(key))
        };
        let groups = v
            .get("Groups")
            .and_then(Value::as_arr)
            .ok_or(ConfigError::Missing("Groups"))?
            .iter()
            .filter_map(|g| g.as_str().map(str::to_string))
            .collect();
        // Fleet-shaping keys are optional so pre-heterogeneity Fleet
        // files keep parsing unchanged.
        let instance_types = match v.get("INSTANCE_TYPES") {
            None => Vec::new(),
            Some(arr) => arr
                .as_arr()
                .ok_or_else(|| invalid("INSTANCE_TYPES", "expected array of strings"))?
                .iter()
                .map(|t| {
                    let s = t
                        .as_str()
                        .ok_or_else(|| invalid("INSTANCE_TYPES", "expected strings"))?;
                    InstanceSlot::parse(s).map_err(|e| invalid("INSTANCE_TYPES", e))
                })
                .collect::<Result<Vec<_>, _>>()?,
        };
        let allocation_strategy = match v.get("ALLOCATION_STRATEGY") {
            None => AllocationStrategy::LowestPrice,
            Some(a) => {
                let s = a
                    .as_str()
                    .ok_or_else(|| invalid("ALLOCATION_STRATEGY", "expected string"))?;
                AllocationStrategy::parse(s).ok_or_else(|| {
                    invalid(
                        "ALLOCATION_STRATEGY",
                        "expected lowest-price | diversified | capacity-optimized",
                    )
                })?
            }
        };
        let on_demand_base = match v.get("ON_DEMAND_BASE") {
            None => 0,
            Some(n) => n
                .as_u64()
                .and_then(|n| u32::try_from(n).ok())
                .ok_or_else(|| invalid("ON_DEMAND_BASE", "expected non-negative integer"))?,
        };
        let spec = Self {
            iam_fleet_role: s("IamFleetRole")?,
            iam_instance_profile: s("IamInstanceProfile")?,
            key_name: s("KeyName")?,
            subnet_id: s("SubnetId")?,
            security_groups: groups,
            image_id: s("ImageId")?,
            snapshot_id: s("SnapshotId")?,
            region: s("Region")?,
            instance_types,
            allocation_strategy,
            on_demand_base,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("IamFleetRole", self.iam_fleet_role.as_str())
            .with("IamInstanceProfile", self.iam_instance_profile.as_str())
            .with("KeyName", self.key_name.as_str())
            .with("SubnetId", self.subnet_id.as_str())
            .with(
                "Groups",
                Value::Arr(
                    self.security_groups
                        .iter()
                        .map(|g| Value::from(g.as_str()))
                        .collect(),
                ),
            )
            .with("ImageId", self.image_id.as_str())
            .with("SnapshotId", self.snapshot_id.as_str())
            .with("Region", self.region.as_str())
            .with(
                "INSTANCE_TYPES",
                Value::Arr(
                    self.instance_types
                        .iter()
                        .map(|s| Value::from(s.render()))
                        .collect(),
                ),
            )
            .with("ALLOCATION_STRATEGY", self.allocation_strategy.name())
            .with("ON_DEMAND_BASE", u64::from(self.on_demand_base))
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if !self.iam_fleet_role.starts_with("arn:aws:iam::") {
            return Err(invalid("IamFleetRole", "must be an IAM role ARN"));
        }
        if !self.iam_instance_profile.starts_with("arn:aws:iam::") {
            return Err(invalid("IamInstanceProfile", "must be an IAM ARN"));
        }
        if self.key_name.is_empty() || self.key_name.ends_with(".pem") {
            return Err(invalid(
                "KeyName",
                "key name without the .pem extension (per the paper)",
            ));
        }
        if !self.subnet_id.starts_with("subnet-") {
            return Err(invalid("SubnetId", "expected subnet-…"));
        }
        if self.security_groups.is_empty()
            || !self.security_groups.iter().all(|g| g.starts_with("sg-"))
        {
            return Err(invalid("Groups", "expected sg-… ids"));
        }
        if !self.image_id.starts_with("ami-") {
            return Err(invalid("ImageId", "expected ami-…"));
        }
        if !self.snapshot_id.starts_with("snap-") {
            return Err(invalid("SnapshotId", "expected snap-…"));
        }
        // AMIs are region-specific: a known region must use its template AMI.
        if let Some((_, ami, _)) = REGION_AMIS.iter().find(|(r, _, _)| *r == self.region) {
            if &self.image_id != ami {
                return Err(invalid(
                    "ImageId",
                    format!("AMI is region-specific; expected {ami} for {}", self.region),
                ));
            }
        }
        for (i, slot) in self.instance_types.iter().enumerate() {
            if instance_type(&slot.name).is_none() {
                return Err(invalid(
                    "INSTANCE_TYPES",
                    format!("unknown instance type '{}'", slot.name),
                ));
            }
            if slot.weight == 0 {
                return Err(invalid("INSTANCE_TYPES", "weights must be >= 1"));
            }
            // A type may appear only once: duplicates with different
            // weights would silently run a different fleet than asked
            // for (first occurrence wins in fulfillment).
            if self.instance_types[..i].iter().any(|p| p.name == slot.name) {
                return Err(invalid(
                    "INSTANCE_TYPES",
                    format!("duplicate instance type '{}'", slot.name),
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn template_regions_valid() {
        for (region, _, _) in REGION_AMIS {
            let t = FleetSpec::template(region).unwrap();
            t.validate().unwrap();
        }
        assert!(FleetSpec::template("mars-north-1").is_none());
    }

    #[test]
    fn roundtrip() {
        let t = FleetSpec::template("us-east-1").unwrap();
        let back = FleetSpec::from_json(&t.to_json().pretty()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_heterogeneous() {
        let mut t = FleetSpec::template("us-east-1").unwrap();
        t.instance_types = vec![
            InstanceSlot::new("m5.large"),
            InstanceSlot {
                name: "m5.xlarge".into(),
                weight: 2,
            },
        ];
        t.allocation_strategy = AllocationStrategy::Diversified;
        t.on_demand_base = 3;
        let text = t.to_json().pretty();
        assert!(text.contains("m5.xlarge:2"), "{text}");
        assert!(text.contains("diversified"), "{text}");
        let back = FleetSpec::from_json(&text).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn fleet_keys_optional_for_old_files() {
        // A pre-heterogeneity Fleet file (no new keys) still parses with
        // the defaults.
        let mut v = FleetSpec::template("us-east-1").unwrap().to_json();
        if let Value::Obj(fields) = &mut v {
            fields.retain(|(k, _)| {
                k != "INSTANCE_TYPES" && k != "ALLOCATION_STRATEGY" && k != "ON_DEMAND_BASE"
            });
        }
        let back = FleetSpec::from_json(&v.pretty()).unwrap();
        assert!(back.instance_types.is_empty());
        assert_eq!(back.allocation_strategy, AllocationStrategy::LowestPrice);
        assert_eq!(back.on_demand_base, 0);
    }

    #[test]
    fn rejects_bad_fleet_keys() {
        let mut t = FleetSpec::template("us-east-1").unwrap();
        t.instance_types = vec![InstanceSlot::new("warp9.mega")];
        assert!(t.validate().is_err());

        // Duplicate types (e.g. conflicting weights) must not silently
        // run a different fleet than requested.
        let mut t = FleetSpec::template("us-east-1").unwrap();
        t.instance_types = vec![
            InstanceSlot::new("m5.xlarge"),
            InstanceSlot {
                name: "m5.xlarge".into(),
                weight: 3,
            },
        ];
        let err = t.validate().unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");

        let mut v = FleetSpec::template("us-east-1").unwrap().to_json();
        if let Value::Obj(fields) = &mut v {
            for (k, val) in fields.iter_mut() {
                if k == "ALLOCATION_STRATEGY" {
                    *val = Value::from("best-effort");
                }
            }
        }
        let err = FleetSpec::from_json(&v.pretty()).unwrap_err();
        assert!(err.to_string().contains("lowest-price"), "{err}");
    }

    #[test]
    fn rejects_pem_suffix() {
        let mut t = FleetSpec::template("us-east-1").unwrap();
        t.key_name = "mykey.pem".into();
        assert!(t.validate().is_err());
    }

    #[test]
    fn rejects_wrong_region_ami() {
        let mut t = FleetSpec::template("us-east-1").unwrap();
        t.image_id = "ami-0ds00000000000002".into(); // us-west-2's AMI
        let err = t.validate().unwrap_err();
        assert!(err.to_string().contains("region-specific"));
    }

    #[test]
    fn rejects_malformed_ids() {
        let mut t = FleetSpec::template("us-east-1").unwrap();
        t.subnet_id = "net-123".into();
        assert!(t.validate().is_err());
        let mut t = FleetSpec::template("us-east-1").unwrap();
        t.security_groups = vec![];
        assert!(t.validate().is_err());
    }
}
