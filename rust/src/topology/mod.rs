//! Cluster topology: failure domains, placement policies, and
//! correlated-fault injection (DESIGN.md §12).
//!
//! The paper's cluster is one implicit region — one spot market, one
//! bucket, faults that are independent per machine.  Real AWS
//! coordination is dominated by *where* things run: regions and AZs with
//! independent spot markets and capacity, region-local buckets whose
//! cross-region reads cost extra egress dollars and latency, and
//! failures that are correlated within a domain (an AZ outage, a spot
//! reclaim storm in one pool, a throttled regional bucket).  This module
//! is the typed half of that story:
//!
//! * [`ClusterTopology`] — named [`FailureDomain`]s (AZ granularity,
//!   each tagged with its region) plus declared [`FaultSpec`] windows.
//!   Construction validates eagerly: empty topologies, duplicate domain
//!   names, faults naming unknown domains, and zero-length or
//!   nonsensical fault windows are typed [`TopologyError`]s, never
//!   panics.  Topologies parse from a TOPOLOGY JSON file
//!   ([`ClusterTopology::parse`], strict about unknown keys like the
//!   Sweep and WORKFLOW files), render back bit-identically
//!   ([`ClusterTopology::render`]), build in code via
//!   [`ClusterTopology::builder`], and resolve from built-in shape names
//!   (`single`, `three-az`, `two-region`) or file paths
//!   ([`ClusterTopology::resolve`]).
//! * [`Placement`] — how the fleet spreads capacity over domains: pack
//!   everything into the home domain, spread round-robin for blast-radius
//!   isolation, or chase the cheapest spot price across all domains.
//! * [`FaultKind`] — the correlated-failure vocabulary: `az-outage`
//!   (domain capacity zero, running instances killed), `price-storm`
//!   (spot price multiplier on one domain's pools), `bucket-throttle`
//!   (one region's bucket capacity scaled down).
//! * [`TopologyBreakdown`] — the topology slice of a run report
//!   (per-domain cost/interruptions/jobs, cross-region egress bytes and
//!   dollars, outage timelines), threaded RunReport → ScenarioSummary →
//!   sweep JSON exactly like the pool/data/scaling/workflow breakdowns.
//!
//! The market/fleet mechanics that consume all of this live in
//! [`crate::aws::ec2`]; the driver that schedules fault windows and
//! accounts cross-region egress is [`crate::coordinator::run`].

use thiserror::Error;

use crate::json::{parse, Value};
use crate::sim::{SimTime, MINUTE};

/// Why a topology spec was rejected.  Every variant names the topology
/// and the offending element, so `ds describe`/`ds sweep --dry-run` can
/// surface the problem without a panic.
#[derive(Debug, Error, PartialEq)]
pub enum TopologyError {
    #[error("topology spec: {0}")]
    Parse(String),
    #[error("topology '{topology}': no failure domains declared")]
    Empty { topology: String },
    #[error("topology '{topology}': duplicate domain name '{domain}'")]
    DuplicateDomain { topology: String, domain: String },
    #[error("topology '{topology}': fault references unknown domain '{domain}'")]
    UnknownDomain { topology: String, domain: String },
    #[error("topology '{topology}': fault on '{domain}' has a zero-length window")]
    EmptyWindow { topology: String, domain: String },
    #[error("topology '{topology}': fault on '{domain}' has non-positive magnitude {magnitude}")]
    BadMagnitude {
        topology: String,
        domain: String,
        magnitude: f64,
    },
    #[error(
        "unknown topology '{0}' (expected a shape name — single, three-az, two-region — or a readable TOPOLOGY file path)"
    )]
    Unknown(String),
}

fn parse_err(msg: impl Into<String>) -> TopologyError {
    TopologyError::Parse(msg.into())
}

/// One failure domain — an availability zone — tagged with the region
/// whose bucket is "local" to it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FailureDomain {
    /// AZ-style name, e.g. `us-east-1a`.
    pub name: String,
    /// Region the domain belongs to, e.g. `us-east-1`.
    pub region: String,
}

/// The correlated-failure vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The domain loses all spot capacity for the window; running spot
    /// instances there are terminated when the window opens.
    AzOutage,
    /// Spot prices in the domain are multiplied by `magnitude` for the
    /// window — a reclaim storm that interrupts over-bid instances.
    PriceStorm,
    /// The region's bucket throughput is multiplied by `magnitude`
    /// (< 1.0 throttles) for the window.
    BucketThrottle,
}

impl FaultKind {
    pub const ALL: [FaultKind; 3] = [Self::AzOutage, Self::PriceStorm, Self::BucketThrottle];

    /// Stable name (also the TOPOLOGY file value and report label).
    pub fn name(self) -> &'static str {
        match self {
            Self::AzOutage => "az-outage",
            Self::PriceStorm => "price-storm",
            Self::BucketThrottle => "bucket-throttle",
        }
    }

    /// Parse a kind name.
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// One declared fault window, deterministic from the spec (minutes, so
/// TOPOLOGY files round-trip bit-identically).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Name of the affected [`FailureDomain`].
    pub domain: String,
    /// Window start, minutes of simulated time.
    pub at_min: u64,
    /// Window length, minutes.
    pub duration_min: u64,
    /// Kind-specific strength: price multiplier for `price-storm`,
    /// bucket capacity factor for `bucket-throttle`; ignored (use 1.0)
    /// for `az-outage`.
    pub magnitude: f64,
}

impl FaultSpec {
    /// The window in simulated milliseconds `[start, end)`.
    pub fn window_ms(&self) -> (SimTime, SimTime) {
        let start = self.at_min * MINUTE;
        (start, start + self.duration_min * MINUTE)
    }
}

/// A validated cluster topology.  Invariants (enforced by every
/// constructor): at least one domain, unique domain names, every fault
/// naming a declared domain with a non-empty window and positive
/// magnitude.
///
/// ```
/// use ds_rs::topology::{ClusterTopology, FaultKind};
///
/// let topo = ClusterTopology::builder("demo")
///     .domain("us-east-1a", "us-east-1")
///     .domain("us-west-2a", "us-west-2")
///     .fault(FaultKind::AzOutage, "us-east-1a", 30, 60, 1.0)
///     .build()
///     .unwrap();
/// assert_eq!(topo.domain_count(), 2);
/// assert_eq!(topo.home_region(), "us-east-1");
/// // TOPOLOGY files round-trip bit-identically.
/// let back = ClusterTopology::parse(&topo.render()).unwrap();
/// assert_eq!(back, topo);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterTopology {
    pub name: String,
    /// Domains in declaration order; domain 0 is the *home* domain — the
    /// data bucket lives in its region and pack placement fills it first.
    pub domains: Vec<FailureDomain>,
    /// Declared fault windows in declaration order.
    pub faults: Vec<FaultSpec>,
}

impl ClusterTopology {
    /// Build and validate.  The single gate every front door (file,
    /// JSON, builder, shapes) funnels through.
    pub fn new(
        name: &str,
        domains: Vec<FailureDomain>,
        faults: Vec<FaultSpec>,
    ) -> Result<Self, TopologyError> {
        let topo = Self {
            name: name.to_string(),
            domains,
            faults,
        };
        topo.validate()?;
        Ok(topo)
    }

    /// Start an in-code topology.
    pub fn builder(name: &str) -> TopologyBuilder {
        TopologyBuilder {
            name: name.to_string(),
            domains: Vec::new(),
            faults: Vec::new(),
        }
    }

    /// Re-check the invariants every constructor enforces (at least one
    /// domain, unique names, faults reference declared domains with
    /// non-empty windows and positive magnitude).  Useful for topologies
    /// assembled field-by-field.
    pub fn validate(&self) -> Result<(), TopologyError> {
        let topo = || self.name.clone();
        if self.domains.is_empty() {
            return Err(TopologyError::Empty { topology: topo() });
        }
        for (i, d) in self.domains.iter().enumerate() {
            if self.domains[..i].iter().any(|o| o.name == d.name) {
                return Err(TopologyError::DuplicateDomain {
                    topology: topo(),
                    domain: d.name.clone(),
                });
            }
        }
        for f in &self.faults {
            if self.index_of(&f.domain).is_none() {
                return Err(TopologyError::UnknownDomain {
                    topology: topo(),
                    domain: f.domain.clone(),
                });
            }
            if f.duration_min == 0 {
                return Err(TopologyError::EmptyWindow {
                    topology: topo(),
                    domain: f.domain.clone(),
                });
            }
            if !(f.magnitude > 0.0) {
                return Err(TopologyError::BadMagnitude {
                    topology: topo(),
                    domain: f.domain.clone(),
                    magnitude: f.magnitude,
                });
            }
        }
        Ok(())
    }

    pub fn domain_count(&self) -> usize {
        self.domains.len()
    }

    /// Domain index by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.domains.iter().position(|d| d.name == name)
    }

    /// The home region: where the data bucket lives (domain 0's region).
    pub fn home_region(&self) -> &str {
        &self.domains[0].region
    }

    /// Region of domain `i`.
    pub fn region_of(&self, i: usize) -> &str {
        &self.domains[i].region
    }

    /// Whether domain `i` reads the data bucket across a region boundary
    /// (billed as cross-region egress, slower first byte).
    pub fn is_cross_region(&self, i: usize) -> bool {
        self.domains[i].region != self.home_region()
    }

    /// The TOPOLOGY file as JSON (NAME / DOMAINS / FAULTS, declaration
    /// order preserved) — [`parse`](Self::parse) inverts it
    /// bit-identically.
    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("NAME", self.name.as_str())
            .with(
                "DOMAINS",
                Value::Arr(
                    self.domains
                        .iter()
                        .map(|d| {
                            Value::obj()
                                .with("name", d.name.as_str())
                                .with("region", d.region.as_str())
                        })
                        .collect(),
                ),
            )
            .with(
                "FAULTS",
                Value::Arr(
                    self.faults
                        .iter()
                        .map(|f| {
                            Value::obj()
                                .with("kind", f.kind.name())
                                .with("domain", f.domain.as_str())
                                .with("at_min", f.at_min)
                                .with("duration_min", f.duration_min)
                                .with("magnitude", f.magnitude)
                        })
                        .collect(),
                ),
            )
    }

    /// Decode (and validate) a TOPOLOGY JSON value.  Strict like the
    /// Sweep file: unknown keys are rejected, not ignored.
    pub fn from_json(v: &Value) -> Result<Self, TopologyError> {
        let fields = v
            .as_obj()
            .ok_or_else(|| parse_err("expected a TOPOLOGY object"))?;
        let mut name = None;
        let mut domains = None;
        let mut faults = None;
        for (k, val) in fields {
            match k.as_str() {
                "NAME" => {
                    name = Some(
                        val.as_str()
                            .ok_or_else(|| parse_err("NAME must be a string"))?
                            .to_string(),
                    );
                }
                "DOMAINS" => {
                    let arr = val
                        .as_arr()
                        .ok_or_else(|| parse_err("DOMAINS must be an array"))?;
                    domains = Some(
                        arr.iter()
                            .map(Self::domain_from_json)
                            .collect::<Result<Vec<_>, _>>()?,
                    );
                }
                "FAULTS" => {
                    let arr = val
                        .as_arr()
                        .ok_or_else(|| parse_err("FAULTS must be an array"))?;
                    faults = Some(
                        arr.iter()
                            .map(Self::fault_from_json)
                            .collect::<Result<Vec<_>, _>>()?,
                    );
                }
                other => return Err(parse_err(format!("unknown TOPOLOGY key '{other}'"))),
            }
        }
        let name = name.ok_or_else(|| parse_err("missing NAME"))?;
        let domains = domains.ok_or_else(|| parse_err("missing DOMAINS"))?;
        let faults = faults.unwrap_or_default();
        Self::new(&name, domains, faults)
    }

    fn domain_from_json(v: &Value) -> Result<FailureDomain, TopologyError> {
        let fields = v
            .as_obj()
            .ok_or_else(|| parse_err("each DOMAINS entry must be an object"))?;
        let mut name = None;
        let mut region = None;
        for (k, val) in fields {
            let s = val
                .as_str()
                .ok_or_else(|| parse_err(format!("domain key '{k}' must be a string")))?
                .to_string();
            match k.as_str() {
                "name" => name = Some(s),
                "region" => region = Some(s),
                other => return Err(parse_err(format!("unknown domain key '{other}'"))),
            }
        }
        Ok(FailureDomain {
            name: name.ok_or_else(|| parse_err("domain missing 'name'"))?,
            region: region.ok_or_else(|| parse_err("domain missing 'region'"))?,
        })
    }

    fn fault_from_json(v: &Value) -> Result<FaultSpec, TopologyError> {
        let fields = v
            .as_obj()
            .ok_or_else(|| parse_err("each FAULTS entry must be an object"))?;
        let mut kind = None;
        let mut domain = None;
        let mut at_min = 0u64;
        let mut duration_min = 0u64;
        let mut magnitude = 1.0f64;
        for (k, val) in fields {
            match k.as_str() {
                "kind" => {
                    let s = val
                        .as_str()
                        .ok_or_else(|| parse_err("fault kind must be a string"))?;
                    kind = Some(FaultKind::parse(s).ok_or_else(|| {
                        parse_err(format!(
                            "unknown fault kind '{s}' (expected az-outage, price-storm, or bucket-throttle)"
                        ))
                    })?);
                }
                "domain" => {
                    domain = Some(
                        val.as_str()
                            .ok_or_else(|| parse_err("fault domain must be a string"))?
                            .to_string(),
                    );
                }
                "at_min" => {
                    at_min = val
                        .as_u64()
                        .ok_or_else(|| parse_err("at_min must be an unsigned integer"))?;
                }
                "duration_min" => {
                    duration_min = val
                        .as_u64()
                        .ok_or_else(|| parse_err("duration_min must be an unsigned integer"))?;
                }
                "magnitude" => {
                    magnitude = val
                        .as_f64()
                        .ok_or_else(|| parse_err("magnitude must be a number"))?;
                }
                other => return Err(parse_err(format!("unknown fault key '{other}'"))),
            }
        }
        Ok(FaultSpec {
            kind: kind.ok_or_else(|| parse_err("fault missing 'kind'"))?,
            domain: domain.ok_or_else(|| parse_err("fault missing 'domain'"))?,
            at_min,
            duration_min,
            magnitude,
        })
    }

    /// Parse (and validate) a TOPOLOGY file's text.
    pub fn parse(text: &str) -> Result<Self, TopologyError> {
        let v = parse(text).map_err(|e| parse_err(format!("invalid JSON: {e}")))?;
        Self::from_json(&v)
    }

    /// Render the TOPOLOGY file text; `parse(render())` is bit-identical
    /// (pinned by the round-trip tests).
    pub fn render(&self) -> String {
        self.to_json().pretty()
    }

    /// The built-in shape names [`resolve`](Self::resolve) knows.
    pub const SHAPES: [&'static str; 3] = ["single", "three-az", "two-region"];

    /// A built-in shape by name, if any.  `single` is the implicit
    /// pre-topology cluster — one AZ, one region, no faults — and is what
    /// the `--topology` axis treats as "no topology installed".
    pub fn shape(name: &str) -> Option<Self> {
        let topo = match name {
            "single" => Self::builder("single").domain("us-east-1a", "us-east-1"),
            "three-az" => Self::builder("three-az")
                .domain("us-east-1a", "us-east-1")
                .domain("us-east-1b", "us-east-1")
                .domain("us-east-1c", "us-east-1"),
            "two-region" => Self::builder("two-region")
                .domain("us-east-1a", "us-east-1")
                .domain("us-west-2a", "us-west-2"),
            _ => return None,
        };
        Some(topo.build().expect("built-in shapes validate"))
    }

    /// Resolve a `--topology` value: a built-in shape name first, else a
    /// TOPOLOGY file path.
    pub fn resolve(value: &str) -> Result<Self, TopologyError> {
        if let Some(topo) = Self::shape(value) {
            return Ok(topo);
        }
        match std::fs::read_to_string(value) {
            Ok(text) => Self::parse(&text),
            Err(_) => Err(TopologyError::Unknown(value.to_string())),
        }
    }
}

/// In-code topology construction; `build` runs the same validation as
/// the file parser.
#[derive(Debug, Clone)]
pub struct TopologyBuilder {
    name: String,
    domains: Vec<FailureDomain>,
    faults: Vec<FaultSpec>,
}

impl TopologyBuilder {
    /// Declare a failure domain in `region`.
    pub fn domain(mut self, name: &str, region: &str) -> Self {
        self.domains.push(FailureDomain {
            name: name.to_string(),
            region: region.to_string(),
        });
        self
    }

    /// Declare a fault window on `domain`.
    pub fn fault(
        mut self,
        kind: FaultKind,
        domain: &str,
        at_min: u64,
        duration_min: u64,
        magnitude: f64,
    ) -> Self {
        self.faults.push(FaultSpec {
            kind,
            domain: domain.to_string(),
            at_min,
            duration_min,
            magnitude,
        });
        self
    }

    pub fn build(self) -> Result<ClusterTopology, TopologyError> {
        ClusterTopology::new(&self.name, self.domains, self.faults)
    }
}

/// How the fleet distributes capacity over failure domains — the
/// blast-radius-vs-cost axis.
///
/// ```
/// use ds_rs::topology::Placement;
///
/// assert_eq!(Placement::parse("spread"), Some(Placement::Spread));
/// assert_eq!(Placement::default().name(), "pack");
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Everything in the home domain (domain 0): no cross-region egress,
    /// maximal blast radius.  The neutral default — single-domain runs
    /// are unaffected by it.
    #[default]
    Pack,
    /// Round-robin over domains: capacity survives any single-domain
    /// outage at the price of cross-region egress from remote domains.
    Spread,
    /// Chase the lowest spot price across all domains' pools.
    Cheapest,
}

impl Placement {
    pub const ALL: [Placement; 3] = [Self::Pack, Self::Spread, Self::Cheapest];

    /// Stable name (also the sweep-axis label).
    pub fn name(self) -> &'static str {
        match self {
            Self::Pack => "pack",
            Self::Spread => "spread",
            Self::Cheapest => "cheapest",
        }
    }

    /// Parse a policy name (the `--placement` axis).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|p| p.name() == s)
    }
}

/// One domain's slice of a run: what launched, died, finished, and cost
/// there.
#[derive(Debug, Clone, PartialEq)]
pub struct DomainSlice {
    /// Domain name, e.g. `us-west-2a`.
    pub domain: String,
    /// The domain's region.
    pub region: String,
    /// Instances launched in the domain.
    pub launched: u64,
    /// Spot interruptions (price- or outage-driven) in the domain.
    pub interrupted: u64,
    /// Jobs whose completing machine lived in the domain.
    pub jobs_completed: u64,
    /// Compute dollars billed to the domain's instances.
    pub cost_usd: f64,
}

/// One observed fault window (per-run evidence, like the scaling
/// timeline; dropped in cross-seed summaries).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OutageWindow {
    /// Affected domain name.
    pub domain: String,
    /// [`FaultKind`] name.
    pub kind: String,
    pub start_ms: SimTime,
    pub end_ms: SimTime,
}

/// The topology slice of a run report, the multi-region analog of
/// `Pool`/`Data`/`Scaling`/`WorkflowBreakdown`.  `topology == "single"`
/// — the default — is the paper's implicit one-region cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyBreakdown {
    /// Topology name ("single" when the run had no topology installed).
    pub topology: String,
    /// Placement-policy name the fleet ran under.
    pub placement: String,
    /// Per-domain slices, declaration order.
    pub domains: Vec<DomainSlice>,
    /// Bytes the data plane moved across a region boundary.
    pub xregion_bytes: u64,
    /// Cross-region egress dollars (billed on top of the regular
    /// transfer line items).
    pub xregion_usd: f64,
    /// Fault windows that opened during the run.
    pub outages: Vec<OutageWindow>,
}

impl Default for TopologyBreakdown {
    fn default() -> Self {
        Self {
            topology: "single".to_string(),
            placement: Placement::Pack.name().to_string(),
            domains: Vec::new(),
            xregion_bytes: 0,
            xregion_usd: 0.0,
            outages: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_region_with_outage() -> ClusterTopology {
        ClusterTopology::builder("tr")
            .domain("us-east-1a", "us-east-1")
            .domain("us-west-2a", "us-west-2")
            .fault(FaultKind::AzOutage, "us-east-1a", 30, 60, 1.0)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_and_queries() {
        let t = two_region_with_outage();
        assert_eq!(t.domain_count(), 2);
        assert_eq!(t.home_region(), "us-east-1");
        assert_eq!(t.index_of("us-west-2a"), Some(1));
        assert!(!t.is_cross_region(0));
        assert!(t.is_cross_region(1));
        let (start, end) = t.faults[0].window_ms();
        assert_eq!((start, end), (30 * MINUTE, 90 * MINUTE));
    }

    #[test]
    fn validation_rejects_bad_topologies() {
        assert!(matches!(
            ClusterTopology::builder("t").build(),
            Err(TopologyError::Empty { .. })
        ));
        assert!(matches!(
            ClusterTopology::builder("t")
                .domain("a", "r")
                .domain("a", "r")
                .build(),
            Err(TopologyError::DuplicateDomain { .. })
        ));
        assert!(matches!(
            ClusterTopology::builder("t")
                .domain("a", "r")
                .fault(FaultKind::AzOutage, "ghost", 0, 10, 1.0)
                .build(),
            Err(TopologyError::UnknownDomain { .. })
        ));
        assert!(matches!(
            ClusterTopology::builder("t")
                .domain("a", "r")
                .fault(FaultKind::AzOutage, "a", 0, 0, 1.0)
                .build(),
            Err(TopologyError::EmptyWindow { .. })
        ));
        assert!(matches!(
            ClusterTopology::builder("t")
                .domain("a", "r")
                .fault(FaultKind::PriceStorm, "a", 0, 10, 0.0)
                .build(),
            Err(TopologyError::BadMagnitude { .. })
        ));
    }

    #[test]
    fn render_parse_round_trip_is_bit_identical() {
        let t = two_region_with_outage();
        let text = t.render();
        let back = ClusterTopology::parse(&text).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.render(), text);
    }

    #[test]
    fn parse_rejects_unknown_keys_and_bad_shapes() {
        assert!(matches!(
            ClusterTopology::parse(r#"{"NAME": "t", "DOMAINS": [], "EXTRA": 1}"#),
            Err(TopologyError::Parse(_))
        ));
        assert!(matches!(
            ClusterTopology::parse(r#"{"NAME": "t", "DOMAINS": [{"name": "a", "color": "red"}]}"#),
            Err(TopologyError::Parse(_))
        ));
        assert!(matches!(
            ClusterTopology::parse(r#"{"DOMAINS": [{"name": "a", "region": "r"}]}"#),
            Err(TopologyError::Parse(_))
        ));
        assert!(matches!(
            ClusterTopology::parse(
                r#"{"NAME": "t", "DOMAINS": [{"name": "a", "region": "r"}],
                    "FAULTS": [{"kind": "meteor", "domain": "a"}]}"#
            ),
            Err(TopologyError::Parse(_))
        ));
        // Empty DOMAINS parses as JSON but fails validation.
        assert!(matches!(
            ClusterTopology::parse(r#"{"NAME": "t", "DOMAINS": []}"#),
            Err(TopologyError::Empty { .. })
        ));
    }

    #[test]
    fn shapes_resolve_and_validate() {
        for name in ClusterTopology::SHAPES {
            let t = ClusterTopology::resolve(name).unwrap();
            assert_eq!(t.name, name);
            assert!(t.domain_count() >= 1);
        }
        assert_eq!(ClusterTopology::shape("single").unwrap().domain_count(), 1);
        assert_eq!(ClusterTopology::shape("three-az").unwrap().domain_count(), 3);
        let tr = ClusterTopology::shape("two-region").unwrap();
        assert_eq!(tr.domain_count(), 2);
        assert!(tr.is_cross_region(1));
        assert!(matches!(
            ClusterTopology::resolve("no-such-topology"),
            Err(TopologyError::Unknown(_))
        ));
    }

    #[test]
    fn fault_kind_and_placement_names_round_trip() {
        for k in FaultKind::ALL {
            assert_eq!(FaultKind::parse(k.name()), Some(k));
        }
        assert_eq!(FaultKind::parse("meteor"), None);
        for p in Placement::ALL {
            assert_eq!(Placement::parse(p.name()), Some(p));
        }
        assert_eq!(Placement::parse("bogus"), None);
        assert_eq!(Placement::default(), Placement::Pack);
    }

    #[test]
    fn breakdown_default_is_the_flat_run() {
        let b = TopologyBreakdown::default();
        assert_eq!(b.topology, "single");
        assert_eq!(b.placement, "pack");
        assert!(b.domains.is_empty());
        assert_eq!(b.xregion_bytes, 0);
        assert!(b.outages.is_empty());
    }
}
