//! `ds` — the run.py analog: four single-line commands (plus helpers).
//!
//! ```text
//! ds make-config  --out files/config.json            # template Config
//! ds make-fleet-file --region us-east-1 --out files/fleet.json
//! ds make-job     --plate P1 --wells 96 --sites 4 --out files/job.json
//! ds run          --config files/config.json --job files/job.json \
//!                 --fleet files/fleet.json [--monitor] [--cheapest] \
//!                 [--pjrt artifacts/] [--seed N] [--volatility low|medium|high]
//! ds describe     --config files/config.json         # validate + print
//! ds workloads    [--artifacts artifacts/]           # list AOT artifacts
//! ```
//!
//! `run` performs setup → submitJob → startCluster → (monitor) over the
//! simulated account and prints the run report.  With `--pjrt` the jobs
//! execute the real AOT-compiled pipeline through PJRT.

use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use ds_rs::aws::ec2::Volatility;
use ds_rs::cli::Args;
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::runtime::{Manifest, PjrtRuntime};
use ds_rs::sim::clock::from_secs_f64;
use ds_rs::workloads::{DurationModel, ModeledExecutor, PjrtExecutor};

fn main() -> ExitCode {
    let args = Args::from_env();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("make-config") => make_config(args),
        Some("make-fleet-file") => make_fleet_file(args),
        Some("make-job") => make_job(args),
        Some("describe") => describe(args),
        Some("workloads") => workloads(args),
        Some("run") => run(args),
        Some(other) => bail!(
            "unknown command '{other}' (try: make-config, make-fleet-file, make-job, describe, workloads, run)"
        ),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "ds — Distributed-Something, reproduced\n\n\
         commands:\n\
         \x20 make-config      write a template Config file\n\
         \x20 make-fleet-file  write a region-specific Fleet file template\n\
         \x20 make-job         write a plate-layout Job file\n\
         \x20 describe         validate and print a Config file\n\
         \x20 workloads        list available AOT workload artifacts\n\
         \x20 run              setup + submitJob + startCluster (+ monitor)\n\n\
         see README.md for the full walkthrough"
    );
}

fn write_or_print(path: Option<&str>, text: &str) -> Result<()> {
    match path {
        Some(p) => {
            if let Some(dir) = std::path::Path::new(p).parent() {
                std::fs::create_dir_all(dir).ok();
            }
            std::fs::write(p, text).with_context(|| format!("writing {p}"))?;
            println!("wrote {p}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn make_config(args: &Args) -> Result<()> {
    let cfg = AppConfig {
        app_name: args.get_or("app-name", "MyApp").to_string(),
        workload_id: args.get_or("workload", "cp_256_b1").to_string(),
        cluster_machines: args.get_u64("machines", 4) as u32,
        machine_price: args.get_f64("price", 0.10),
        ..Default::default()
    };
    cfg.validate()?;
    write_or_print(args.get("out"), &cfg.to_json().pretty())
}

fn make_fleet_file(args: &Args) -> Result<()> {
    let region = args.get_or("region", "us-east-1");
    let spec = FleetSpec::template(region)
        .with_context(|| format!("no template for region '{region}'"))?;
    write_or_print(args.get("out"), &spec.to_json().pretty())
}

fn make_job(args: &Args) -> Result<()> {
    let plate = args.get_or("plate", "Plate1");
    let wells = args.get_u64("wells", 96) as u32;
    let sites = args.get_u64("sites", 4) as u32;
    let jobs = JobSpec::plate(
        plate,
        wells,
        sites,
        vec![
            ("input_prefix".into(), "input".into()),
            ("output_prefix".into(), "output".into()),
            ("output_bucket".into(), "ds-data".into()),
        ],
    );
    write_or_print(args.get("out"), &jobs.to_json().pretty())
}

fn load_config(args: &Args) -> Result<AppConfig> {
    let path = args
        .get("config")
        .context("--config files/config.json required")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    AppConfig::from_json(&text).context("parsing Config file")
}

fn describe(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    println!("{}", cfg.to_json().pretty());
    println!(
        "\nderived: task_family={} service={} instance_log_group={}",
        cfg.task_family(),
        cfg.service_name(),
        cfg.instance_log_group()
    );
    Ok(())
}

fn workloads(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let man = Manifest::load(dir)?;
    println!(
        "{:<24} {:<14} {:>12} {:>10}",
        "name", "kind", "input f32s", "out f32s"
    );
    for name in man.names() {
        let w = man.get(name)?;
        println!(
            "{:<24} {:<14} {:>12} {:>10}",
            w.name,
            format!("{:?}", w.kind),
            w.input_lens().iter().sum::<usize>(),
            w.output_len
        );
    }
    Ok(())
}

fn parse_volatility(s: &str) -> Result<Volatility> {
    Ok(match s {
        "low" => Volatility::Low,
        "medium" => Volatility::Medium,
        "high" => Volatility::High,
        other => bail!("volatility must be low|medium|high, got '{other}'"),
    })
}

fn run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let job_path = args.get("job").context("--job files/job.json required")?;
    let jobs = JobSpec::from_json(
        &std::fs::read_to_string(job_path).with_context(|| format!("reading {job_path}"))?,
    )
    .context("parsing Job file")?;
    let fleet_path = args
        .get("fleet")
        .context("--fleet files/fleet.json required")?;
    let fleet = FleetSpec::from_json(
        &std::fs::read_to_string(fleet_path)
            .with_context(|| format!("reading {fleet_path}"))?,
    )
    .context("parsing Fleet file")?;

    let opts = RunOptions {
        seed: args.get_u64("seed", 42),
        volatility: parse_volatility(args.get_or("volatility", "low"))?,
        monitor: !args.flag("no-monitor"),
        cheapest: args.flag("cheapest"),
        crash_mttf: args
            .get("crash-mttf-min")
            .and_then(|v| v.parse::<f64>().ok())
            .map(|m| from_secs_f64(m * 60.0)),
        ..Default::default()
    };

    println!(
        "run: app={} jobs={} machines={} bid=${}/h monitor={} cheapest={}",
        cfg.app_name,
        jobs.groups.len(),
        cfg.cluster_machines,
        cfg.machine_price,
        opts.monitor,
        opts.cheapest
    );

    let report = if let Some(artifacts) = args.get("pjrt") {
        let runtime = PjrtRuntime::new(artifacts)?;
        let mut ex = PjrtExecutor::new(runtime, &cfg.workload_id)?;
        ex.time_scale = args.get_f64("time-scale", 1.0);
        run_full(&cfg, &jobs, &fleet, &mut ex, opts)?
    } else {
        let mut ex = ModeledExecutor {
            model: DurationModel {
                mean_s: args.get_f64("job-mean-s", 90.0),
                cv: args.get_f64("job-cv", 0.3),
                stall_prob: args.get_f64("stall-prob", 0.0),
                fail_prob: args.get_f64("fail-prob", 0.0),
            },
            ..Default::default()
        };
        run_full(&cfg, &jobs, &fleet, &mut ex, opts)?
    };

    println!("\n{}", report.summary());
    Ok(())
}
