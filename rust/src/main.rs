//! `ds` — the run.py analog: four single-line commands (plus helpers).
//!
//! ```text
//! ds make-config  --out files/config.json            # template Config
//! ds make-fleet-file --region us-east-1 --out files/fleet.json
//! ds make-job     --plate P1 --wells 96 --sites 4 --out files/job.json
//! ds run          --config files/config.json --job files/job.json \
//!                 --fleet files/fleet.json [--no-monitor] [--cheapest] \
//!                 [--scaling none|target-tracking|step] [--scaling-target B] \
//!                 [--pjrt artifacts/] [--seed N] [--volatility low|medium|high] \
//!                 [--json]
//! ds sweep        [--plan files/sweep.json] [--dry-run] \
//!                 [--config files/config.json] [--job files/job.json] \
//!                 [--fleet files/fleet.json] \
//!                 --seeds 8 --machines 2,4,8 --visibility-s 120,600 \
//!                 --volatility low,medium --job-mean-s 90,240 \
//!                 --allocation lowest-price,diversified,capacity-optimized \
//!                 --instance-types m5.large+c5.xlarge:2,m5.xlarge \
//!                 --input-mb 0,64,256 --net-profile standard,narrow \
//!                 --scaling none,target-tracking,step --scaling-target 2,4 \
//!                 --workflow none,diamond,mosaic --sharing s3,node-local,shared-fs \
//!                 --topology single,three-az,two-region --placement pack,spread \
//!                 --traffic single,two-tenant,noisy-neighbor \
//!                 --queueing fifo,fair-share,priority \
//!                 [--on-demand-base N] [--threads N] [--json] \
//!                 [--shards N] [--shard-exec process|inproc] \
//!                 [--shard-timeout-s S] [--shard-retries N]
//! ds describe     --config files/config.json [--fleet files/fleet.json]
//!                 [--job files/job.json] [--workflow W] [--topology T]
//!                 [--traffic F]
//!                 # validate + print + the per-type container packing
//!                 # of the machines the run will actually use, the
//!                 # Job file's data footprint (GB in/out), the
//!                 # workflow DAG's stage structure, the topology's
//!                 # domains, per-domain pools, and bucket homes, and
//!                 # the traffic spec's tenants and arrival processes
//! ds workloads    [--artifacts artifacts/]           # list AOT artifacts
//! ```
//!
//! `run` performs setup → submitJob → startCluster → (monitor) over the
//! simulated account and prints the run report.  With `--pjrt` the jobs
//! execute the real AOT-compiled pipeline through PJRT.  `sweep` replays
//! the whole cartesian matrix of scenarios on a worker-thread pool and
//! prints per-scenario aggregates (mean/p50/p95 across seeds); with
//! `--shards N` it partitions the matrix across N worker processes
//! instead, re-invoking this binary as the hidden `shard-worker`
//! subcommand (request on stdin, result on stdout) and merging the
//! partial reports bit-identically.
//!
//! Every sweep axis, its flag, its Sweep-file key, and its help line
//! come from the typed axis registry (`ds_rs::scenario`): the help
//! text, the strict unknown-flag rejection, and the `--plan` file
//! schema are three projections of the same table, so none of them can
//! drift from the parser.

use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use ds_rs::aws::ec2::{instance_type, InstanceSlot};
use ds_rs::aws::ecs::containers_that_fit;
use ds_rs::cli::Args;
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::cluster::fleet_slots;
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::coordinator::shard::{run_sweep_sharded, InProcExecutor, ProcessExecutor, ShardOptions};
use ds_rs::coordinator::sweep::{default_threads, run_sweep, SweepRun};
use ds_rs::json::Value;
use ds_rs::runtime::{Manifest, PjrtRuntime};
use ds_rs::scenario::{
    describe_matrix, plan_from_cli, render_flag_specs, render_matrix_entries, run_flags,
    sweep_flags, Axis, ScenarioMatrix, SweepFile, AXES,
};
use ds_rs::sim::clock::from_secs_f64;
use ds_rs::workloads::{ModeledExecutor, PjrtExecutor};

fn main() -> ExitCode {
    let args = Args::from_env();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("make-config") => make_config(args),
        Some("make-fleet-file") => make_fleet_file(args),
        Some("make-job") => make_job(args),
        Some("describe") => describe(args),
        Some("workloads") => workloads(args),
        Some("run") => run(args),
        Some("sweep") => sweep(args),
        // Hidden: the child half of `ds sweep --shards N`.  Not listed
        // in usage or the unknown-command hint — it is wire plumbing,
        // not a user-facing command.
        Some("shard-worker") => shard_worker_cmd(),
        Some(other) => bail!(
            "unknown command '{other}' (try: make-config, make-fleet-file, make-job, describe, workloads, run, sweep)"
        ),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "ds — Distributed-Something, reproduced\n\n\
         commands:\n\
         \x20 make-config      write a template Config file\n\
         \x20 make-fleet-file  write a region-specific Fleet file template\n\
         \x20 make-job         write a plate-layout Job file\n\
         \x20 describe         validate and print a Config file (+ per-type packing)\n\
         \x20 workloads        list available AOT workload artifacts\n\
         \x20 run              setup + submitJob + startCluster (+ monitor)\n\
         \x20 sweep            parallel scenario matrix with aggregate analytics\n\n\
         run flags (`ds run --help`):\n{}\n\
         sweep flags (`ds sweep --help`; unknown flags are rejected):\n{}\n\
         see README.md for the full walkthrough",
        render_flag_specs(&run_flags()),
        render_flag_specs(&sweep_flags())
    );
}

fn write_or_print(path: Option<&str>, text: &str) -> Result<()> {
    match path {
        Some(p) => {
            if let Some(dir) = std::path::Path::new(p).parent() {
                std::fs::create_dir_all(dir).ok();
            }
            std::fs::write(p, text).with_context(|| format!("writing {p}"))?;
            println!("wrote {p}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn make_config(args: &Args) -> Result<()> {
    let cfg = AppConfig {
        app_name: args.get_or("app-name", "MyApp").to_string(),
        workload_id: args.get_or("workload", "cp_256_b1").to_string(),
        cluster_machines: parse_scalar(args, "machines", 4u32)?,
        machine_price: parse_scalar(args, "price", 0.10f64)?,
        ..Default::default()
    };
    cfg.validate()?;
    write_or_print(args.get("out"), &cfg.to_json().pretty())
}

fn make_fleet_file(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!(
            "ds make-fleet-file [--region R] [--out FILE]\n\n\
             Writes a region-specific Fleet file template (regions: us-east-1,\n\
             us-west-2, eu-west-1).  Edit the account fields (ARNs, key, subnet,\n\
             security groups) before a real deployment; the AMI must stay the\n\
             region's template AMI.\n\n\
             Fleet-shaping keys (drive the simulated spot fleet):\n\
             \x20 INSTANCE_TYPES       launch specs, \"name\" or \"name:weight\"\n\
             \x20                      (e.g. [\"m5.large\", \"m5.xlarge:2\"]); empty\n\
             \x20                      inherits the Config's MACHINE_TYPE at weight 1\n\
             \x20 ALLOCATION_STRATEGY  lowest-price | diversified | capacity-optimized\n\
             \x20 ON_DEMAND_BASE       weighted units kept on-demand (flat-billed,\n\
             \x20                      never interrupted); must be <= CLUSTER_MACHINES"
        );
        return Ok(());
    }
    let region = args.get_or("region", "us-east-1");
    let spec = FleetSpec::template(region)
        .with_context(|| format!("no template for region '{region}'"))?;
    write_or_print(args.get("out"), &spec.to_json().pretty())
}

fn make_job(args: &Args) -> Result<()> {
    let plate = args.get_or("plate", "Plate1");
    let wells = parse_scalar(args, "wells", 96u32)?;
    let sites = parse_scalar(args, "sites", 4u32)?;
    let jobs = JobSpec::plate(
        plate,
        wells,
        sites,
        vec![
            ("input_prefix".into(), "input".into()),
            ("output_prefix".into(), "output".into()),
            ("output_bucket".into(), "ds-data".into()),
        ],
    );
    write_or_print(args.get("out"), &jobs.to_json().pretty())
}

fn load_config(args: &Args) -> Result<AppConfig> {
    let path = args
        .get("config")
        .context("--config files/config.json required")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    AppConfig::from_json(&text).context("parsing Config file")
}

fn describe(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    println!("{}", cfg.to_json().pretty());
    // With --job, describe the data footprint the run will move through
    // the S3 data plane (0 GB = pure duration-model jobs).
    if let Some(p) = args.get("job") {
        let jobs = JobSpec::from_json(
            &std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
        )
        .context("parsing Job file")?;
        let (input, output) = jobs.data_footprint();
        let n = jobs.groups.len().max(1) as f64;
        println!(
            "\njob data footprint: {} groups, {:.2} GB in / {:.2} GB out total \
             ({:.1} MB in / {:.1} MB out per group mean)",
            jobs.groups.len(),
            input as f64 / 1e9,
            output as f64 / 1e9,
            input as f64 / n / 1e6,
            output as f64 / n / 1e6,
        );
    }
    // With --workflow, validate and summarize the DAG the run would
    // schedule (canonical shape name or Workflow file).  Cycles and
    // unknown job references surface here as typed errors, before any
    // run burns fleet time on a workload that can never finish.
    if let Some(w) = args.get("workflow") {
        let spec = ds_rs::workflow::WorkflowSpec::resolve(w)
            .with_context(|| format!("describing workflow '{w}'"))?;
        let depths = spec.depths();
        println!(
            "\nworkflow '{}': {} nodes, {} edges, critical path {} stage(s), \
             {} root(s), fingerprint {:016x}",
            spec.name,
            spec.jobs.len(),
            spec.edges.len(),
            spec.critical_path_len(),
            depths.iter().filter(|&&d| d == 0).count(),
            spec.fingerprint(),
        );
        for d in 0..=depths.iter().copied().max().unwrap_or(0) {
            let stage: Vec<&str> = spec
                .jobs
                .iter()
                .zip(&depths)
                .filter(|(_, dd)| **dd == d)
                .map(|(j, _)| j.name.as_str())
                .collect();
            println!("  stage {d}: {}", stage.join(", "));
        }
    }
    println!(
        "\nderived: task_family={} service={} instance_log_group={}",
        cfg.task_family(),
        cfg.service_name(),
        cfg.instance_log_group()
    );
    // With --fleet, describe the machines the run will REALLY use: the
    // Fleet file's INSTANCE_TYPES override the Config's MACHINE_TYPE.
    let fleet = match args.get("fleet") {
        Some(p) => Some(
            FleetSpec::from_json(
                &std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
            )
            .context("parsing Fleet file")?,
        ),
        None => None,
    };
    let slots: Vec<InstanceSlot> = match &fleet {
        Some(f) => fleet_slots(&cfg, f),
        None => cfg
            .machine_types
            .iter()
            .map(|t| InstanceSlot::new(t.as_str()))
            .collect(),
    };
    if let Some(f) = &fleet {
        println!(
            "fleet: allocation={} on_demand_base={}",
            f.allocation_strategy.name(),
            f.on_demand_base
        );
    }
    // Per-type packing: what ECS will actually fit on each allowed
    // machine (the paper's "too large / too small Docker" caveat).
    println!(
        "placement ({} CPU shares, {} MB per container, intent {}/machine):",
        cfg.cpu_shares, cfg.memory_mb, cfg.tasks_per_machine
    );
    for slot in &slots {
        // Both files' validation guarantees the type exists.
        let ty = instance_type(&slot.name).expect("validated type");
        let fit = containers_that_fit(cfg.cpu_shares, cfg.memory_mb, ty);
        let note = if fit == 0 {
            "  <- Docker larger than the machine: never placed"
        } else if fit < cfg.tasks_per_machine {
            "  <- fewer than TASKS_PER_MACHINE fit"
        } else if fit > cfg.tasks_per_machine {
            "  <- ECS will overpack beyond TASKS_PER_MACHINE"
        } else {
            ""
        };
        println!("  {}: fits {fit}{note}", slot.render());
    }
    // With --topology, validate and summarize the failure-domain layout
    // capacity would place over (built-in shape name or TOPOLOGY file),
    // mirroring --workflow: bad specs surface here as typed errors
    // before any run burns fleet time.
    if let Some(t) = args.get("topology") {
        let topo = ds_rs::topology::ClusterTopology::resolve(t)
            .with_context(|| format!("describing topology '{t}'"))?;
        println!(
            "\ntopology '{}': {} failure domain(s), {} fault window(s); home region {}",
            topo.name,
            topo.domain_count(),
            topo.faults.len(),
            topo.home_region(),
        );
        for (i, d) in topo.domains.iter().enumerate() {
            let bucket = if topo.is_cross_region(i) {
                format!("{} (cross-region: egress billed)", topo.home_region())
            } else {
                d.region.clone()
            };
            let pools: Vec<String> = slots
                .iter()
                .map(|s| format!("{}@{}", s.name, d.name))
                .collect();
            println!(
                "  domain {i}: {} in {} — bucket home {bucket}; pools {}",
                d.name,
                d.region,
                pools.join(", ")
            );
        }
        for f in &topo.faults {
            let (start, end) = f.window_ms();
            println!(
                "  fault: {} on {} from {}m to {}m (magnitude {})",
                f.kind.name(),
                f.domain,
                start / 60_000,
                end / 60_000,
                f.magnitude
            );
        }
    }
    // With --traffic, validate and summarize the multi-tenant arrival
    // plan (built-in shape name or TRAFFIC file), mirroring --workflow
    // and --topology: undeclared tenants, zero rates, and stray process
    // parameters surface here as typed errors before any run burns
    // fleet time.
    if let Some(t) = args.get("traffic") {
        let spec = ds_rs::traffic::TrafficSpec::resolve(t)
            .with_context(|| format!("describing traffic '{t}'"))?;
        println!(
            "\ntraffic '{}': {} tenant(s), {} jobs total",
            spec.name,
            spec.tenants.len(),
            spec.total_jobs(),
        );
        for tenant in &spec.tenants {
            let arrival = spec
                .arrivals
                .iter()
                .find(|a| a.tenant == tenant.name)
                .expect("validated spec pairs every tenant with an arrival");
            println!(
                "  tenant {}: {} jobs, weight {}, priority {}, SLO wait {}s — \
                 {} arrivals, mean {:.2}/min",
                tenant.name,
                tenant.jobs,
                tenant.weight,
                tenant.priority,
                tenant.slo_wait_s,
                arrival.process.kind(),
                arrival.process.mean_rate_per_min(),
            );
        }
    }
    Ok(())
}

fn workloads(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let man = Manifest::load(dir)?;
    println!(
        "{:<24} {:<14} {:>12} {:>10}",
        "name", "kind", "input f32s", "out f32s"
    );
    for name in man.names() {
        let w = man.get(name)?;
        println!(
            "{:<24} {:<14} {:>12} {:>10}",
            w.name,
            format!("{:?}", w.kind),
            w.input_lens().iter().sum::<usize>(),
            w.output_len
        );
    }
    Ok(())
}

/// Strict scalar flag (anyhow-flavored wrapper over [`Args::try_parse`]).
fn parse_scalar<T: std::str::FromStr>(args: &Args, name: &str, default: T) -> Result<T> {
    args.try_parse(name, default).map_err(|e| anyhow!(e))
}

/// `ds shard-worker` (hidden): read a `SweepShardRequest` envelope from
/// stdin, run the assigned cells, write the `ShardResult` envelope to
/// stdout.  All human-facing chatter belongs on stderr — stdout is the
/// wire.
fn shard_worker_cmd() -> Result<()> {
    use std::io::Read as _;
    let mut input = String::new();
    std::io::stdin()
        .read_to_string(&mut input)
        .context("reading shard request from stdin")?;
    if let Some(faulted) = injected_fault(&input) {
        return faulted;
    }
    let output = ds_rs::coordinator::shard::shard_worker(&input)?;
    println!("{output}");
    Ok(())
}

/// Test-only fault hooks for the real-process supervision tests: a
/// worker that genuinely dies / hangs / prints garbage, armed through
/// the child's environment so nothing can trip in production use.
///
/// * `DS_SHARD_FAULT` = `kill` | `hang` | `garbage` arms the fault.
/// * `DS_SHARD_FAULT_SHARD` = N restricts it to the shard whose request
///   carries `assignment.index == N` (default: every shard).
/// * `DS_SHARD_FAULT_ONCE` = PATH makes it one-shot across retries: the
///   fault only trips while PATH does not exist and creates PATH when it
///   does — the retried fresh process then runs clean.
///
/// Returns `None` when no fault trips (the normal path).
fn injected_fault(input: &str) -> Option<Result<()>> {
    let fault = std::env::var("DS_SHARD_FAULT").ok()?;
    if let Ok(only) = std::env::var("DS_SHARD_FAULT_SHARD") {
        let shard = ds_rs::json::parse(input.trim())
            .ok()?
            .get("assignment")?
            .get("index")?
            .as_u64()?;
        if only != shard.to_string() {
            return None;
        }
    }
    if let Ok(marker) = std::env::var("DS_SHARD_FAULT_ONCE") {
        if std::path::Path::new(&marker).exists() {
            return None;
        }
        std::fs::write(&marker, b"tripped").ok();
    }
    match fault.as_str() {
        "kill" => {
            eprintln!("worker killed mid-shard (injected)");
            std::process::abort();
        }
        "hang" => loop {
            std::thread::sleep(std::time::Duration::from_millis(50));
        },
        "garbage" => {
            println!("{{\"cells\": [tru");
            Some(Ok(()))
        }
        other => Some(Err(anyhow!("unknown DS_SHARD_FAULT '{other}'"))),
    }
}

/// `ds run`: the four-command flow for one configuration.  The axis
/// flags it shares with `ds sweep` (volatility, duration model, input
/// MB, net profile) parse through the same registry but must carry a
/// single value; machines, visibility, and the fleet shape come from
/// the Config and Fleet files, as in the paper.
fn run(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!(
            "ds run — setup + submitJob + startCluster (+ monitor)\n\n\
             Axis flags shared with `ds sweep` take a single value here.\n\n\
             flags:\n{}",
            render_flag_specs(&run_flags())
        );
        return Ok(());
    }
    // Same strictness as sweep: a typo'd or sweep-only flag (--machines,
    // --allocation…) must not silently run a different study.
    let known: Vec<&str> = run_flags().iter().map(|f| f.flag).collect();
    let unknown = args.unknown_flags(&known);
    if !unknown.is_empty() {
        bail!(
            "unknown flag --{} for run (see `ds run --help`)",
            unknown.join(", --")
        );
    }
    let cfg = load_config(args)?;
    let job_path = args.get("job").context("--job files/job.json required")?;
    let jobs = JobSpec::from_json(
        &std::fs::read_to_string(job_path).with_context(|| format!("reading {job_path}"))?,
    )
    .context("parsing Job file")?;
    let fleet_path = args
        .get("fleet")
        .context("--fleet files/fleet.json required")?;
    let fleet = FleetSpec::from_json(
        &std::fs::read_to_string(fleet_path)
            .with_context(|| format!("reading {fleet_path}"))?,
    )
    .context("parsing Fleet file")?;

    // Parse the shared axes into a one-scenario matrix.
    let mut matrix = ScenarioMatrix::defaults_from(&cfg);
    for ax in AXES {
        if ax.in_run() {
            ax.parse_cli(args, &mut matrix)?;
        }
    }
    let scenarios = matrix.scenarios();
    if scenarios.len() != 1 {
        bail!(
            "ds run takes a single value per axis flag (got {} combinations); \
             use `ds sweep` for matrices",
            scenarios.len()
        );
    }

    let base_opts = RunOptions {
        seed: parse_scalar(args, "seed", 42u64)?,
        monitor: !args.flag("no-monitor"),
        cheapest: args.flag("cheapest"),
        queue_downscale: args.flag("queue-downscale"),
        crash_mttf: if args.flag("crash-mttf-min") {
            Some(from_secs_f64(
                parse_scalar(args, "crash-mttf-min", 0.0f64)? * 60.0,
            ))
        } else {
            None
        },
        ..Default::default()
    };
    let cell = scenarios[0].run_inputs(&cfg, &fleet, &base_opts);
    // A non-zero input-MB axis overlays a data shape on the Job file:
    // every job gains download + upload phases on the S3 data plane.
    let jobs = if cell.input_mb > 0.0 {
        jobs.with_data_shape((cell.input_mb * 1e6) as u64, cell.opts.seed)
    } else {
        jobs
    };

    let preamble = format!(
        "run: app={} jobs={} machines={} bid=${}/h monitor={} cheapest={} scaling={}",
        cell.cfg.app_name,
        jobs.groups.len(),
        cell.cfg.cluster_machines,
        cell.cfg.machine_price,
        cell.opts.monitor,
        cell.opts.cheapest,
        cell.opts
            .scaling
            .as_ref()
            .map(|p| p.name())
            .unwrap_or("none"),
    );
    // Keep stdout machine-parseable under --json: chatter goes to stderr.
    if args.flag("json") {
        eprintln!("{preamble}");
    } else {
        println!("{preamble}");
    }

    let report = if let Some(artifacts) = args.get("pjrt") {
        let runtime = PjrtRuntime::new(artifacts)?;
        let mut ex = PjrtExecutor::new(runtime, &cell.cfg.workload_id)?;
        ex.time_scale = parse_scalar(args, "time-scale", 1.0f64)?;
        run_full(&cell.cfg, &jobs, &cell.fleet, &mut ex, cell.opts)?
    } else {
        let mut ex = ModeledExecutor {
            model: cell.model.clone(),
            ..Default::default()
        };
        run_full(&cell.cfg, &jobs, &cell.fleet, &mut ex, cell.opts)?
    };

    if args.flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        println!("\n{}", report.summary());
    }
    Ok(())
}

/// `ds sweep` — the scenario-matrix front door.  Every axis flag is a
/// comma-separated list, so `ds sweep --machines 2,4,8 --seeds 8` is a
/// plain one-axis scaling study with per-scenario mean/p50/p95 across 8
/// seeds.  A `--plan` Sweep file declares the same matrix as a fourth
/// paper-style KEY-value file, with CLI flags overriding file keys.
/// Absent axes collapse to a single value: machines and visibility
/// inherit from the (base) config, while volatility and the duration
/// model fall back to fixed defaults (low, 90 s mean) since the Config
/// file does not carry them.  `--fleet` is optional; without it the
/// builtin us-east-1 template fleet is used.
fn sweep(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!(
            "ds sweep — parallel scenario matrix with aggregate analytics\n\n\
             Every axis flag takes a comma-separated list; the scenarios are the\n\
             cartesian product of all axes, replicated over --seeds seeds.  With\n\
             --plan FILE the same matrix comes from a Sweep file (KEY-value JSON,\n\
             keys = the flags below in SCREAMING_CASE); CLI flags override file\n\
             keys, and --dry-run prints the expanded matrix without running.\n\n\
             flags:\n{}",
            render_flag_specs(&sweep_flags())
        );
        return Ok(());
    }
    // A stray positional is almost always a space where a comma belonged
    // (`--machines 2 4`); running the shrunken matrix silently would be
    // exactly the wrong-study failure the strict flag parsing prevents.
    if let Some(stray) = args.positionals.first() {
        bail!("unexpected argument '{stray}' (list flags take comma-separated values, e.g. --machines 2,4,8)");
    }
    // Same logic for a typo'd flag: reject anything outside the registry.
    let known: Vec<&str> = sweep_flags().iter().map(|f| f.flag).collect();
    let unknown = args.unknown_flags(&known);
    if !unknown.is_empty() {
        bail!(
            "unknown flag --{} for sweep (see `ds sweep --help`)",
            unknown.join(", --")
        );
    }

    let file = match args.get("plan") {
        Some(path) => Some(SweepFile::load(path)?),
        // A forgotten value must not silently run a default study.
        None if args.flag("plan") => bail!("missing value for --plan"),
        None => None,
    };
    let plan = plan_from_cli(args, file.as_ref())?;
    let threads = parse_scalar(args, "threads", default_threads())?.max(1);
    // --shards 0 (the default) keeps the single-process engine; N > 0
    // partitions the matrix across N worker processes (or in-process
    // workers under --shard-exec inproc, the test/debug path).
    let shards = parse_scalar(args, "shards", 0usize)?;
    let shard_exec = args.get_or("shard-exec", "process").to_string();
    if !matches!(shard_exec.as_str(), "process" | "inproc") {
        bail!("unknown --shard-exec '{shard_exec}' (expected process or inproc)");
    }

    // Counts come from the registry's per-axis lengths, not from
    // expanding the product — a dry run of a 10^8-scenario file must
    // not allocate 10^8 scenarios.
    let scenario_count = plan.matrix.scenario_count();
    if args.flag("dry-run") {
        if args.flag("json") {
            // --json keeps stdout machine-parseable in the dry path too.
            let mut axes = Value::obj();
            for (key, val) in render_matrix_entries(&plan.matrix) {
                axes = axes.with(key, val);
            }
            let out = Value::obj()
                .with("scenarios", scenario_count)
                .with("cells", plan.matrix.cell_count())
                .with("seeds", plan.matrix.seeds.len())
                .with("jobs_per_cell", plan.jobs.groups.len())
                .with("axes", axes);
            println!("{}", out.pretty());
            return Ok(());
        }
        let seeds = &plan.matrix.seeds;
        // Summarize big seed lists instead of flooding the terminal.
        let seeds_desc = if seeds.len() <= 16 {
            format!("{seeds:?}")
        } else {
            format!(
                "[{} .. {}] ({} values)",
                seeds.first().unwrap(),
                seeds.last().unwrap(),
                seeds.len()
            )
        };
        println!(
            "sweep plan (dry run):\n{}\
             \x20 seeds: {} ({})\n\
             \x20 scenarios: {}  cells: {} (scenarios x seeds)  jobs/cell: {}",
            describe_matrix(&plan.matrix),
            seeds.len(),
            seeds_desc,
            scenario_count,
            plan.matrix.cell_count(),
            plan.jobs.groups.len(),
        );
        // Workflow cells get one structural line each — the DAG is the
        // only axis whose value is a whole graph, so the one-word
        // describe_matrix entry undersells what will actually run.
        for spec in plan.matrix.workflows.iter().flatten() {
            println!(
                "  workflow {}: {} nodes, {} edges, critical path {} stage(s)",
                spec.name,
                spec.jobs.len(),
                spec.edges.len(),
                spec.critical_path_len(),
            );
        }
        return Ok(());
    }

    let sharding = if shards > 0 {
        format!(" across {shards} {shard_exec} shards")
    } else {
        String::new()
    };
    let preamble = format!(
        "sweep: {} scenarios x {} seeds = {} cells on {} threads{sharding} ({} jobs/cell)",
        scenario_count,
        plan.matrix.seeds.len(),
        plan.matrix.cell_count(),
        threads,
        plan.jobs.groups.len(),
    );
    // Keep stdout machine-parseable under --json: chatter goes to stderr.
    if args.flag("json") {
        eprintln!("{preamble}");
    } else {
        println!("{preamble}");
    }

    let t0 = std::time::Instant::now();
    let run: SweepRun = if shards > 0 {
        let opts = ShardOptions {
            shards,
            threads,
            retries: parse_scalar(args, "shard-retries", 2usize)?,
        };
        let timeout =
            std::time::Duration::from_secs(parse_scalar(args, "shard-timeout-s", 600u64)?);
        if shard_exec == "inproc" {
            run_sweep_sharded(&plan, &opts, &InProcExecutor)?
        } else {
            let exec = ProcessExecutor::current_exe(timeout)
                .context("locating the ds binary to spawn shard workers")?;
            run_sweep_sharded(&plan, &opts, &exec)?
        }
    } else {
        run_sweep(&plan, threads)?
    };
    let wall = t0.elapsed().as_secs_f64();

    if args.flag("json") {
        println!("{}", run.report.to_json().pretty());
    } else {
        println!("\n{}", run.report.table().render());
    }
    eprintln!(
        "{} cells ({} simulated jobs) in {wall:.2}s wall",
        run.cells.len(),
        run.report.total_completed(),
    );
    Ok(())
}
