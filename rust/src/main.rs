//! `ds` — the run.py analog: four single-line commands (plus helpers).
//!
//! ```text
//! ds make-config  --out files/config.json            # template Config
//! ds make-fleet-file --region us-east-1 --out files/fleet.json
//! ds make-job     --plate P1 --wells 96 --sites 4 --out files/job.json
//! ds run          --config files/config.json --job files/job.json \
//!                 --fleet files/fleet.json [--monitor] [--cheapest] \
//!                 [--pjrt artifacts/] [--seed N] [--volatility low|medium|high]
//! ds sweep        [--config files/config.json] [--job files/job.json] \
//!                 [--fleet files/fleet.json] \
//!                 --seeds 8 --machines 2,4,8 --visibility-s 120,600 \
//!                 --volatility low,medium --job-mean-s 90,240 \
//!                 --allocation lowest-price,diversified,capacity-optimized \
//!                 --instance-types m5.large+c5.xlarge:2,m5.xlarge \
//!                 --input-mb 0,64,256 --net-profile standard,narrow \
//!                 [--on-demand-base N] [--threads N] [--json]
//! ds describe     --config files/config.json [--fleet files/fleet.json]
//!                 [--job files/job.json]
//!                 # validate + print + the per-type container packing
//!                 # of the machines the run will actually use, and the
//!                 # Job file's data footprint (GB in/out)
//! ds workloads    [--artifacts artifacts/]           # list AOT artifacts
//! ```
//!
//! `run` performs setup → submitJob → startCluster → (monitor) over the
//! simulated account and prints the run report.  With `--pjrt` the jobs
//! execute the real AOT-compiled pipeline through PJRT.  `sweep` replays
//! the whole cartesian matrix of scenarios on a worker-thread pool and
//! prints per-scenario aggregates (mean/p50/p95 across seeds).

use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use ds_rs::aws::ec2::{instance_type, AllocationStrategy, InstanceSlot, Volatility};
use ds_rs::aws::ecs::containers_that_fit;
use ds_rs::aws::s3::dataplane::NetProfile;
use ds_rs::cli::Args;
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::cluster::fleet_slots;
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::coordinator::sweep::{default_threads, run_sweep, ScenarioMatrix, SweepPlan};
use ds_rs::runtime::{Manifest, PjrtRuntime};
use ds_rs::sim::clock::from_secs_f64;
use ds_rs::sim::SimTime;
use ds_rs::workloads::{DurationModel, ModeledExecutor, PjrtExecutor};

/// One documented flag: name, value placeholder (empty = boolean), help.
/// `sweep` renders its help from this table *and* rejects flags not in
/// it, so the documentation and the strict parser cannot drift apart.
struct Flag {
    name: &'static str,
    value: &'static str,
    help: &'static str,
}

/// Every flag `sweep` reads — the audit table (`tests/cli.rs` pins that
/// typos are rejected against it).
const SWEEP_FLAGS: &[Flag] = &[
    Flag { name: "config", value: "FILE", help: "base Config file (default: built-in defaults)" },
    Flag { name: "job", value: "FILE", help: "Job file replayed by every cell (default: synthetic plate)" },
    Flag { name: "fleet", value: "FILE", help: "Fleet file (default: built-in us-east-1 template)" },
    Flag { name: "plate", value: "NAME", help: "synthetic plate name when no --job (default P1)" },
    Flag { name: "wells", value: "N", help: "synthetic plate wells when no --job (default 24)" },
    Flag { name: "sites", value: "N", help: "synthetic plate sites/well when no --job (default 2)" },
    Flag { name: "seeds", value: "N", help: "replicate seeds per scenario (default 4)" },
    Flag { name: "seed-base", value: "N", help: "first seed value (default 0)" },
    Flag { name: "machines", value: "N,N,..", help: "CLUSTER_MACHINES axis (weighted units)" },
    Flag { name: "visibility-s", value: "S,S,..", help: "SQS_MESSAGE_VISIBILITY axis, seconds" },
    Flag { name: "volatility", value: "V,V,..", help: "market axis: low|medium|high" },
    Flag { name: "allocation", value: "A,A,..", help: "fleet allocation axis: lowest-price|diversified|capacity-optimized" },
    Flag { name: "instance-types", value: "T+T,..", help: "instance-set axis; sets comma-separated, types '+'-joined, each 'name[:weight]' (e.g. m5.large+c5.xlarge:2)" },
    Flag { name: "on-demand-base", value: "N", help: "weighted units kept on-demand in every cell (default: Fleet file's)" },
    Flag { name: "job-mean-s", value: "S,S,..", help: "modeled mean job duration axis, seconds (default 90)" },
    Flag { name: "job-cv", value: "X", help: "duration coefficient of variation (default 0.3)" },
    Flag { name: "stall-prob", value: "P", help: "per-job stall probability (default 0)" },
    Flag { name: "fail-prob", value: "P", help: "per-job fast-failure probability (default 0)" },
    Flag { name: "input-mb", value: "MB,MB,..", help: "mean input MB per job axis; non-zero adds download/compute/upload phases on the S3 data plane (default 0)" },
    Flag { name: "net-profile", value: "P,P,..", help: "network profile axis: wide|standard|narrow (bucket throughput + first-byte latency)" },
    Flag { name: "threads", value: "N", help: "worker threads (default: available cores)" },
    Flag { name: "json", value: "", help: "emit the report as JSON on stdout (chatter to stderr)" },
    Flag { name: "help", value: "", help: "show this help" },
];

/// Flags `run` reads (help only; run stays permissive for compatibility).
const RUN_FLAGS: &[Flag] = &[
    Flag { name: "config", value: "FILE", help: "Config file (required)" },
    Flag { name: "job", value: "FILE", help: "Job file (required)" },
    Flag { name: "fleet", value: "FILE", help: "Fleet file (required)" },
    Flag { name: "seed", value: "N", help: "simulation seed (default 42)" },
    Flag { name: "volatility", value: "V", help: "market volatility: low|medium|high (default low)" },
    Flag { name: "no-monitor", value: "", help: "skip the Step-4 monitor (leaks resources, as in the paper)" },
    Flag { name: "cheapest", value: "", help: "monitor cheapest mode (downscale requested capacity after 15 min; excludes --queue-downscale)" },
    Flag { name: "queue-downscale", value: "", help: "monitor terminates surplus machines as the queue drains, cheapest pool last (excludes --cheapest)" },
    Flag { name: "crash-mttf-min", value: "M", help: "mean minutes to instance crash (default: no crashes)" },
    Flag { name: "pjrt", value: "DIR", help: "run real AOT artifacts from DIR instead of the modeled executor" },
    Flag { name: "time-scale", value: "X", help: "PJRT wall-time to sim-time scale (default 1.0)" },
    Flag { name: "job-mean-s", value: "S", help: "modeled mean job duration, seconds (default 90)" },
    Flag { name: "job-cv", value: "X", help: "duration coefficient of variation (default 0.3)" },
    Flag { name: "stall-prob", value: "P", help: "per-job stall probability (default 0)" },
    Flag { name: "fail-prob", value: "P", help: "per-job fast-failure probability (default 0)" },
    Flag { name: "input-mb", value: "MB", help: "mean input MB per job; non-zero adds download/compute/upload phases on the S3 data plane (default 0)" },
    Flag { name: "net-profile", value: "P", help: "network profile: wide|standard|narrow (default standard)" },
    Flag { name: "help", value: "", help: "show this help" },
];

fn render_flags(flags: &[Flag]) -> String {
    let mut out = String::new();
    for f in flags {
        let lhs = if f.value.is_empty() {
            format!("--{}", f.name)
        } else {
            format!("--{} {}", f.name, f.value)
        };
        out.push_str(&format!("  {lhs:<28} {}\n", f.help));
    }
    out
}

fn main() -> ExitCode {
    let args = Args::from_env();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("make-config") => make_config(args),
        Some("make-fleet-file") => make_fleet_file(args),
        Some("make-job") => make_job(args),
        Some("describe") => describe(args),
        Some("workloads") => workloads(args),
        Some("run") => run(args),
        Some("sweep") => sweep(args),
        Some(other) => bail!(
            "unknown command '{other}' (try: make-config, make-fleet-file, make-job, describe, workloads, run, sweep)"
        ),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "ds — Distributed-Something, reproduced\n\n\
         commands:\n\
         \x20 make-config      write a template Config file\n\
         \x20 make-fleet-file  write a region-specific Fleet file template\n\
         \x20 make-job         write a plate-layout Job file\n\
         \x20 describe         validate and print a Config file (+ per-type packing)\n\
         \x20 workloads        list available AOT workload artifacts\n\
         \x20 run              setup + submitJob + startCluster (+ monitor)\n\
         \x20 sweep            parallel scenario matrix with aggregate analytics\n\n\
         run flags (`ds run --help`):\n{}\n\
         sweep flags (`ds sweep --help`; unknown flags are rejected):\n{}\n\
         see README.md for the full walkthrough",
        render_flags(RUN_FLAGS),
        render_flags(SWEEP_FLAGS)
    );
}

fn write_or_print(path: Option<&str>, text: &str) -> Result<()> {
    match path {
        Some(p) => {
            if let Some(dir) = std::path::Path::new(p).parent() {
                std::fs::create_dir_all(dir).ok();
            }
            std::fs::write(p, text).with_context(|| format!("writing {p}"))?;
            println!("wrote {p}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn make_config(args: &Args) -> Result<()> {
    let cfg = AppConfig {
        app_name: args.get_or("app-name", "MyApp").to_string(),
        workload_id: args.get_or("workload", "cp_256_b1").to_string(),
        cluster_machines: parse_scalar(args, "machines", 4u32)?,
        machine_price: parse_scalar(args, "price", 0.10f64)?,
        ..Default::default()
    };
    cfg.validate()?;
    write_or_print(args.get("out"), &cfg.to_json().pretty())
}

fn make_fleet_file(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!(
            "ds make-fleet-file [--region R] [--out FILE]\n\n\
             Writes a region-specific Fleet file template (regions: us-east-1,\n\
             us-west-2, eu-west-1).  Edit the account fields (ARNs, key, subnet,\n\
             security groups) before a real deployment; the AMI must stay the\n\
             region's template AMI.\n\n\
             Fleet-shaping keys (drive the simulated spot fleet):\n\
             \x20 INSTANCE_TYPES       launch specs, \"name\" or \"name:weight\"\n\
             \x20                      (e.g. [\"m5.large\", \"m5.xlarge:2\"]); empty\n\
             \x20                      inherits the Config's MACHINE_TYPE at weight 1\n\
             \x20 ALLOCATION_STRATEGY  lowest-price | diversified | capacity-optimized\n\
             \x20 ON_DEMAND_BASE       weighted units kept on-demand (flat-billed,\n\
             \x20                      never interrupted); must be <= CLUSTER_MACHINES"
        );
        return Ok(());
    }
    let region = args.get_or("region", "us-east-1");
    let spec = FleetSpec::template(region)
        .with_context(|| format!("no template for region '{region}'"))?;
    write_or_print(args.get("out"), &spec.to_json().pretty())
}

fn make_job(args: &Args) -> Result<()> {
    let plate = args.get_or("plate", "Plate1");
    let wells = parse_scalar(args, "wells", 96u32)?;
    let sites = parse_scalar(args, "sites", 4u32)?;
    let jobs = JobSpec::plate(
        plate,
        wells,
        sites,
        vec![
            ("input_prefix".into(), "input".into()),
            ("output_prefix".into(), "output".into()),
            ("output_bucket".into(), "ds-data".into()),
        ],
    );
    write_or_print(args.get("out"), &jobs.to_json().pretty())
}

fn load_config(args: &Args) -> Result<AppConfig> {
    let path = args
        .get("config")
        .context("--config files/config.json required")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    AppConfig::from_json(&text).context("parsing Config file")
}

fn describe(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    println!("{}", cfg.to_json().pretty());
    // With --job, describe the data footprint the run will move through
    // the S3 data plane (0 GB = pure duration-model jobs).
    if let Some(p) = args.get("job") {
        let jobs = JobSpec::from_json(
            &std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
        )
        .context("parsing Job file")?;
        let (input, output) = jobs.data_footprint();
        let n = jobs.groups.len().max(1) as f64;
        println!(
            "\njob data footprint: {} groups, {:.2} GB in / {:.2} GB out total \
             ({:.1} MB in / {:.1} MB out per group mean)",
            jobs.groups.len(),
            input as f64 / 1e9,
            output as f64 / 1e9,
            input as f64 / n / 1e6,
            output as f64 / n / 1e6,
        );
    }
    println!(
        "\nderived: task_family={} service={} instance_log_group={}",
        cfg.task_family(),
        cfg.service_name(),
        cfg.instance_log_group()
    );
    // With --fleet, describe the machines the run will REALLY use: the
    // Fleet file's INSTANCE_TYPES override the Config's MACHINE_TYPE.
    let fleet = match args.get("fleet") {
        Some(p) => Some(
            FleetSpec::from_json(
                &std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
            )
            .context("parsing Fleet file")?,
        ),
        None => None,
    };
    let slots: Vec<InstanceSlot> = match &fleet {
        Some(f) => fleet_slots(&cfg, f),
        None => cfg
            .machine_types
            .iter()
            .map(|t| InstanceSlot::new(t.as_str()))
            .collect(),
    };
    if let Some(f) = &fleet {
        println!(
            "fleet: allocation={} on_demand_base={}",
            f.allocation_strategy.name(),
            f.on_demand_base
        );
    }
    // Per-type packing: what ECS will actually fit on each allowed
    // machine (the paper's "too large / too small Docker" caveat).
    println!(
        "placement ({} CPU shares, {} MB per container, intent {}/machine):",
        cfg.cpu_shares, cfg.memory_mb, cfg.tasks_per_machine
    );
    for slot in &slots {
        // Both files' validation guarantees the type exists.
        let ty = instance_type(&slot.name).expect("validated type");
        let fit = containers_that_fit(cfg.cpu_shares, cfg.memory_mb, ty);
        let note = if fit == 0 {
            "  <- Docker larger than the machine: never placed"
        } else if fit < cfg.tasks_per_machine {
            "  <- fewer than TASKS_PER_MACHINE fit"
        } else if fit > cfg.tasks_per_machine {
            "  <- ECS will overpack beyond TASKS_PER_MACHINE"
        } else {
            ""
        };
        println!("  {}: fits {fit}{note}", slot.render());
    }
    Ok(())
}

fn workloads(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let man = Manifest::load(dir)?;
    println!(
        "{:<24} {:<14} {:>12} {:>10}",
        "name", "kind", "input f32s", "out f32s"
    );
    for name in man.names() {
        let w = man.get(name)?;
        println!(
            "{:<24} {:<14} {:>12} {:>10}",
            w.name,
            format!("{:?}", w.kind),
            w.input_lens().iter().sum::<usize>(),
            w.output_len
        );
    }
    Ok(())
}

/// Strict scalar flag (anyhow-flavored wrapper over [`Args::try_parse`]).
fn parse_scalar<T: std::str::FromStr>(args: &Args, name: &str, default: T) -> Result<T> {
    args.try_parse(name, default).map_err(|e| anyhow!(e))
}

/// Strict comma-separated flag; `None` when absent.
fn parse_list<T: std::str::FromStr>(args: &Args, name: &str) -> Result<Option<Vec<T>>> {
    args.try_parse_list(name).map_err(|e| anyhow!(e))
}

fn parse_volatility(s: &str) -> Result<Volatility> {
    Ok(match s {
        "low" => Volatility::Low,
        "medium" => Volatility::Medium,
        "high" => Volatility::High,
        other => bail!("volatility must be low|medium|high, got '{other}'"),
    })
}

fn parse_net_profile(s: &str) -> Result<NetProfile> {
    NetProfile::parse(s)
        .ok_or_else(|| anyhow!("net-profile must be wide|standard|narrow, got '{s}'"))
}

fn run(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!("ds run — setup + submitJob + startCluster (+ monitor)\n\nflags:\n{}", render_flags(RUN_FLAGS));
        return Ok(());
    }
    let cfg = load_config(args)?;
    let job_path = args.get("job").context("--job files/job.json required")?;
    let jobs = JobSpec::from_json(
        &std::fs::read_to_string(job_path).with_context(|| format!("reading {job_path}"))?,
    )
    .context("parsing Job file")?;
    let fleet_path = args
        .get("fleet")
        .context("--fleet files/fleet.json required")?;
    let fleet = FleetSpec::from_json(
        &std::fs::read_to_string(fleet_path)
            .with_context(|| format!("reading {fleet_path}"))?,
    )
    .context("parsing Fleet file")?;

    let opts = RunOptions {
        seed: parse_scalar(args, "seed", 42u64)?,
        volatility: parse_volatility(args.get_or("volatility", "low"))?,
        monitor: !args.flag("no-monitor"),
        cheapest: args.flag("cheapest"),
        queue_downscale: args.flag("queue-downscale"),
        crash_mttf: if args.flag("crash-mttf-min") {
            Some(from_secs_f64(
                parse_scalar(args, "crash-mttf-min", 0.0f64)? * 60.0,
            ))
        } else {
            None
        },
        net: parse_net_profile(args.get_or("net-profile", "standard"))?,
        ..Default::default()
    };
    // --input-mb overlays a data shape on the Job file: every job gains
    // download + upload phases on the S3 data plane.
    let input_mb = parse_scalar(args, "input-mb", 0.0f64)?;
    let jobs = if input_mb > 0.0 {
        jobs.with_data_shape((input_mb * 1e6) as u64, opts.seed)
    } else {
        jobs
    };

    println!(
        "run: app={} jobs={} machines={} bid=${}/h monitor={} cheapest={}",
        cfg.app_name,
        jobs.groups.len(),
        cfg.cluster_machines,
        cfg.machine_price,
        opts.monitor,
        opts.cheapest
    );

    let report = if let Some(artifacts) = args.get("pjrt") {
        let runtime = PjrtRuntime::new(artifacts)?;
        let mut ex = PjrtExecutor::new(runtime, &cfg.workload_id)?;
        ex.time_scale = parse_scalar(args, "time-scale", 1.0f64)?;
        run_full(&cfg, &jobs, &fleet, &mut ex, opts)?
    } else {
        let mut ex = ModeledExecutor {
            model: DurationModel {
                mean_s: parse_scalar(args, "job-mean-s", 90.0f64)?,
                cv: parse_scalar(args, "job-cv", 0.3f64)?,
                stall_prob: parse_scalar(args, "stall-prob", 0.0f64)?,
                fail_prob: parse_scalar(args, "fail-prob", 0.0f64)?,
            },
            ..Default::default()
        };
        run_full(&cfg, &jobs, &fleet, &mut ex, opts)?
    };

    println!("\n{}", report.summary());
    Ok(())
}

/// `ds sweep` — the scenario-matrix front door.  Every axis flag is a
/// comma-separated list, so `ds sweep --machines 2,4,8 --seeds 8` is a
/// plain one-axis scaling study with per-scenario mean/p50/p95 across 8
/// seeds.  Absent axes collapse to a single value: machines and
/// visibility inherit from the (base) config, while volatility and the
/// duration model fall back to fixed defaults (low, 90 s mean) since the
/// Config file does not carry them.  `--fleet` is optional; without it
/// the builtin us-east-1 template fleet is used.
fn sweep(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!(
            "ds sweep — parallel scenario matrix with aggregate analytics\n\n\
             Every axis flag takes a comma-separated list; the scenarios are the\n\
             cartesian product of all axes, replicated over --seeds seeds.\n\n\
             flags:\n{}",
            render_flags(SWEEP_FLAGS)
        );
        return Ok(());
    }
    // A stray positional is almost always a space where a comma belonged
    // (`--machines 2 4`); running the shrunken matrix silently would be
    // exactly the wrong-study failure the strict flag parsing prevents.
    if let Some(stray) = args.positionals.first() {
        bail!("unexpected argument '{stray}' (list flags take comma-separated values, e.g. --machines 2,4,8)");
    }
    // Same logic for a typo'd flag: reject anything outside the table.
    let known: Vec<&str> = SWEEP_FLAGS.iter().map(|f| f.name).collect();
    let unknown = args.unknown_flags(&known);
    if !unknown.is_empty() {
        bail!(
            "unknown flag --{} for sweep (see `ds sweep --help`)",
            unknown.join(", --")
        );
    }
    let cfg = match args.get("config") {
        Some(_) => load_config(args)?,
        None => AppConfig::default(),
    };
    let jobs = match args.get("job") {
        Some(p) => JobSpec::from_json(
            &std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
        )
        .context("parsing Job file")?,
        None => JobSpec::plate(
            args.get_or("plate", "P1"),
            parse_scalar(args, "wells", 24u32)?,
            parse_scalar(args, "sites", 2u32)?,
            vec![],
        ),
    };

    let seed_base = parse_scalar(args, "seed-base", 0u64)?;
    let n_seeds = parse_scalar(args, "seeds", 4u64)?.max(1);
    let seeds: Vec<u64> = (0..n_seeds).map(|i| seed_base + i).collect();

    let machines: Vec<u32> =
        parse_list(args, "machines")?.unwrap_or_else(|| vec![cfg.cluster_machines]);
    let visibilities: Vec<SimTime> = parse_list::<f64>(args, "visibility-s")?
        .map(|secs| secs.into_iter().map(from_secs_f64).collect())
        .unwrap_or_else(|| vec![cfg.sqs_message_visibility]);
    let volatilities: Vec<Volatility> = match args.get_list("volatility") {
        Some(items) if !items.is_empty() => items
            .iter()
            .map(|s| parse_volatility(s))
            .collect::<Result<Vec<_>>>()?,
        // Flag present with no (or an empty) value: error like every
        // other axis rather than silently running a low-volatility study.
        Some(_) => bail!("missing value for --volatility"),
        None if args.flag("volatility") => bail!("missing value for --volatility"),
        None => vec![Volatility::Low],
    };
    let allocations: Vec<AllocationStrategy> = match args.get_list("allocation") {
        Some(items) if !items.is_empty() => items
            .iter()
            .map(|s| {
                AllocationStrategy::parse(s).ok_or_else(|| {
                    anyhow!(
                        "allocation must be lowest-price|diversified|capacity-optimized, got '{s}'"
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?,
        Some(_) => bail!("missing value for --allocation"),
        None if args.flag("allocation") => bail!("missing value for --allocation"),
        None => vec![AllocationStrategy::LowestPrice],
    };
    // Instance sets: comma separates sets, '+' joins the types inside one
    // (`--instance-types m5.large+c5.xlarge:2,m5.xlarge`).
    let instance_sets: Vec<Vec<InstanceSlot>> = match args.get_list("instance-types") {
        Some(items) if !items.is_empty() => items
            .iter()
            .map(|set| {
                let slots = set
                    .split('+')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(|s| InstanceSlot::parse(s).map_err(|e| anyhow!(e)))
                    .collect::<Result<Vec<InstanceSlot>>>()?;
                if slots.is_empty() {
                    bail!("empty instance set in --instance-types");
                }
                Ok(slots)
            })
            .collect::<Result<Vec<_>>>()?,
        Some(_) => bail!("missing value for --instance-types"),
        None if args.flag("instance-types") => bail!("missing value for --instance-types"),
        None => vec![Vec::new()],
    };
    let cv = parse_scalar(args, "job-cv", 0.3f64)?;
    let stall_prob = parse_scalar(args, "stall-prob", 0.0f64)?;
    let fail_prob = parse_scalar(args, "fail-prob", 0.0f64)?;
    let models: Vec<DurationModel> = parse_list::<f64>(args, "job-mean-s")?
        .unwrap_or_else(|| vec![90.0])
        .into_iter()
        .map(|mean_s| DurationModel {
            mean_s,
            cv,
            stall_prob,
            fail_prob,
        })
        .collect();
    let input_mbs: Vec<f64> = parse_list(args, "input-mb")?.unwrap_or_else(|| vec![0.0]);
    let net_profiles: Vec<NetProfile> = match args.get_list("net-profile") {
        Some(items) if !items.is_empty() => items
            .iter()
            .map(|s| parse_net_profile(s))
            .collect::<Result<Vec<_>>>()?,
        Some(_) => bail!("missing value for --net-profile"),
        None if args.flag("net-profile") => bail!("missing value for --net-profile"),
        None => vec![NetProfile::default()],
    };

    let matrix = ScenarioMatrix {
        seeds,
        volatilities,
        visibilities,
        cluster_machines: machines,
        allocations,
        instance_sets,
        input_mbs,
        net_profiles,
        models,
    };
    let threads = parse_scalar(args, "threads", default_threads())?.max(1);

    let mut plan = SweepPlan::new(cfg, jobs, matrix);
    if let Some(p) = args.get("fleet") {
        plan.fleet = FleetSpec::from_json(
            &std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
        )
        .context("parsing Fleet file")?;
    }
    plan.fleet.on_demand_base =
        parse_scalar(args, "on-demand-base", plan.fleet.on_demand_base)?;
    let preamble = format!(
        "sweep: {} scenarios x {} seeds = {} cells on {} threads ({} jobs/cell)",
        plan.matrix.scenarios().len(),
        plan.matrix.seeds.len(),
        plan.matrix.cell_count(),
        threads,
        plan.jobs.groups.len(),
    );
    // Keep stdout machine-parseable under --json: chatter goes to stderr.
    if args.flag("json") {
        eprintln!("{preamble}");
    } else {
        println!("{preamble}");
    }

    let t0 = std::time::Instant::now();
    let run = run_sweep(&plan, threads)?;
    let wall = t0.elapsed().as_secs_f64();

    if args.flag("json") {
        println!("{}", run.report.to_json().pretty());
    } else {
        println!("\n{}", run.report.table().render());
    }
    eprintln!(
        "{} cells ({} simulated jobs) in {wall:.2}s wall",
        run.cells.len(),
        run.report.total_completed(),
    );
    Ok(())
}
