//! Run statistics and reporting.

pub mod aggregate;

pub use aggregate::{Aggregate, ScenarioSummary, SweepReport};
pub use crate::aws::billing::DataBreakdown;
pub use crate::aws::ec2::PoolBreakdown;
pub use crate::coordinator::autoscale::{ScalingBreakdown, ScalingDecision};
pub use crate::topology::{DomainSlice, OutageWindow, TopologyBreakdown};
pub use crate::traffic::{TenantBreakdown, TenantSlice};
pub use crate::workflow::{StageSpan, WorkflowBreakdown};

use crate::aws::billing::CostReport;
use crate::json::Value;
use crate::sim::clock::{fmt_dur, SimTime, HOUR};

/// Raw counters accumulated by the event loop.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct RunStats {
    /// Jobs completed successfully (message deleted).
    pub completed: u64,
    /// Jobs skipped because CHECK_IF_DONE found existing outputs.
    pub skipped_done: u64,
    /// Completed work whose receipt had gone stale (visibility expired
    /// mid-run): the job ran twice — pure waste.
    pub duplicates: u64,
    /// Attempts that failed (tool exit != 0); message retried.
    pub failed_attempts: u64,
    /// Attempts that stalled (worker wedged until timeout).
    pub stalled: u64,
    /// Work lost because the instance died mid-job.
    pub lost_to_death: u64,
    /// Messages parked in the dead-letter queue at the end.
    pub dead_lettered: u64,
    /// Instance lifecycle.
    pub instances_launched: u64,
    pub interruptions: u64,
    pub crashes: u64,
    pub alarm_terminations: u64,
    pub self_shutdowns: u64,
    /// Events processed (perf telemetry).
    pub events_processed: u64,
}

/// The full end-of-run report.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    pub stats: RunStats,
    /// When the queue drained (all messages consumed), if it did.
    pub drained_at: Option<SimTime>,
    /// When the run ended (monitor cleanup or max time).
    pub ended_at: SimTime,
    /// Whether monitor cleanup completed (all resources torn down).
    pub cleaned_up: bool,
    pub cost: CostReport,
    /// Per-capacity-pool slice of the EC2 activity (launches,
    /// interruptions, machine-hours, dollars), sorted by pool label.
    /// On-demand usage of a type is its own `"<type>/on-demand"` row.
    pub pools: Vec<PoolBreakdown>,
    /// The data-plane slice: bytes moved (`bytes_downloaded` /
    /// `bytes_uploaded` totals), S3 request/egress dollars, and the
    /// bucket-vs-NIC bottleneck attribution.  The byte counters and
    /// bottleneck clocks are zero for zero-data runs; the request
    /// counters also fold in the control-plane's instantaneous S3 calls
    /// (output PUTs, CHECK_IF_DONE LISTs), so they are nonzero whenever
    /// the run touched the store at all.
    pub data: DataBreakdown,
    /// The elasticity slice: what the autoscaling control loop decided
    /// (policy, decision counts, capacity timeline, units added and
    /// released, time-at-capacity).  `policy == "none"` — the default —
    /// is the paper's fixed fleet.
    pub scaling: ScalingBreakdown,
    /// The DAG slice: what the readiness scheduler did (workflow shape,
    /// sharing mode, critical path, dependent-job releases, artifact
    /// bytes staged, stall time, per-stage spans).  `workflow == "none"`
    /// — the default — is the paper's flat bag of independent jobs.
    pub workflow: WorkflowBreakdown,
    /// The multi-region slice: which failure domains the fleet spanned,
    /// per-domain launches / interruptions / jobs / dollars, cross-region
    /// egress, and the fault windows that opened.  `topology == "single"`
    /// — the default — is the paper's implicit one-region cluster and
    /// emits nothing extra in summaries or JSON, so pre-topology output
    /// is byte-identical.
    pub topology: TopologyBreakdown,
    /// The multi-tenant slice: which traffic spec drove the run, the
    /// queueing policy that arbitrated it, and per-tenant submissions,
    /// wait percentiles, SLO attainment, and billed dollar share.
    /// `traffic == "single"` — the default — is the paper's one
    /// anonymous submitter and emits nothing extra in summaries or
    /// JSON, so pre-traffic output is byte-identical.
    pub traffic: TenantBreakdown,
    /// Jobs submitted (initial submission plus any scheduled bursts,
    /// dependent jobs released by the workflow scheduler, and open-loop
    /// traffic arrivals).
    pub jobs_submitted: u64,
}

impl RunReport {
    /// Makespan: submit → queue drained (None if never drained).
    pub fn makespan(&self) -> Option<SimTime> {
        self.drained_at
    }

    /// Throughput in jobs per simulated hour, over the drain window.
    pub fn jobs_per_hour(&self) -> f64 {
        match self.drained_at {
            Some(t) if t > 0 => self.stats.completed as f64 / (t as f64 / HOUR as f64),
            _ => 0.0,
        }
    }

    /// Fraction of finished attempts that were wasted duplicates.  A job
    /// whose receipt went stale can still be *finished* by a later
    /// attempt completing or by CHECK_IF_DONE recognizing the duplicate's
    /// own outputs, so both count in the denominator.
    pub fn duplicate_fraction(&self) -> f64 {
        let total = self.stats.completed + self.stats.skipped_done + self.stats.duplicates;
        if total == 0 {
            0.0
        } else {
            self.stats.duplicates as f64 / total as f64
        }
    }

    /// Did every submitted job end up completed (or parked in the DLQ)?
    pub fn fully_accounted(&self) -> bool {
        self.stats.completed + self.stats.skipped_done + self.stats.dead_lettered
            >= self.jobs_submitted
    }

    /// Human-readable multi-line summary.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "jobs: {}/{} completed ({} skipped-done, {} dead-lettered)\n",
            self.stats.completed,
            self.jobs_submitted,
            self.stats.skipped_done,
            self.stats.dead_lettered
        ));
        s.push_str(&format!(
            "attempts: {} duplicates, {} failures, {} stalled, {} lost-to-death\n",
            self.stats.duplicates,
            self.stats.failed_attempts,
            self.stats.stalled,
            self.stats.lost_to_death
        ));
        s.push_str(&format!(
            "fleet: {} launched, {} interrupted, {} crashed, {} alarm-reaped, {} self-shutdown\n",
            self.stats.instances_launched,
            self.stats.interruptions,
            self.stats.crashes,
            self.stats.alarm_terminations,
            self.stats.self_shutdowns
        ));
        match self.drained_at {
            Some(t) => s.push_str(&format!(
                "makespan: {} ({:.1} jobs/h)\n",
                fmt_dur(t),
                self.jobs_per_hour()
            )),
            None => s.push_str("makespan: queue never drained\n"),
        }
        s.push_str(&format!(
            "ended: {} cleaned_up={}\n",
            fmt_dur(self.ended_at),
            self.cleaned_up
        ));
        s.push_str(&format!(
            "cost: ${:.4} total (EC2 ${:.4}, {:.2} machine-h; on-demand would be ${:.4}, {:.1}x); overhead {:.2}%\n",
            self.cost.total_usd(),
            self.cost.ec2_usd,
            self.cost.machine_hours,
            self.cost.on_demand_equivalent_usd,
            self.cost.spot_savings_factor(),
            self.cost.overhead_fraction() * 100.0
        ));
        for p in &self.pools {
            s.push_str(&format!(
                "  pool {}: {} launched, {} interrupted, {:.2} machine-h, ${:.4}\n",
                p.pool, p.launched, p.interrupted, p.machine_hours, p.cost_usd
            ));
        }
        if self.scaling.policy != "none" {
            s.push_str(&format!(
                "scaling({}): {} decisions ({} out / {} in), +{}/-{} units, capacity {}..{}, {:.2} unit-h\n",
                self.scaling.policy,
                self.scaling.decisions,
                self.scaling.scale_outs,
                self.scaling.scale_ins,
                self.scaling.units_launched,
                self.scaling.units_terminated,
                self.scaling.floor_capacity,
                self.scaling.peak_capacity,
                self.scaling.capacity_unit_hours,
            ));
        }
        if self.workflow.workflow != "none" {
            s.push_str(&format!(
                "workflow({}/{}): {} nodes, {} edges, critical path {}; {} releases, {:.2} GB staged, {} stalled on parents\n",
                self.workflow.workflow,
                self.workflow.sharing,
                self.workflow.nodes,
                self.workflow.edges,
                self.workflow.critical_path_len,
                self.workflow.releases,
                self.workflow.artifact_bytes_staged as f64 / 1e9,
                fmt_dur(self.workflow.stall_ms),
            ));
        }
        if self.topology.topology != "single" {
            s.push_str(&format!(
                "topology({}/{}): {} domains, {} fault windows; x-region {:.2} GB (${:.4})\n",
                self.topology.topology,
                self.topology.placement,
                self.topology.domains.len(),
                self.topology.outages.len(),
                self.topology.xregion_bytes as f64 / 1e9,
                self.topology.xregion_usd,
            ));
            for d in &self.topology.domains {
                s.push_str(&format!(
                    "  domain {} ({}): {} launched, {} interrupted, {} jobs, ${:.4}\n",
                    d.domain, d.region, d.launched, d.interrupted, d.jobs_completed, d.cost_usd
                ));
            }
        }
        if self.traffic.traffic != "single" {
            s.push_str(&format!(
                "traffic({}/{}): {} tenants\n",
                self.traffic.traffic,
                self.traffic.queueing,
                self.traffic.tenants.len(),
            ));
            for t in &self.traffic.tenants {
                s.push_str(&format!(
                    "  tenant {} (w={} p={}): {}/{} done, wait p50 {} p95 {}, SLO {}/{}, ${:.4}\n",
                    t.tenant,
                    t.weight,
                    t.priority,
                    t.completed,
                    t.submitted,
                    fmt_dur(t.wait_p50_ms),
                    fmt_dur(t.wait_p95_ms),
                    t.slo_attained,
                    t.completed,
                    t.billed_usd,
                ));
            }
        }
        if self.data.total_bytes() > 0 {
            s.push_str(&format!(
                "data: {:.2} GB down, {:.2} GB up ({:.2} GB wasted); bottleneck {:.0}% bucket / {:.0}% NIC; requests ${:.4}, egress ${:.4}\n",
                self.data.bytes_downloaded as f64 / 1e9,
                self.data.bytes_uploaded as f64 / 1e9,
                self.data.bytes_wasted as f64 / 1e9,
                self.data.bucket_bound_fraction() * 100.0,
                (1.0 - self.data.bucket_bound_fraction()) * 100.0,
                self.data.request_usd,
                self.data.egress_usd,
            ));
        }
        s
    }

    /// The full report as JSON — what `ds run --json` prints.  The
    /// field set is pinned by the golden-snapshot test
    /// (`rust/tests/golden_json.rs`): schema drift fails loudly there
    /// instead of silently breaking downstream parsers.
    pub fn to_json(&self) -> Value {
        let st = &self.stats;
        let stats = Value::obj()
            .with("completed", st.completed)
            .with("skipped_done", st.skipped_done)
            .with("duplicates", st.duplicates)
            .with("failed_attempts", st.failed_attempts)
            .with("stalled", st.stalled)
            .with("lost_to_death", st.lost_to_death)
            .with("dead_lettered", st.dead_lettered)
            .with("instances_launched", st.instances_launched)
            .with("interruptions", st.interruptions)
            .with("crashes", st.crashes)
            .with("alarm_terminations", st.alarm_terminations)
            .with("self_shutdowns", st.self_shutdowns)
            .with("events_processed", st.events_processed);
        let cost = Value::obj()
            .with("total_usd", self.cost.total_usd())
            .with("ec2_usd", self.cost.ec2_usd)
            .with("sqs_usd", self.cost.sqs_usd)
            .with("s3_usd", self.cost.s3_usd)
            .with("s3_egress_usd", self.cost.s3_egress_usd)
            .with("cloudwatch_usd", self.cost.cloudwatch_usd)
            .with("machine_hours", self.cost.machine_hours)
            .with("on_demand_equivalent_usd", self.cost.on_demand_equivalent_usd)
            .with("spot_savings_factor", self.cost.spot_savings_factor())
            .with("overhead_fraction", self.cost.overhead_fraction());
        let mut v = Value::obj()
            .with("jobs_submitted", self.jobs_submitted)
            .with("stats", stats)
            .with(
                "drained_at_s",
                match self.drained_at {
                    Some(t) => Value::from(t as f64 / 1000.0),
                    None => Value::Null,
                },
            )
            .with("ended_at_s", self.ended_at as f64 / 1000.0)
            .with("cleaned_up", self.cleaned_up)
            .with("jobs_per_hour", self.jobs_per_hour())
            .with("duplicate_fraction", self.duplicate_fraction())
            .with("cost", cost)
            .with(
                "pools",
                Value::Arr(self.pools.iter().map(aggregate::pool_to_json).collect()),
            )
            .with("data", aggregate::data_to_json(&self.data))
            .with("scaling", aggregate::scaling_to_json(&self.scaling, true))
            .with("workflow", aggregate::workflow_to_json(&self.workflow, true));
        // The topology object appears only for multi-domain runs, so the
        // default single-domain JSON stays byte-identical to pre-topology
        // output (the golden snapshots pin exactly this).
        if self.topology.topology != "single" {
            v = v.with("topology", aggregate::topology_to_json(&self.topology, true));
        }
        // Likewise the traffic object: only multi-tenant runs grow it.
        if self.traffic.traffic != "single" {
            v = v.with("traffic", aggregate::traffic_to_json(&self.traffic));
        }
        v
    }
}

/// Simple fixed-width table printer for experiment outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> RunReport {
        RunReport {
            stats: RunStats {
                completed: 100,
                duplicates: 5,
                ..Default::default()
            },
            drained_at: Some(2 * HOUR),
            ended_at: 2 * HOUR + 10 * 60_000,
            cleaned_up: true,
            cost: CostReport::default(),
            pools: vec![],
            data: DataBreakdown::default(),
            scaling: ScalingBreakdown::default(),
            workflow: WorkflowBreakdown::default(),
            topology: TopologyBreakdown::default(),
            traffic: TenantBreakdown::default(),
            jobs_submitted: 100,
        }
    }

    #[test]
    fn throughput_and_duplicates() {
        let r = report();
        assert!((r.jobs_per_hour() - 50.0).abs() < 1e-9);
        assert!((r.duplicate_fraction() - 5.0 / 105.0).abs() < 1e-9);
        assert!(r.fully_accounted());
    }

    #[test]
    fn summary_mentions_key_numbers() {
        let s = report().summary();
        assert!(s.contains("100/100 completed"));
        assert!(s.contains("5 duplicates"));
        assert!(s.contains("2.00h"));
    }

    #[test]
    fn summary_shows_data_line_only_for_data_runs() {
        let zero = report();
        assert!(!zero.summary().contains("bottleneck"));
        let mut data_run = report();
        data_run.data.bytes_downloaded = 3_000_000_000;
        data_run.data.bytes_uploaded = 1_000_000_000;
        data_run.data.bucket_bound_ms = 900;
        data_run.data.nic_bound_ms = 100;
        let s = data_run.summary();
        assert!(s.contains("3.00 GB down"), "{s}");
        assert!(s.contains("90% bucket"), "{s}");
    }

    #[test]
    fn summary_shows_workflow_line_only_for_dag_runs() {
        let flat = report();
        assert!(!flat.summary().contains("workflow("));
        let mut dag = report();
        dag.workflow.workflow = "diamond".into();
        dag.workflow.sharing = "node-local".into();
        dag.workflow.nodes = 6;
        dag.workflow.edges = 8;
        dag.workflow.critical_path_len = 3;
        dag.workflow.releases = 5;
        let s = dag.summary();
        assert!(s.contains("workflow(diamond/node-local)"), "{s}");
        assert!(s.contains("critical path 3"), "{s}");
    }

    #[test]
    fn summary_and_json_show_topology_only_for_multi_domain_runs() {
        let flat = report();
        assert!(!flat.summary().contains("topology("));
        assert!(flat.to_json().get("topology").is_none(), "single-domain JSON is legacy-shaped");
        let mut multi = report();
        multi.topology.topology = "two-region".into();
        multi.topology.placement = "spread".into();
        multi.topology.domains = vec![
            DomainSlice {
                domain: "us-east-1a".into(),
                region: "us-east-1".into(),
                launched: 3,
                interrupted: 2,
                jobs_completed: 40,
                cost_usd: 0.25,
            },
            DomainSlice {
                domain: "us-west-2a".into(),
                region: "us-west-2".into(),
                launched: 4,
                interrupted: 0,
                jobs_completed: 60,
                cost_usd: 0.5,
            },
        ];
        multi.topology.xregion_bytes = 2_000_000_000;
        multi.topology.xregion_usd = 0.18;
        multi.topology.outages.push(OutageWindow {
            domain: "us-east-1a".into(),
            kind: "az-outage".into(),
            start_ms: 0,
            end_ms: HOUR,
        });
        let s = multi.summary();
        assert!(s.contains("topology(two-region/spread)"), "{s}");
        assert!(s.contains("domain us-west-2a (us-west-2): 4 launched"), "{s}");
        assert!(s.contains("x-region 2.00 GB ($0.1800)"), "{s}");
        let t = multi.to_json().get("topology").cloned().unwrap();
        assert_eq!(t.get("topology").and_then(Value::as_str), Some("two-region"));
        assert_eq!(
            t.get("domains").and_then(Value::as_arr).map(Vec::len),
            Some(2)
        );
        assert_eq!(
            t.get("outages").and_then(Value::as_arr).unwrap()[0]
                .get("kind")
                .and_then(Value::as_str),
            Some("az-outage")
        );
    }

    #[test]
    fn summary_and_json_show_traffic_only_for_multi_tenant_runs() {
        let solo = report();
        assert!(!solo.summary().contains("traffic("));
        assert!(solo.to_json().get("traffic").is_none(), "single-tenant JSON is legacy-shaped");
        let mut multi = report();
        multi.traffic.traffic = "noisy-neighbor".into();
        multi.traffic.queueing = "fair-share".into();
        multi.traffic.tenants = vec![
            TenantSlice {
                tenant: "victim".into(),
                weight: 1,
                priority: 1,
                submitted: 24,
                completed: 24,
                wait_p50_ms: 30_000,
                wait_p95_ms: 120_000,
                slo_target_ms: 300_000,
                slo_attained: 23,
                billed_usd: 0.25,
            },
            TenantSlice {
                tenant: "noisy".into(),
                weight: 1,
                priority: 0,
                submitted: 96,
                completed: 96,
                wait_p50_ms: 60_000,
                wait_p95_ms: 600_000,
                slo_target_ms: 3_600_000,
                slo_attained: 96,
                billed_usd: 1.0,
            },
        ];
        let s = multi.summary();
        assert!(s.contains("traffic(noisy-neighbor/fair-share): 2 tenants"), "{s}");
        assert!(s.contains("tenant victim (w=1 p=1): 24/24 done"), "{s}");
        assert!(s.contains("SLO 23/24"), "{s}");
        let t = multi.to_json().get("traffic").cloned().unwrap();
        assert_eq!(t.get("traffic").and_then(Value::as_str), Some("noisy-neighbor"));
        assert_eq!(t.get("queueing").and_then(Value::as_str), Some("fair-share"));
        let tenants = t.get("tenants").and_then(Value::as_arr).unwrap();
        assert_eq!(tenants.len(), 2);
        assert_eq!(tenants[0].get("tenant").and_then(Value::as_str), Some("victim"));
        assert_eq!(tenants[0].get("wait_p95_ms").and_then(Value::as_u64), Some(120_000));
        assert_eq!(tenants[1].get("slo_attained").and_then(Value::as_u64), Some(96));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["machines", "jobs/h"]);
        t.row(&["1".into(), "11.5".into()]);
        t.row(&["128".into(), "1472.0".into()]);
        let s = t.render();
        assert!(s.contains("machines"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }
}
