//! Cross-seed aggregation for scenario sweeps (DESIGN.md §5).
//!
//! One sweep cell is one independent [`RunReport`]; a *scenario* is the
//! set of cells that share a configuration and differ only by seed.  The
//! types here reduce a scenario's cells into distribution summaries
//! (mean/p50/p95 makespan, jobs/hour, cost, duplicate-work rate,
//! dead-letter rate) plus summed fleet counters and a merged
//! per-capacity-pool cost/interruption breakdown, and render the whole
//! sweep as a [`Table`] or as JSON.
//!
//! Everything is computed in a fixed order from already-deterministic
//! per-cell reports, so a [`SweepReport`] is bit-identical regardless of
//! how many worker threads produced the cells — the determinism tests
//! pin exactly that.
//!
//! ```
//! use ds_rs::metrics::Aggregate;
//!
//! let a = Aggregate::from_values(&[4.0, 1.0, 3.0, 2.0]);
//! assert_eq!((a.n, a.min, a.max), (4, 1.0, 4.0));
//! assert!((a.mean - 2.5).abs() < 1e-12);
//! assert!(a.min <= a.p50 && a.p50 <= a.p95 && a.p95 <= a.max);
//! ```

use std::collections::BTreeMap;

use crate::json::Value;
use crate::sim::clock::fmt_dur;
use crate::sim::SimTime;

use super::{
    DataBreakdown, DomainSlice, PoolBreakdown, RunReport, ScalingBreakdown, Table,
    TenantBreakdown, TenantSlice, TopologyBreakdown, WorkflowBreakdown,
};

/// Distribution summary over a sample of f64s.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Aggregate {
    /// Sample size.
    pub n: usize,
    pub mean: f64,
    /// Nearest-rank median.
    pub p50: f64,
    /// Nearest-rank 95th percentile.
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Aggregate {
    /// Summarize a sample.  An empty sample yields all-zero fields — never
    /// NaN, so reports stay bit-comparable with `==`.
    pub fn from_values(values: &[f64]) -> Self {
        if values.is_empty() {
            return Self {
                n: 0,
                mean: 0.0,
                p50: 0.0,
                p95: 0.0,
                min: 0.0,
                max: 0.0,
            };
        }
        let mut sorted = values.to_vec();
        sorted.sort_by(f64::total_cmp);
        let nearest_rank = |p: f64| {
            let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
            sorted[idx]
        };
        Self {
            n: sorted.len(),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50: nearest_rank(0.50),
            p95: nearest_rank(0.95),
            min: sorted[0],
            max: sorted[sorted.len() - 1],
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("n", self.n)
            .with("mean", self.mean)
            .with("p50", self.p50)
            .with("p95", self.p95)
            .with("min", self.min)
            .with("max", self.max)
    }
}

/// Aggregated view of one scenario: all its seeds' [`RunReport`]s reduced
/// to distribution summaries plus summed counters.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSummary {
    pub label: String,
    /// The scenario's machine-readable coordinates, keyed by the axis
    /// registry's Sweep-file keys (`Scenario::axis_json`); empty for
    /// summaries built outside a sweep.  Downstream tooling reads this
    /// instead of parsing the label.
    pub axes: Value,
    /// Cells (seeds) aggregated.
    pub cells: usize,
    /// Cells whose queue drained (makespan/jobs-per-hour aggregates cover
    /// only these; undrained cells would poison the sample with zeros).
    pub drained: usize,
    // Summed job counters across all cells.
    pub jobs_submitted: u64,
    pub completed: u64,
    pub skipped_done: u64,
    pub dead_lettered: u64,
    pub duplicates: u64,
    // Summed fleet counters across all cells.
    pub instances_launched: u64,
    pub interruptions: u64,
    pub lost_to_death: u64,
    /// Makespan in seconds, over drained cells.
    pub makespan_s: Aggregate,
    /// Throughput in jobs per simulated hour, over drained cells.
    pub jobs_per_hour: Aggregate,
    /// Total (EC2 + control-plane) cost in USD, over all cells.
    pub cost_usd: Aggregate,
    /// Wasted-duplicate fraction of finished attempts, over all cells.
    pub duplicate_rate: Aggregate,
    /// Dead-lettered fraction of submitted jobs, over all cells.
    pub dead_letter_rate: Aggregate,
    /// Per-capacity-pool activity merged across all cells (launches,
    /// interruptions, machine-hours, dollars summed by pool label),
    /// sorted by label.
    pub pools: Vec<PoolBreakdown>,
    /// Data-plane activity summed across all cells: bytes moved/wasted,
    /// request + egress dollars, bucket-vs-NIC bottleneck attribution.
    pub data: DataBreakdown,
    /// Autoscaling activity merged across all cells: decision counters
    /// and capacity-unit-hours summed, peak/floor capacity taken as the
    /// max/min over cells.  The per-decision timeline is per-run
    /// evidence, not an aggregate, so it stays empty here.
    pub scaling: ScalingBreakdown,
    /// Workflow activity merged across all cells: releases, artifact
    /// bytes, and stall time summed; the shape/sharing identity and the
    /// topology counts come from the first report (every cell of a
    /// scenario runs the same DAG).  Per-stage spans are per-run
    /// evidence, like the scaling timeline, so they stay empty here.
    pub workflow: WorkflowBreakdown,
    /// Topology activity merged across all cells: per-domain counters and
    /// cross-region egress summed; the topology/placement identity and
    /// the domain list come from the first report (every cell of a
    /// scenario runs the same topology).  Observed fault windows are
    /// per-run evidence and stay empty here.
    pub topology: TopologyBreakdown,
    /// Multi-tenant activity merged across all cells: per-tenant job
    /// counters summed, wait percentiles averaged across seeds (integer
    /// mean — a cross-seed typical value, not a re-derived percentile),
    /// billed dollars summed; the traffic/queueing identity, tenant
    /// list, weights, priorities, and SLO targets come from the first
    /// report (every cell of a scenario runs the same traffic spec, so
    /// the lists align positionally).
    pub traffic: TenantBreakdown,
}

impl ScenarioSummary {
    /// Reduce one scenario's per-seed reports.  Aggregation is positional
    /// and order-independent only through sorting inside [`Aggregate`], so
    /// callers should still pass reports in a fixed order to keep summed
    /// f64 fields bit-stable.
    pub fn from_reports(label: &str, reports: &[&RunReport]) -> Self {
        let drained: Vec<&&RunReport> = reports.iter().filter(|r| r.drained_at.is_some()).collect();
        let makespans: Vec<f64> = drained
            .iter()
            .filter_map(|r| r.makespan())
            .map(|t| t as f64 / 1000.0)
            .collect();
        let throughputs: Vec<f64> = drained.iter().map(|r| r.jobs_per_hour()).collect();
        let costs: Vec<f64> = reports.iter().map(|r| r.cost.total_usd()).collect();
        let dup_rates: Vec<f64> = reports.iter().map(|r| r.duplicate_fraction()).collect();
        let dlq_rates: Vec<f64> = reports
            .iter()
            .map(|r| {
                if r.jobs_submitted == 0 {
                    0.0
                } else {
                    r.stats.dead_lettered as f64 / r.jobs_submitted as f64
                }
            })
            .collect();
        let sum = |f: fn(&RunReport) -> u64| -> u64 { reports.iter().map(|r| f(r)).sum() };
        // Merge the per-cell pool breakdowns by pool label.  Cells are
        // passed in a fixed order, so the f64 sums are bit-stable.
        let mut pool_map: BTreeMap<String, PoolBreakdown> = BTreeMap::new();
        for r in reports {
            for p in &r.pools {
                let e = pool_map
                    .entry(p.pool.clone())
                    .or_insert_with(|| PoolBreakdown {
                        pool: p.pool.clone(),
                        launched: 0,
                        interrupted: 0,
                        machine_hours: 0.0,
                        cost_usd: 0.0,
                    });
                e.launched += p.launched;
                e.interrupted += p.interrupted;
                e.machine_hours += p.machine_hours;
                e.cost_usd += p.cost_usd;
            }
        }
        // Sum the per-cell data breakdowns (fixed report order keeps the
        // f64 dollar sums bit-stable).
        let mut data = DataBreakdown::default();
        for r in reports {
            data.bytes_downloaded += r.data.bytes_downloaded;
            data.bytes_uploaded += r.data.bytes_uploaded;
            data.bytes_wasted += r.data.bytes_wasted;
            data.get_requests += r.data.get_requests;
            data.put_requests += r.data.put_requests;
            data.head_requests += r.data.head_requests;
            data.list_requests += r.data.list_requests;
            data.request_usd += r.data.request_usd;
            data.egress_usd += r.data.egress_usd;
            data.bucket_bound_ms += r.data.bucket_bound_ms;
            data.nic_bound_ms += r.data.nic_bound_ms;
            data.first_byte_wait_ms += r.data.first_byte_wait_ms;
        }
        // Merge the scaling slices: summed counters, max peak, min
        // floor.  Every cell of a scenario ran the same policy, so the
        // first report's name is the scenario's.
        let mut scaling = ScalingBreakdown {
            policy: reports
                .first()
                .map(|r| r.scaling.policy.clone())
                .unwrap_or_else(|| "none".to_string()),
            ..ScalingBreakdown::default()
        };
        for r in reports {
            scaling.decisions += r.scaling.decisions;
            scaling.scale_outs += r.scaling.scale_outs;
            scaling.scale_ins += r.scaling.scale_ins;
            scaling.units_launched += r.scaling.units_launched;
            scaling.units_terminated += r.scaling.units_terminated;
            scaling.peak_capacity = scaling.peak_capacity.max(r.scaling.peak_capacity);
            scaling.floor_capacity = if scaling.floor_capacity == 0 {
                r.scaling.floor_capacity
            } else {
                scaling.floor_capacity.min(r.scaling.floor_capacity)
            };
            scaling.capacity_unit_hours += r.scaling.capacity_unit_hours;
        }
        // Merge the workflow slices the same way: identity + topology
        // from the first report, activity counters summed, stages
        // dropped (per-run only).
        let mut workflow = reports
            .first()
            .map(|r| WorkflowBreakdown {
                stages: Vec::new(),
                releases: 0,
                artifact_bytes_staged: 0,
                stall_ms: 0,
                ..r.workflow.clone()
            })
            .unwrap_or_default();
        for r in reports {
            workflow.releases += r.workflow.releases;
            workflow.artifact_bytes_staged += r.workflow.artifact_bytes_staged;
            workflow.stall_ms += r.workflow.stall_ms;
        }
        // Merge the topology slices: identity and domain list from the
        // first report (cells share the topology, so the lists align
        // positionally), activity counters summed, fault windows dropped.
        let mut topology = reports
            .first()
            .map(|r| TopologyBreakdown {
                domains: r
                    .topology
                    .domains
                    .iter()
                    .map(|d| DomainSlice {
                        launched: 0,
                        interrupted: 0,
                        jobs_completed: 0,
                        cost_usd: 0.0,
                        ..d.clone()
                    })
                    .collect(),
                xregion_bytes: 0,
                xregion_usd: 0.0,
                outages: Vec::new(),
                ..r.topology.clone()
            })
            .unwrap_or_default();
        for r in reports {
            topology.xregion_bytes += r.topology.xregion_bytes;
            topology.xregion_usd += r.topology.xregion_usd;
            for (slot, d) in topology.domains.iter_mut().zip(&r.topology.domains) {
                slot.launched += d.launched;
                slot.interrupted += d.interrupted;
                slot.jobs_completed += d.jobs_completed;
                slot.cost_usd += d.cost_usd;
            }
        }
        // Merge the traffic slices: identity and tenant list from the
        // first report (cells share the spec, so tenants align
        // positionally), job counters and dollars summed, wait
        // percentiles averaged across seeds.
        let mut traffic = reports
            .first()
            .map(|r| TenantBreakdown {
                tenants: r
                    .traffic
                    .tenants
                    .iter()
                    .map(|t| TenantSlice {
                        submitted: 0,
                        completed: 0,
                        wait_p50_ms: 0,
                        wait_p95_ms: 0,
                        slo_attained: 0,
                        billed_usd: 0.0,
                        ..t.clone()
                    })
                    .collect(),
                ..r.traffic.clone()
            })
            .unwrap_or_default();
        for r in reports {
            for (slot, t) in traffic.tenants.iter_mut().zip(&r.traffic.tenants) {
                slot.submitted += t.submitted;
                slot.completed += t.completed;
                slot.wait_p50_ms += t.wait_p50_ms;
                slot.wait_p95_ms += t.wait_p95_ms;
                slot.slo_attained += t.slo_attained;
                slot.billed_usd += t.billed_usd;
            }
        }
        let n = reports.len() as u64;
        if n > 1 {
            for slot in &mut traffic.tenants {
                slot.wait_p50_ms /= n;
                slot.wait_p95_ms /= n;
            }
        }
        Self {
            label: label.to_string(),
            axes: Value::obj(),
            cells: reports.len(),
            drained: drained.len(),
            jobs_submitted: sum(|r| r.jobs_submitted),
            completed: sum(|r| r.stats.completed),
            skipped_done: sum(|r| r.stats.skipped_done),
            dead_lettered: sum(|r| r.stats.dead_lettered),
            duplicates: sum(|r| r.stats.duplicates),
            instances_launched: sum(|r| r.stats.instances_launched),
            interruptions: sum(|r| r.stats.interruptions),
            lost_to_death: sum(|r| r.stats.lost_to_death),
            makespan_s: Aggregate::from_values(&makespans),
            jobs_per_hour: Aggregate::from_values(&throughputs),
            cost_usd: Aggregate::from_values(&costs),
            duplicate_rate: Aggregate::from_values(&dup_rates),
            dead_letter_rate: Aggregate::from_values(&dlq_rates),
            pools: pool_map.into_values().collect(),
            data,
            scaling,
            workflow,
            topology,
            traffic,
        }
    }

    /// Attach the scenario's registry-keyed axis coordinates (the sweep
    /// engine calls this with `Scenario::axis_json`).
    pub fn with_axes(mut self, axes: Value) -> Self {
        self.axes = axes;
        self
    }

    /// Render one of this scenario's makespan aggregate values (seconds)
    /// for a table cell: "-" when no seed drained (the empty aggregate is
    /// all zeros, which would otherwise read as instant completion).
    pub fn makespan_cell(&self, secs: f64) -> String {
        if self.drained == 0 {
            "-".to_string()
        } else {
            fmt_dur((secs * 1000.0) as SimTime)
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj()
            .with("label", self.label.as_str())
            .with("axes", self.axes.clone())
            .with("cells", self.cells)
            .with("drained", self.drained)
            .with("jobs_submitted", self.jobs_submitted)
            .with("completed", self.completed)
            .with("skipped_done", self.skipped_done)
            .with("dead_lettered", self.dead_lettered)
            .with("duplicates", self.duplicates)
            .with("instances_launched", self.instances_launched)
            .with("interruptions", self.interruptions)
            .with("lost_to_death", self.lost_to_death)
            .with("makespan_s", self.makespan_s.to_json())
            .with("jobs_per_hour", self.jobs_per_hour.to_json())
            .with("cost_usd", self.cost_usd.to_json())
            .with("duplicate_rate", self.duplicate_rate.to_json())
            .with("dead_letter_rate", self.dead_letter_rate.to_json())
            .with(
                "pools",
                Value::Arr(self.pools.iter().map(pool_to_json).collect()),
            )
            .with("data", data_to_json(&self.data))
            .with("scaling", scaling_to_json(&self.scaling, false))
            .with("workflow", workflow_to_json(&self.workflow, false));
        // Like the run report: single-domain summaries stay legacy-shaped.
        if self.topology.topology != "single" {
            v = v.with("topology", topology_to_json(&self.topology, false));
        }
        // And single-tenant summaries: the traffic object only appears
        // when a traffic spec actually drove the cells.
        if self.traffic.traffic != "single" {
            v = v.with("traffic", traffic_to_json(&self.traffic));
        }
        v
    }
}

/// JSON shape of one merged [`PoolBreakdown`] row.
pub(crate) fn pool_to_json(p: &PoolBreakdown) -> Value {
    Value::obj()
        .with("pool", p.pool.as_str())
        .with("launched", p.launched)
        .with("interrupted", p.interrupted)
        .with("machine_hours", p.machine_hours)
        .with("cost_usd", p.cost_usd)
}

/// JSON shape of the merged [`DataBreakdown`] (the sweep's data axis
/// lands here: byte totals, request/egress dollars, and the
/// bucket-vs-NIC bottleneck attribution).
pub(crate) fn data_to_json(d: &DataBreakdown) -> Value {
    Value::obj()
        .with("bytes_downloaded", d.bytes_downloaded)
        .with("bytes_uploaded", d.bytes_uploaded)
        .with("bytes_wasted", d.bytes_wasted)
        .with("get_requests", d.get_requests)
        .with("put_requests", d.put_requests)
        .with("head_requests", d.head_requests)
        .with("list_requests", d.list_requests)
        .with("request_usd", d.request_usd)
        .with("egress_usd", d.egress_usd)
        .with("bucket_bound_ms", d.bucket_bound_ms)
        .with("nic_bound_ms", d.nic_bound_ms)
        .with("first_byte_wait_ms", d.first_byte_wait_ms)
        .with("bucket_bound_fraction", d.bucket_bound_fraction())
}

/// JSON shape of a [`ScalingBreakdown`].  The per-decision `timeline`
/// rides along only in single-run reports (`ds run --json`); cross-seed
/// summaries carry counters alone.
pub(crate) fn scaling_to_json(s: &ScalingBreakdown, timeline: bool) -> Value {
    let mut v = Value::obj()
        .with("policy", s.policy.as_str())
        .with("decisions", s.decisions)
        .with("scale_outs", s.scale_outs)
        .with("scale_ins", s.scale_ins)
        .with("units_launched", s.units_launched)
        .with("units_terminated", s.units_terminated)
        .with("peak_capacity", s.peak_capacity)
        .with("floor_capacity", s.floor_capacity)
        .with("capacity_unit_hours", s.capacity_unit_hours);
    if timeline {
        v = v.with(
            "timeline",
            Value::Arr(
                s.timeline
                    .iter()
                    .map(|d| {
                        Value::obj()
                            .with("at_s", d.at as f64 / 1000.0)
                            .with("from", d.from)
                            .with("to", d.to)
                            .with("backlog", d.backlog)
                    })
                    .collect(),
            ),
        );
    }
    v
}

/// JSON shape of a [`WorkflowBreakdown`].  The per-stage `stages` rows
/// ride along only in single-run reports (`ds run --json`); cross-seed
/// summaries carry counters alone, like the scaling timeline.
pub(crate) fn workflow_to_json(w: &WorkflowBreakdown, stages: bool) -> Value {
    let mut v = Value::obj()
        .with("workflow", w.workflow.as_str())
        .with("sharing", w.sharing.as_str())
        .with("nodes", w.nodes)
        .with("edges", w.edges)
        .with("critical_path_len", w.critical_path_len)
        .with("releases", w.releases)
        .with("artifact_bytes_staged", w.artifact_bytes_staged)
        .with("stall_ms", w.stall_ms);
    if stages {
        v = v.with(
            "stages",
            Value::Arr(
                w.stages
                    .iter()
                    .map(|s| {
                        Value::obj()
                            .with("depth", s.depth)
                            .with("released_s", s.released_ms as f64 / 1000.0)
                            .with("committed_s", s.committed_ms as f64 / 1000.0)
                    })
                    .collect(),
            ),
        );
    }
    v
}

/// JSON shape of a [`TopologyBreakdown`].  The observed fault-window
/// rows ride along only in single-run reports (`ds run --json`);
/// cross-seed summaries carry per-domain counters alone.  Callers emit
/// this object only when a topology was actually installed, so
/// single-domain output keeps its legacy field set.
pub(crate) fn topology_to_json(t: &TopologyBreakdown, outages: bool) -> Value {
    let mut v = Value::obj()
        .with("topology", t.topology.as_str())
        .with("placement", t.placement.as_str())
        .with(
            "domains",
            Value::Arr(
                t.domains
                    .iter()
                    .map(|d| {
                        Value::obj()
                            .with("domain", d.domain.as_str())
                            .with("region", d.region.as_str())
                            .with("launched", d.launched)
                            .with("interrupted", d.interrupted)
                            .with("jobs_completed", d.jobs_completed)
                            .with("cost_usd", d.cost_usd)
                    })
                    .collect(),
            ),
        )
        .with("xregion_bytes", t.xregion_bytes)
        .with("xregion_usd", t.xregion_usd);
    if outages {
        v = v.with(
            "outages",
            Value::Arr(
                t.outages
                    .iter()
                    .map(|o| {
                        Value::obj()
                            .with("domain", o.domain.as_str())
                            .with("kind", o.kind.as_str())
                            .with("start_s", o.start_ms as f64 / 1000.0)
                            .with("end_s", o.end_ms as f64 / 1000.0)
                    })
                    .collect(),
            ),
        );
    }
    v
}

/// JSON shape of a [`TenantBreakdown`].  Same rows in single-run reports
/// and cross-seed summaries — the per-tenant slice is already compact.
/// Callers emit this object only when a traffic spec was actually
/// installed, so single-tenant output keeps its legacy field set.
pub(crate) fn traffic_to_json(t: &TenantBreakdown) -> Value {
    Value::obj()
        .with("traffic", t.traffic.as_str())
        .with("queueing", t.queueing.as_str())
        .with(
            "tenants",
            Value::Arr(
                t.tenants
                    .iter()
                    .map(|t| {
                        Value::obj()
                            .with("tenant", t.tenant.as_str())
                            .with("weight", t.weight)
                            .with("priority", u64::from(t.priority))
                            .with("submitted", t.submitted)
                            .with("completed", t.completed)
                            .with("wait_p50_ms", t.wait_p50_ms)
                            .with("wait_p95_ms", t.wait_p95_ms)
                            .with("slo_target_ms", t.slo_target_ms)
                            .with("slo_attained", t.slo_attained)
                            .with("billed_usd", t.billed_usd)
                    })
                    .collect(),
            ),
        )
}

/// The whole sweep: one [`ScenarioSummary`] per scenario, in matrix order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SweepReport {
    pub scenarios: Vec<ScenarioSummary>,
}

impl SweepReport {
    /// Assemble the whole report as a pure fold over tagged cells — the
    /// single merge point shared by the in-process sweep engine and the
    /// sharded parent (`coordinator::shard`).
    ///
    /// `scenario_ids` is one `(label, axes)` pair per scenario in matrix
    /// order; `cells` is *any permutation* of the sweep's
    /// `(scenario_index, seed_slot, report)` triples — thread-completion
    /// order, shard-arrival order, whatever.  The fold canonically sorts
    /// by `(scenario, seed_slot)` before reducing, so the output (every
    /// byte of it, f64 sums included) is identical for every input
    /// order.  A duplicated or out-of-range cell is a caller bug — the
    /// sharded path validates cell sets against assignments before it
    /// gets here — and panics rather than merging a corrupt matrix.
    pub fn from_cells(
        scenario_ids: &[(String, Value)],
        cells: &[(usize, usize, &RunReport)],
    ) -> Self {
        let mut order: Vec<usize> = (0..cells.len()).collect();
        order.sort_by_key(|&i| (cells[i].0, cells[i].1));
        for w in order.windows(2) {
            let a = (cells[w[0]].0, cells[w[0]].1);
            let b = (cells[w[1]].0, cells[w[1]].1);
            assert_ne!(
                a, b,
                "duplicate sweep cell (scenario {}, seed slot {})",
                a.0, a.1
            );
        }
        for &(scenario, _, _) in cells {
            assert!(
                scenario < scenario_ids.len(),
                "cell references scenario {scenario} of a {}-scenario sweep",
                scenario_ids.len()
            );
        }
        let scenarios = scenario_ids
            .iter()
            .enumerate()
            .map(|(i, (label, axes))| {
                let reports: Vec<&RunReport> = order
                    .iter()
                    .map(|&k| &cells[k])
                    .filter(|c| c.0 == i)
                    .map(|c| c.2)
                    .collect();
                ScenarioSummary::from_reports(label, &reports).with_axes(axes.clone())
            })
            .collect();
        Self { scenarios }
    }

    /// Cells across every scenario.
    pub fn total_cells(&self) -> usize {
        self.scenarios.iter().map(|s| s.cells).sum()
    }

    /// Jobs completed across every scenario.
    pub fn total_completed(&self) -> u64 {
        self.scenarios.iter().map(|s| s.completed).sum()
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "scenario",
            "seeds",
            "drained",
            "makespan p50",
            "makespan p95",
            "jobs/h",
            "cost $",
            "dup %",
            "dlq %",
            "done/sub",
        ]);
        for s in &self.scenarios {
            t.row(&[
                s.label.clone(),
                s.cells.to_string(),
                s.drained.to_string(),
                s.makespan_cell(s.makespan_s.p50),
                s.makespan_cell(s.makespan_s.p95),
                format!("{:.0}", s.jobs_per_hour.mean),
                format!("{:.4}", s.cost_usd.mean),
                format!("{:.1}", s.duplicate_rate.mean * 100.0),
                format!("{:.1}", s.dead_letter_rate.mean * 100.0),
                format!("{}/{}", s.completed, s.jobs_submitted),
            ]);
        }
        t
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("total_cells", self.total_cells())
            .with("total_completed", self.total_completed())
            .with(
                "scenarios",
                Value::Arr(self.scenarios.iter().map(ScenarioSummary::to_json).collect()),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::billing::CostReport;
    use crate::metrics::RunStats;
    use crate::sim::HOUR;

    fn report(completed: u64, drained: Option<SimTime>, cost: f64) -> RunReport {
        RunReport {
            stats: RunStats {
                completed,
                duplicates: 1,
                dead_lettered: 2,
                ..Default::default()
            },
            drained_at: drained,
            ended_at: drained.unwrap_or(4 * HOUR),
            cleaned_up: true,
            cost: CostReport {
                ec2_usd: cost,
                ..Default::default()
            },
            pools: vec![PoolBreakdown {
                pool: "m5.xlarge".into(),
                launched: 3,
                interrupted: 1,
                machine_hours: 2.0,
                cost_usd: cost,
            }],
            data: DataBreakdown {
                bytes_downloaded: 1_000,
                bytes_uploaded: 100,
                egress_usd: 0.25,
                bucket_bound_ms: 30,
                nic_bound_ms: 10,
                ..Default::default()
            },
            scaling: ScalingBreakdown {
                policy: "target-tracking".into(),
                decisions: 2,
                scale_outs: 1,
                scale_ins: 1,
                units_launched: 3,
                units_terminated: 2,
                peak_capacity: 4,
                floor_capacity: 1,
                capacity_unit_hours: 2.5,
                ..Default::default()
            },
            workflow: WorkflowBreakdown {
                workflow: "diamond".into(),
                sharing: "s3".into(),
                nodes: 6,
                edges: 8,
                critical_path_len: 3,
                releases: 5,
                artifact_bytes_staged: 1_000,
                stall_ms: 40,
                stages: vec![crate::workflow::StageSpan {
                    depth: 0,
                    released_ms: 0,
                    committed_ms: 100,
                }],
            },
            topology: TopologyBreakdown {
                topology: "two-region".into(),
                placement: "spread".into(),
                domains: vec![
                    DomainSlice {
                        domain: "us-east-1a".into(),
                        region: "us-east-1".into(),
                        launched: 2,
                        interrupted: 1,
                        jobs_completed: completed / 2,
                        cost_usd: cost / 2.0,
                    },
                    DomainSlice {
                        domain: "us-west-2a".into(),
                        region: "us-west-2".into(),
                        launched: 1,
                        interrupted: 0,
                        jobs_completed: completed - completed / 2,
                        cost_usd: cost / 2.0,
                    },
                ],
                xregion_bytes: 500,
                xregion_usd: 0.045,
                outages: vec![crate::topology::OutageWindow {
                    domain: "us-east-1a".into(),
                    kind: "az-outage".into(),
                    start_ms: 0,
                    end_ms: HOUR,
                }],
            },
            traffic: TenantBreakdown {
                traffic: "two-tenant".into(),
                queueing: "fair-share".into(),
                tenants: vec![
                    TenantSlice {
                        tenant: "batch".into(),
                        weight: 2,
                        priority: 0,
                        submitted: completed / 2 + 1,
                        completed: completed / 2,
                        wait_p50_ms: 20_000,
                        wait_p95_ms: 80_000,
                        slo_target_ms: 900_000,
                        slo_attained: completed / 2,
                        billed_usd: cost / 2.0,
                    },
                    TenantSlice {
                        tenant: "interactive".into(),
                        weight: 1,
                        priority: 1,
                        submitted: completed - completed / 2 + 1,
                        completed: completed - completed / 2,
                        wait_p50_ms: 10_000,
                        wait_p95_ms: 40_000,
                        slo_target_ms: 120_000,
                        slo_attained: completed - completed / 2,
                        billed_usd: cost / 2.0,
                    },
                ],
            },
            jobs_submitted: completed + 2,
        }
    }

    #[test]
    fn aggregate_five_numbers() {
        let a = Aggregate::from_values(&[3.0, 1.0, 2.0, 4.0]);
        assert_eq!(a.n, 4);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 4.0);
        assert!((a.mean - 2.5).abs() < 1e-12);
        assert!(a.p50 <= a.p95);
    }

    #[test]
    fn aggregate_empty_is_zero_not_nan() {
        let a = Aggregate::from_values(&[]);
        assert_eq!(a, Aggregate::from_values(&[]));
        assert_eq!(a.n, 0);
        assert_eq!(a.mean, 0.0);
    }

    #[test]
    fn aggregate_order_independent() {
        let a = Aggregate::from_values(&[5.0, 1.0, 9.0, 3.0, 7.0]);
        let b = Aggregate::from_values(&[9.0, 7.0, 5.0, 3.0, 1.0]);
        assert_eq!(a, b);
    }

    #[test]
    fn summary_sums_and_rates() {
        let r1 = report(10, Some(HOUR), 0.5);
        let r2 = report(20, Some(2 * HOUR), 1.5);
        let r3 = report(5, None, 0.25);
        let s = ScenarioSummary::from_reports("s", &[&r1, &r2, &r3]);
        assert_eq!(s.cells, 3);
        assert_eq!(s.drained, 2);
        assert_eq!(s.completed, 35);
        assert_eq!(s.jobs_submitted, 41);
        assert_eq!(s.dead_lettered, 6);
        assert_eq!(s.makespan_s.n, 2);
        assert!((s.makespan_s.max - 7200.0).abs() < 1e-9);
        assert!((s.cost_usd.mean - 0.75).abs() < 1e-12);
        assert!(s.dead_letter_rate.mean > 0.0);
        // Pool rows merge by label across cells.
        assert_eq!(s.pools.len(), 1);
        assert_eq!(s.pools[0].pool, "m5.xlarge");
        assert_eq!(s.pools[0].launched, 9);
        assert_eq!(s.pools[0].interrupted, 3);
        assert!((s.pools[0].machine_hours - 6.0).abs() < 1e-12);
        assert!((s.pools[0].cost_usd - 2.25).abs() < 1e-12);
        // Data breakdowns sum across cells.
        assert_eq!(s.data.bytes_downloaded, 3_000);
        assert_eq!(s.data.bytes_uploaded, 300);
        assert!((s.data.egress_usd - 0.75).abs() < 1e-12);
        assert!((s.data.bucket_bound_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn summary_merges_scaling_counters() {
        let r1 = report(10, Some(HOUR), 0.5);
        let mut r2 = report(20, Some(2 * HOUR), 1.5);
        r2.scaling.peak_capacity = 8;
        r2.scaling.floor_capacity = 2;
        let s = ScenarioSummary::from_reports("s", &[&r1, &r2]);
        assert_eq!(s.scaling.policy, "target-tracking");
        assert_eq!(s.scaling.decisions, 4);
        assert_eq!(s.scaling.scale_outs, 2);
        assert_eq!(s.scaling.units_launched, 6);
        assert_eq!(s.scaling.peak_capacity, 8, "max over cells");
        assert_eq!(s.scaling.floor_capacity, 1, "min over cells");
        assert!((s.scaling.capacity_unit_hours - 5.0).abs() < 1e-12);
        assert!(s.scaling.timeline.is_empty(), "timeline is per-run only");
        // The summary JSON carries the counters but no timeline.
        let j = s.to_json();
        let sc = j.get("scaling").unwrap();
        assert_eq!(sc.get("policy").and_then(Value::as_str), Some("target-tracking"));
        assert_eq!(sc.get("decisions").and_then(Value::as_u64), Some(4));
        assert!(sc.get("timeline").is_none());
    }

    #[test]
    fn summary_merges_workflow_counters() {
        let r1 = report(10, Some(HOUR), 0.5);
        let mut r2 = report(20, Some(2 * HOUR), 1.5);
        r2.workflow.releases = 7;
        r2.workflow.stall_ms = 60;
        let s = ScenarioSummary::from_reports("s", &[&r1, &r2]);
        assert_eq!(s.workflow.workflow, "diamond");
        assert_eq!(s.workflow.sharing, "s3");
        assert_eq!(s.workflow.nodes, 6, "topology comes from the first cell");
        assert_eq!(s.workflow.critical_path_len, 3);
        assert_eq!(s.workflow.releases, 12, "activity counters sum");
        assert_eq!(s.workflow.artifact_bytes_staged, 2_000);
        assert_eq!(s.workflow.stall_ms, 100);
        assert!(s.workflow.stages.is_empty(), "stages are per-run only");
        // The summary JSON carries the counters but no stage rows.
        let j = s.to_json();
        let w = j.get("workflow").unwrap();
        assert_eq!(w.get("workflow").and_then(Value::as_str), Some("diamond"));
        assert_eq!(w.get("releases").and_then(Value::as_u64), Some(12));
        assert!(w.get("stages").is_none());
    }

    #[test]
    fn summary_merges_topology_counters() {
        let r1 = report(10, Some(HOUR), 0.5);
        let mut r2 = report(20, Some(2 * HOUR), 1.5);
        r2.topology.xregion_bytes = 1_500;
        let s = ScenarioSummary::from_reports("s", &[&r1, &r2]);
        assert_eq!(s.topology.topology, "two-region");
        assert_eq!(s.topology.placement, "spread");
        assert_eq!(s.topology.domains.len(), 2, "domain list from the first cell");
        assert_eq!(s.topology.domains[0].domain, "us-east-1a");
        assert_eq!(s.topology.domains[0].launched, 4, "per-domain counters sum");
        assert_eq!(s.topology.domains[0].interrupted, 2);
        assert_eq!(s.topology.domains[0].jobs_completed, 15);
        assert!((s.topology.domains[1].cost_usd - 1.0).abs() < 1e-12);
        assert_eq!(s.topology.xregion_bytes, 2_000);
        assert!((s.topology.xregion_usd - 0.09).abs() < 1e-12);
        assert!(s.topology.outages.is_empty(), "fault windows are per-run only");
        // The summary JSON carries the domain rows but no outage rows.
        let j = s.to_json();
        let t = j.get("topology").unwrap();
        assert_eq!(t.get("placement").and_then(Value::as_str), Some("spread"));
        assert_eq!(
            t.get("domains").and_then(Value::as_arr).map(Vec::len),
            Some(2)
        );
        assert!(t.get("outages").is_none());
    }

    #[test]
    fn single_domain_summary_json_stays_legacy_shaped() {
        let mut r = report(10, Some(HOUR), 0.5);
        r.topology = TopologyBreakdown::default();
        let s = ScenarioSummary::from_reports("s", &[&r]);
        assert!(s.to_json().get("topology").is_none());
    }

    #[test]
    fn summary_merges_traffic_counters() {
        let r1 = report(10, Some(HOUR), 0.5);
        let mut r2 = report(20, Some(2 * HOUR), 1.5);
        r2.traffic.tenants[0].wait_p50_ms = 40_000;
        r2.traffic.tenants[0].wait_p95_ms = 120_000;
        let s = ScenarioSummary::from_reports("s", &[&r1, &r2]);
        assert_eq!(s.traffic.traffic, "two-tenant");
        assert_eq!(s.traffic.queueing, "fair-share");
        assert_eq!(s.traffic.tenants.len(), 2, "tenant list from the first cell");
        let batch = &s.traffic.tenants[0];
        assert_eq!(batch.tenant, "batch");
        assert_eq!(batch.weight, 2, "identity fields from the first cell");
        assert_eq!(batch.slo_target_ms, 900_000);
        assert_eq!(batch.submitted, 17, "job counters sum");
        assert_eq!(batch.completed, 15);
        assert_eq!(batch.slo_attained, 15);
        assert_eq!(batch.wait_p50_ms, 30_000, "percentiles average across seeds");
        assert_eq!(batch.wait_p95_ms, 100_000);
        assert!((batch.billed_usd - 1.0).abs() < 1e-12, "dollars sum");
        // The summary JSON carries the tenant rows.
        let j = s.to_json();
        let t = j.get("traffic").unwrap();
        assert_eq!(t.get("queueing").and_then(Value::as_str), Some("fair-share"));
        let rows = t.get("tenants").and_then(Value::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("wait_p50_ms").and_then(Value::as_u64), Some(30_000));
    }

    #[test]
    fn single_tenant_summary_json_stays_legacy_shaped() {
        let mut r = report(10, Some(HOUR), 0.5);
        r.traffic = TenantBreakdown::default();
        let s = ScenarioSummary::from_reports("s", &[&r]);
        assert_eq!(s.traffic, TenantBreakdown::default());
        assert!(s.to_json().get("traffic").is_none());
    }

    #[test]
    fn sweep_report_table_and_json() {
        let r = report(10, Some(HOUR), 0.5);
        let rep = SweepReport {
            scenarios: vec![ScenarioSummary::from_reports("m=4", &[&r])],
        };
        assert_eq!(rep.total_cells(), 1);
        assert_eq!(rep.total_completed(), 10);
        let rendered = rep.table().render();
        assert!(rendered.contains("m=4"), "{rendered}");
        assert!(rendered.contains("10/12"), "{rendered}");
        let j = rep.to_json();
        assert_eq!(j.get("total_cells").and_then(Value::as_u64), Some(1));
        // Per-pool cost/interruption rows ride along in the JSON.
        let scenario = &j.get("scenarios").and_then(Value::as_arr).unwrap()[0];
        let pools = scenario.get("pools").and_then(Value::as_arr).unwrap();
        assert_eq!(pools[0].get("pool").and_then(Value::as_str), Some("m5.xlarge"));
        assert_eq!(pools[0].get("interrupted").and_then(Value::as_u64), Some(1));
        // The data breakdown rides along in the JSON.
        let data = scenario.get("data").unwrap();
        assert_eq!(data.get("bytes_downloaded").and_then(Value::as_u64), Some(1_000));
        assert_eq!(
            data.get("bucket_bound_fraction").and_then(Value::as_f64),
            Some(0.75)
        );
        let parsed = crate::json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn from_cells_is_order_insensitive_to_the_byte() {
        let r1 = report(10, Some(HOUR), 0.5);
        let r2 = report(20, Some(2 * HOUR), 1.5);
        let r3 = report(5, None, 0.25);
        let r4 = report(7, Some(3 * HOUR), 0.125);
        let ids = vec![
            ("a".to_string(), Value::obj().with("MACHINES", 2u32)),
            ("b".to_string(), Value::obj().with("MACHINES", 4u32)),
        ];
        let canonical = vec![(0, 0, &r1), (0, 1, &r2), (1, 0, &r3), (1, 1, &r4)];
        let reference = SweepReport::from_cells(&ids, &canonical);
        assert_eq!(reference.scenarios.len(), 2);
        assert_eq!(reference.scenarios[0].label, "a");
        assert_eq!(
            reference.scenarios[0].axes.get("MACHINES").and_then(Value::as_u64),
            Some(2)
        );
        assert_eq!(reference.scenarios[0].completed, 30);
        // Every arrival order folds to the same bytes.
        let arrivals = [
            vec![(1, 1, &r4), (1, 0, &r3), (0, 1, &r2), (0, 0, &r1)],
            vec![(1, 0, &r3), (0, 0, &r1), (1, 1, &r4), (0, 1, &r2)],
            vec![(0, 1, &r2), (1, 1, &r4), (0, 0, &r1), (1, 0, &r3)],
        ];
        for shuffled in &arrivals {
            let folded = SweepReport::from_cells(&ids, shuffled);
            assert_eq!(folded, reference);
            assert_eq!(folded.to_json().pretty(), reference.to_json().pretty());
        }
    }

    #[test]
    #[should_panic(expected = "duplicate sweep cell")]
    fn from_cells_rejects_duplicated_cells() {
        let r = report(10, Some(HOUR), 0.5);
        let ids = vec![("a".to_string(), Value::obj())];
        SweepReport::from_cells(&ids, &[(0, 0, &r), (0, 0, &r)]);
    }

    #[test]
    #[should_panic(expected = "references scenario")]
    fn from_cells_rejects_out_of_range_scenarios() {
        let r = report(10, Some(HOUR), 0.5);
        let ids = vec![("a".to_string(), Value::obj())];
        SweepReport::from_cells(&ids, &[(1, 0, &r)]);
    }

    #[test]
    fn undrained_scenario_renders_dashes() {
        let r = report(0, None, 0.1);
        let rep = SweepReport {
            scenarios: vec![ScenarioSummary::from_reports("stuck", &[&r])],
        };
        assert!(rep.table().render().contains("-"));
    }
}
