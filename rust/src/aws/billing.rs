//! Billing meter: turns service usage into USD line items.
//!
//! Powers the cost experiments (T2 spot-vs-on-demand, T3 cheapest mode,
//! T6 resume savings) and quantifies the paper's "adds negligible costs
//! to the compute" claim: control-plane requests (SQS + S3 + CloudWatch)
//! are metered separately from EC2 machine-hours so the coordinator
//! overhead fraction is reported directly.
//!
//! Rates are the 2022-era public price sheet shape: exact values matter
//! only through the *ratios* experiments report.

use crate::aws::ec2::fleet::CostRecord;
use crate::aws::s3::S3Stats;

/// $/1M SQS requests (standard queue, after free tier).
pub const SQS_PER_MILLION_REQ: f64 = 0.40;
/// $/1k S3 PUT/LIST requests.
pub const S3_PER_1K_PUT: f64 = 0.005;
/// $/1k S3 GET requests.
pub const S3_PER_1K_GET: f64 = 0.0004;
/// $/GB-month S3 standard storage.
pub const S3_PER_GB_MONTH: f64 = 0.023;
/// $/1k CloudWatch metric PutMetricData requests (approximation).
pub const CW_PER_1K_PUTS: f64 = 0.01;

/// Itemized cost summary of a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostReport {
    pub ec2_usd: f64,
    pub sqs_usd: f64,
    pub s3_usd: f64,
    pub cloudwatch_usd: f64,
    /// Machine-hours actually billed (spot + on-demand base).
    pub machine_hours: f64,
    /// What the same machine-hours would have cost entirely on-demand.
    /// For instances the fleet's `ON_DEMAND_BASE` already bought
    /// on-demand, equivalent equals actual — only the spot slice saves.
    pub on_demand_equivalent_usd: f64,
}

impl CostReport {
    pub fn total_usd(&self) -> f64 {
        self.ec2_usd + self.sqs_usd + self.s3_usd + self.cloudwatch_usd
    }

    /// Control-plane overhead as a fraction of total ("negligible costs").
    pub fn overhead_fraction(&self) -> f64 {
        let t = self.total_usd();
        if t == 0.0 {
            0.0
        } else {
            (self.sqs_usd + self.s3_usd + self.cloudwatch_usd) / t
        }
    }

    /// Spot savings vs on-demand for the same machine-hours.
    pub fn spot_savings_factor(&self) -> f64 {
        if self.ec2_usd == 0.0 {
            1.0
        } else {
            self.on_demand_equivalent_usd / self.ec2_usd
        }
    }
}

/// Build a report from raw service counters.
pub fn compute_report(
    ec2_records: &[CostRecord],
    ec2_active_accrued_usd: f64,
    sqs_requests: u64,
    s3: S3Stats,
    s3_gb_hours: f64,
    cw_metric_puts: u64,
) -> CostReport {
    let ec2_usd: f64 =
        ec2_records.iter().map(|r| r.cost_usd).sum::<f64>() + ec2_active_accrued_usd;
    let machine_hours: f64 = ec2_records
        .iter()
        .map(|r| (r.span.1 - r.span.0) as f64 / crate::sim::HOUR as f64)
        .sum();
    let on_demand_equivalent_usd: f64 = ec2_records
        .iter()
        .map(|r| {
            let ty = crate::aws::ec2::instance_type(r.itype).unwrap();
            ty.on_demand_hourly * (r.span.1 - r.span.0) as f64 / crate::sim::HOUR as f64
        })
        .sum();
    CostReport {
        ec2_usd,
        sqs_usd: sqs_requests as f64 / 1e6 * SQS_PER_MILLION_REQ,
        s3_usd: (s3.put_requests + s3.list_requests) as f64 / 1e3 * S3_PER_1K_PUT
            + s3.get_requests as f64 / 1e3 * S3_PER_1K_GET
            + s3_gb_hours / 730.0 * S3_PER_GB_MONTH,
        cloudwatch_usd: cw_metric_puts as f64 / 1e3 * CW_PER_1K_PUTS,
        machine_hours,
        on_demand_equivalent_usd,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::TerminationReason;
    use crate::sim::HOUR;

    fn rec(cost: f64, hours: u64) -> CostRecord {
        CostRecord {
            instance: 1,
            itype: "m5.large",
            lifecycle: crate::aws::ec2::Lifecycle::Spot,
            span: (0, hours * HOUR),
            cost_usd: cost,
            reason: TerminationReason::FleetCancelled,
        }
    }

    #[test]
    fn totals_add_up() {
        let r = compute_report(&[rec(0.30, 10)], 0.0, 1_000_000, S3Stats::default(), 0.0, 0);
        assert!((r.ec2_usd - 0.30).abs() < 1e-12);
        assert!((r.sqs_usd - 0.40).abs() < 1e-12);
        assert!((r.total_usd() - 0.70).abs() < 1e-12);
        assert!((r.machine_hours - 10.0).abs() < 1e-12);
    }

    #[test]
    fn on_demand_equivalent_uses_catalog() {
        let r = compute_report(&[rec(0.30, 10)], 0.0, 0, S3Stats::default(), 0.0, 0);
        // 10h of m5.large on demand = 0.96 -> savings factor 3.2x
        assert!((r.on_demand_equivalent_usd - 0.96).abs() < 1e-9);
        assert!((r.spot_savings_factor() - 3.2).abs() < 1e-9);
    }

    #[test]
    fn overhead_fraction_small_for_compute_heavy_run() {
        // 100 machine-hours at one metric put and a couple of queue/S3
        // round trips per job-minute.
        let s3 = S3Stats {
            put_requests: 5_000,
            get_requests: 20_000,
            list_requests: 5_000,
            bytes_in: 0,
            bytes_out: 0,
        };
        let r = compute_report(&[rec(5.0, 100)], 0.0, 100_000, s3, 10.0, 6_000);
        assert!(
            r.overhead_fraction() < 0.05,
            "overhead={} should be negligible",
            r.overhead_fraction()
        );
    }

    #[test]
    fn accrued_active_cost_included() {
        let r = compute_report(&[], 1.25, 0, S3Stats::default(), 0.0, 0);
        assert!((r.ec2_usd - 1.25).abs() < 1e-12);
    }
}
