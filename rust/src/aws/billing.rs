//! Billing meter: turns service usage into USD line items.
//!
//! Powers the cost experiments (T2 spot-vs-on-demand, T3 cheapest mode,
//! T6 resume savings) and quantifies the paper's "adds negligible costs
//! to the compute" claim: control-plane requests (SQS + S3 + CloudWatch)
//! are metered separately from EC2 machine-hours so the coordinator
//! overhead fraction is reported directly.  The data plane adds two more
//! line items: S3 requests issued for timed transfers and egress on
//! every byte that leaves a bucket (see [`DataBreakdown`]).
//!
//! Rates are the 2022-era public price sheet shape: exact values matter
//! only through the *ratios* experiments report.

use crate::aws::ec2::fleet::CostRecord;
use crate::aws::s3::dataplane::TransferStats;
use crate::aws::s3::S3Stats;

/// $/1M SQS requests (standard queue, after free tier).
pub const SQS_PER_MILLION_REQ: f64 = 0.40;
/// $/1k S3 PUT/LIST requests.
pub const S3_PER_1K_PUT: f64 = 0.005;
/// $/1k S3 GET requests (HEAD bills in this class too).
pub const S3_PER_1K_GET: f64 = 0.0004;
/// $/GB-month S3 standard storage.
pub const S3_PER_GB_MONTH: f64 = 0.023;
/// $/GB leaving S3 (cross-AZ/processed-shape rate; in-region raw
/// transfer is free on the real sheet, but charging the byte flow keeps
/// storage-bound runs visible in the bill, which is the point).  Metered
/// only where transfer *time* is modeled — the data plane's flows — so
/// the store's instantaneous GETs neither re-price pre-data-plane runs
/// nor double-bill an input a flow already carried.
pub const S3_PER_GB_EGRESS: f64 = 0.02;
/// $/GB leaving a bucket for an instance in *another region* (the
/// inter-region transfer sheet rate).  Billed *in addition* to
/// [`S3_PER_GB_EGRESS`] and only as a [`TopologyBreakdown`] line item
/// (`xregion_usd`) when a multi-region topology is installed — the flat
/// single-domain bill is untouched, so pre-topology runs re-price to the
/// exact same dollars.
///
/// [`TopologyBreakdown`]: crate::topology::TopologyBreakdown
pub const S3_XREGION_PER_GB: f64 = 0.09;
/// $/1k CloudWatch metric PutMetricData requests (approximation).
pub const CW_PER_1K_PUTS: f64 = 0.01;

/// Itemized cost summary of a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostReport {
    pub ec2_usd: f64,
    pub sqs_usd: f64,
    pub s3_usd: f64,
    /// Egress on the data plane's timed downloads (see
    /// [`S3_PER_GB_EGRESS`] for why instantaneous GETs are exempt).
    pub s3_egress_usd: f64,
    pub cloudwatch_usd: f64,
    /// Machine-hours actually billed (spot + on-demand base).
    pub machine_hours: f64,
    /// What the same machine-hours would have cost entirely on-demand.
    /// For instances the fleet's `ON_DEMAND_BASE` already bought
    /// on-demand, equivalent equals actual — only the spot slice saves.
    pub on_demand_equivalent_usd: f64,
}

impl CostReport {
    pub fn total_usd(&self) -> f64 {
        self.ec2_usd + self.sqs_usd + self.s3_usd + self.s3_egress_usd + self.cloudwatch_usd
    }

    /// Control-plane overhead as a fraction of total ("negligible
    /// costs").  Egress is data gravity, not coordination, so it sits in
    /// the denominator only.
    pub fn overhead_fraction(&self) -> f64 {
        let t = self.total_usd();
        if t == 0.0 {
            0.0
        } else {
            (self.sqs_usd + self.s3_usd + self.cloudwatch_usd) / t
        }
    }

    /// Spot savings vs on-demand for the same machine-hours.
    pub fn spot_savings_factor(&self) -> f64 {
        if self.ec2_usd == 0.0 {
            1.0
        } else {
            self.on_demand_equivalent_usd / self.ec2_usd
        }
    }
}

/// The data-plane slice of a run, the storage analog of the per-pool EC2
/// breakdown (`PoolBreakdown`): how many bytes moved, what the requests
/// and egress cost, and *which capacity was the bottleneck* while they
/// moved.  Threads RunReport → ScenarioSummary → sweep JSON.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DataBreakdown {
    /// Bytes that flowed S3 → fleet (completed + partial cancelled flows).
    pub bytes_downloaded: u64,
    /// Bytes that flowed fleet → S3.
    pub bytes_uploaded: u64,
    /// Bytes that flowed and were thrown away (transfers cut short by
    /// interruption / crash / reaping — the re-download tax).
    pub bytes_wasted: u64,
    /// GET requests: instantaneous `GetObject`s plus data-plane downloads.
    pub get_requests: u64,
    /// PUT requests: `PutObject`/`DeleteObject` plus data-plane uploads.
    pub put_requests: u64,
    /// HEAD probes (billed in the GET class).
    pub head_requests: u64,
    /// LIST requests (CHECK_IF_DONE polling; billed in the PUT class).
    pub list_requests: u64,
    /// The request slice of `CostReport::s3_usd` (excludes storage).
    pub request_usd: f64,
    /// Mirrors `CostReport::s3_egress_usd`.
    pub egress_usd: f64,
    /// Flow-milliseconds where the bucket's aggregate throughput was the
    /// binding constraint — when this dominates, adding machines cannot
    /// raise throughput (the storage-bound regime).
    pub bucket_bound_ms: u64,
    /// Flow-milliseconds where an instance NIC was the binding constraint.
    pub nic_bound_ms: u64,
    /// Flow-milliseconds spent waiting on per-request first-byte latency.
    pub first_byte_wait_ms: u64,
}

impl DataBreakdown {
    /// Bytes moved in either direction.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_downloaded + self.bytes_uploaded
    }

    /// Fraction of constrained flow time the *bucket* (not the fleet's
    /// NICs) was the bottleneck, in [0, 1].  Near 1 means the fleet is
    /// waiting on storage: `CLUSTER_MACHINES` has stopped helping.
    pub fn bucket_bound_fraction(&self) -> f64 {
        let total = self.bucket_bound_ms + self.nic_bound_ms;
        if total == 0 {
            0.0
        } else {
            self.bucket_bound_ms as f64 / total as f64
        }
    }
}

/// Reduce raw S3 + transfer counters into the [`DataBreakdown`] view.
pub fn data_breakdown(s3: S3Stats, net: TransferStats) -> DataBreakdown {
    let get_requests = s3.get_requests + net.downloads_started;
    let put_requests = s3.put_requests + net.uploads_started;
    DataBreakdown {
        bytes_downloaded: net.bytes_downloaded,
        bytes_uploaded: net.bytes_uploaded,
        bytes_wasted: net.bytes_wasted,
        get_requests,
        put_requests,
        head_requests: s3.head_requests,
        list_requests: s3.list_requests,
        request_usd: (put_requests + s3.list_requests) as f64 / 1e3 * S3_PER_1K_PUT
            + (get_requests + s3.head_requests) as f64 / 1e3 * S3_PER_1K_GET,
        egress_usd: egress_usd(net),
        bucket_bound_ms: net.bucket_bound_ms,
        nic_bound_ms: net.nic_bound_ms,
        first_byte_wait_ms: net.first_byte_wait_ms,
    }
}

/// Egress dollars: data-plane download bytes only (see
/// [`S3_PER_GB_EGRESS`]).  Peer-class flows (node-local / shared-fs
/// artifact sharing) never leave S3, so their bytes are exempt.
fn egress_usd(net: TransferStats) -> f64 {
    (net.bytes_downloaded - net.peer_bytes_downloaded) as f64 / 1e9 * S3_PER_GB_EGRESS
}

/// Build a report from raw service counters.
pub fn compute_report(
    ec2_records: &[CostRecord],
    ec2_active_accrued_usd: f64,
    sqs_requests: u64,
    s3: S3Stats,
    s3_gb_hours: f64,
    cw_metric_puts: u64,
    net: TransferStats,
) -> CostReport {
    let ec2_usd: f64 =
        ec2_records.iter().map(|r| r.cost_usd).sum::<f64>() + ec2_active_accrued_usd;
    let machine_hours: f64 = ec2_records
        .iter()
        .map(|r| (r.span.1 - r.span.0) as f64 / crate::sim::HOUR as f64)
        .sum();
    let on_demand_equivalent_usd: f64 = ec2_records
        .iter()
        .map(|r| {
            let ty = crate::aws::ec2::instance_type(r.itype).unwrap();
            ty.on_demand_hourly * (r.span.1 - r.span.0) as f64 / crate::sim::HOUR as f64
        })
        .sum();
    CostReport {
        ec2_usd,
        sqs_usd: sqs_requests as f64 / 1e6 * SQS_PER_MILLION_REQ,
        s3_usd: (s3.put_requests + s3.list_requests + net.uploads_started) as f64 / 1e3
            * S3_PER_1K_PUT
            + (s3.get_requests + s3.head_requests + net.downloads_started) as f64 / 1e3
                * S3_PER_1K_GET
            + s3_gb_hours / 730.0 * S3_PER_GB_MONTH,
        s3_egress_usd: egress_usd(net),
        cloudwatch_usd: cw_metric_puts as f64 / 1e3 * CW_PER_1K_PUTS,
        machine_hours,
        on_demand_equivalent_usd,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::TerminationReason;
    use crate::sim::HOUR;

    fn rec(cost: f64, hours: u64) -> CostRecord {
        CostRecord {
            instance: 1,
            itype: "m5.large",
            lifecycle: crate::aws::ec2::Lifecycle::Spot,
            span: (0, hours * HOUR),
            cost_usd: cost,
            reason: TerminationReason::FleetCancelled,
            domain: 0,
        }
    }

    #[test]
    fn totals_add_up() {
        let r = compute_report(
            &[rec(0.30, 10)],
            0.0,
            1_000_000,
            S3Stats::default(),
            0.0,
            0,
            TransferStats::default(),
        );
        assert!((r.ec2_usd - 0.30).abs() < 1e-12);
        assert!((r.sqs_usd - 0.40).abs() < 1e-12);
        assert!((r.total_usd() - 0.70).abs() < 1e-12);
        assert!((r.machine_hours - 10.0).abs() < 1e-12);
    }

    #[test]
    fn on_demand_equivalent_uses_catalog() {
        let r = compute_report(
            &[rec(0.30, 10)],
            0.0,
            0,
            S3Stats::default(),
            0.0,
            0,
            TransferStats::default(),
        );
        // 10h of m5.large on demand = 0.96 -> savings factor 3.2x
        assert!((r.on_demand_equivalent_usd - 0.96).abs() < 1e-9);
        assert!((r.spot_savings_factor() - 3.2).abs() < 1e-9);
    }

    #[test]
    fn overhead_fraction_small_for_compute_heavy_run() {
        // 100 machine-hours at one metric put and a couple of queue/S3
        // round trips per job-minute.
        let s3 = S3Stats {
            put_requests: 5_000,
            get_requests: 20_000,
            head_requests: 0,
            list_requests: 5_000,
            bytes_in: 0,
            bytes_out: 0,
        };
        let r = compute_report(&[rec(5.0, 100)], 0.0, 100_000, s3, 10.0, 6_000, TransferStats::default());
        assert!(
            r.overhead_fraction() < 0.05,
            "overhead={} should be negligible",
            r.overhead_fraction()
        );
    }

    #[test]
    fn accrued_active_cost_included() {
        let r = compute_report(&[], 1.25, 0, S3Stats::default(), 0.0, 0, TransferStats::default());
        assert!((r.ec2_usd - 1.25).abs() < 1e-12);
    }

    #[test]
    fn data_plane_bytes_and_requests_reach_the_bill() {
        let net = TransferStats {
            bytes_downloaded: 50_000_000_000, // 50 GB out of the bucket
            bytes_uploaded: 10_000_000_000,
            downloads_started: 1_000,
            uploads_started: 1_000,
            ..Default::default()
        };
        let r = compute_report(&[], 0.0, 0, S3Stats::default(), 0.0, 0, net);
        // Egress: 50 GB x $0.02.
        assert!((r.s3_egress_usd - 1.0).abs() < 1e-9, "{}", r.s3_egress_usd);
        // Requests: 1k GETs + 1k PUTs.
        let want = 1.0 * S3_PER_1K_PUT + 1.0 * S3_PER_1K_GET;
        assert!((r.s3_usd - want).abs() < 1e-12, "{}", r.s3_usd);
        assert!((r.total_usd() - (1.0 + want)).abs() < 1e-9);
        // Egress is not "overhead": a pure-data bill is ~all egress.
        assert!(r.overhead_fraction() < 0.01, "{}", r.overhead_fraction());
    }

    #[test]
    fn head_requests_bill_in_the_get_class() {
        let with_heads = S3Stats {
            head_requests: 10_000,
            ..Default::default()
        };
        let as_gets = S3Stats {
            get_requests: 10_000,
            ..Default::default()
        };
        let a = compute_report(&[], 0.0, 0, with_heads, 0.0, 0, TransferStats::default());
        let b = compute_report(&[], 0.0, 0, as_gets, 0.0, 0, TransferStats::default());
        assert_eq!(a.s3_usd, b.s3_usd);
        assert!(a.s3_usd > 0.0);
    }

    #[test]
    fn data_breakdown_merges_store_and_plane_counters() {
        let s3 = S3Stats {
            put_requests: 5,
            get_requests: 7,
            head_requests: 11,
            list_requests: 13,
            bytes_in: 0,
            bytes_out: 1_000_000_000,
        };
        let net = TransferStats {
            bytes_downloaded: 2_000_000_000,
            bytes_uploaded: 500_000_000,
            bytes_wasted: 123,
            downloads_started: 17,
            uploads_started: 19,
            bucket_bound_ms: 300,
            nic_bound_ms: 100,
            ..Default::default()
        };
        let d = data_breakdown(s3, net);
        assert_eq!(d.get_requests, 24);
        assert_eq!(d.put_requests, 24);
        assert_eq!(d.head_requests, 11);
        assert_eq!(d.list_requests, 13);
        assert_eq!(d.total_bytes(), 2_500_000_000);
        assert_eq!(d.bytes_wasted, 123);
        // Egress covers the plane's timed downloads only (2 GB x $0.02):
        // the store's 1 GB of instantaneous GETs stays request-billed,
        // so pre-data-plane runs keep their exact pre-data-plane bills.
        assert!((d.egress_usd - 0.04).abs() < 1e-9);
        assert!((d.bucket_bound_fraction() - 0.75).abs() < 1e-12);
        // Matches the CostReport line items it mirrors.
        let r = compute_report(&[], 0.0, 0, s3, 0.0, 0, net);
        assert_eq!(d.egress_usd, r.s3_egress_usd);
        assert!((d.request_usd - r.s3_usd).abs() < 1e-12, "no storage term here");
    }

    #[test]
    fn peer_bytes_are_exempt_from_egress_and_requests() {
        // 3 GB moved, 2 GB of it over peer links: only the S3 GB bills
        // egress, and only the S3 flows bill GET requests.
        let net = TransferStats {
            bytes_downloaded: 3_000_000_000,
            peer_bytes_downloaded: 2_000_000_000,
            downloads_started: 10,
            peer_flows_started: 20,
            ..Default::default()
        };
        let r = compute_report(&[], 0.0, 0, S3Stats::default(), 0.0, 0, net);
        assert!((r.s3_egress_usd - 0.02).abs() < 1e-9, "{}", r.s3_egress_usd);
        let d = data_breakdown(S3Stats::default(), net);
        assert_eq!(d.get_requests, 10, "peer flows bill no GETs");
        assert_eq!(d.bytes_downloaded, 3_000_000_000, "breakdown still shows all bytes");
        assert_eq!(d.egress_usd, r.s3_egress_usd);
    }

    #[test]
    fn empty_breakdown_is_all_zero() {
        let d = data_breakdown(S3Stats::default(), TransferStats::default());
        assert_eq!(d, DataBreakdown::default());
        assert_eq!(d.bucket_bound_fraction(), 0.0);
    }
}
