//! Simple Queue Service: visibility timeouts, redelivery, dead-letter
//! queues.
//!
//! SQS semantics are the heart of the paper's reliability story:
//!
//! * `SQS_MESSAGE_VISIBILITY` — a received message is hidden for the
//!   visibility timeout; if the worker neither deletes it nor finishes in
//!   time, it reappears and another worker retries it ("if you set it too
//!   short, you may waste resources doing the same job multiple times; if
//!   you set it too long, your instances may have to wait around").
//! * `SQS_DEAD_LETTER_QUEUE` — after `max_receive_count` receives a
//!   message is moved aside, "keep[ing] a single bad job … from keeping
//!   your cluster active indefinitely".
//!
//! Expiry is applied lazily: every operation takes `now` and first
//! returns any timed-out in-flight messages to the visible queue (or the
//! DLQ).  This keeps the service passive — no event-loop coupling — while
//! remaining exact, because visibility only matters at observation points.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::sim::SimTime;

/// A queued message.  `body` is the DS job payload (JSON text).
#[derive(Debug, Clone)]
pub struct Message {
    pub id: u64,
    pub body: String,
    /// Times this message has been received (ApproximateReceiveCount).
    pub receive_count: u32,
    pub first_enqueued: SimTime,
}

/// Receipt handle: proof-of-receive required to delete.  Unique per
/// receive (re-receives of the same message get fresh handles; stale
/// handles no longer delete, as in real SQS).
pub type ReceiptHandle = u64;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RedrivePolicy {
    pub max_receive_count: u32,
}

#[derive(Debug)]
struct InFlight {
    msg: Message,
    visible_at: SimTime,
}

/// Request counters for billing (SQS bills per request).
#[derive(Debug, Default, Clone, Copy)]
pub struct SqsStats {
    pub send_requests: u64,
    pub receive_requests: u64,
    pub delete_requests: u64,
    /// Messages that timed out in flight and were returned to the queue.
    pub redeliveries: u64,
    /// Messages moved to a dead-letter queue.
    pub dead_lettered: u64,
}

/// One queue.
#[derive(Debug)]
pub struct Queue {
    pub name: String,
    pub visibility_timeout: SimTime,
    pub redrive: Option<(String, RedrivePolicy)>,
    visible: VecDeque<Message>,
    in_flight: HashMap<ReceiptHandle, InFlight>,
    /// Min-heap of (visible_at, handle) for O(log n) expiry instead of a
    /// full in-flight scan per operation (perf pass: 220 µs → sub-µs on a
    /// 100k-deep queue).  Entries go stale when `change_visibility` moves
    /// a deadline or the message is deleted; stale entries are skipped
    /// lazily by re-checking against `in_flight`.
    expiry: BinaryHeap<Reverse<(SimTime, ReceiptHandle)>>,
    next_msg_id: u64,
    next_receipt: u64,
    stats: SqsStats,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum SqsError {
    #[error("QueueDoesNotExist: {0}")]
    NoSuchQueue(String),
    #[error("ReceiptHandleIsInvalid")]
    InvalidReceipt,
}

impl Queue {
    fn new(name: &str, visibility_timeout: SimTime) -> Self {
        Self {
            name: name.to_string(),
            visibility_timeout,
            redrive: None,
            visible: VecDeque::new(),
            in_flight: HashMap::new(),
            expiry: BinaryHeap::new(),
            next_msg_id: 0,
            next_receipt: 0,
            stats: SqsStats::default(),
        }
    }

    /// Return timed-out in-flight messages to visibility (or flag for DLQ).
    /// Returns messages that exceeded the redrive policy.  O(k log n) for
    /// k expirations via the expiry heap; heap order (deadline, handle) is
    /// deterministic.
    fn expire(&mut self, now: SimTime) -> Vec<Message> {
        let mut dead = Vec::new();
        while let Some(&Reverse((at, h))) = self.expiry.peek() {
            if at > now {
                break;
            }
            self.expiry.pop();
            // Stale heap entry? (deleted, or deadline moved)
            let Some(f) = self.in_flight.get(&h) else {
                continue;
            };
            if f.visible_at != at {
                continue;
            }
            let f = self.in_flight.remove(&h).unwrap();
            self.stats.redeliveries += 1;
            let max = self.redrive.as_ref().map(|(_, p)| p.max_receive_count);
            match max {
                Some(m) if f.msg.receive_count >= m => {
                    self.stats.dead_lettered += 1;
                    dead.push(f.msg);
                }
                _ => self.visible.push_back(f.msg),
            }
        }
        dead
    }
}

/// The SQS control plane: named queues.
#[derive(Debug, Default)]
pub struct Sqs {
    queues: HashMap<String, Queue>,
}

impl Sqs {
    pub fn new() -> Self {
        Self::default()
    }

    /// CreateQueue (idempotent on the name; updates visibility timeout).
    pub fn create_queue(&mut self, name: &str, visibility_timeout: SimTime) {
        self.queues
            .entry(name.to_string())
            .and_modify(|q| q.visibility_timeout = visibility_timeout)
            .or_insert_with(|| Queue::new(name, visibility_timeout));
    }

    /// Attach a redrive policy: after `max_receive_count` receives,
    /// messages move to `dlq_name` (which must exist).
    pub fn set_redrive(
        &mut self,
        name: &str,
        dlq_name: &str,
        policy: RedrivePolicy,
    ) -> Result<(), SqsError> {
        if !self.queues.contains_key(dlq_name) {
            return Err(SqsError::NoSuchQueue(dlq_name.into()));
        }
        let q = self
            .queues
            .get_mut(name)
            .ok_or_else(|| SqsError::NoSuchQueue(name.into()))?;
        q.redrive = Some((dlq_name.to_string(), policy));
        Ok(())
    }

    pub fn queue_exists(&self, name: &str) -> bool {
        self.queues.contains_key(name)
    }

    /// DeleteQueue.
    pub fn delete_queue(&mut self, name: &str) {
        self.queues.remove(name);
    }

    fn run_expiry(&mut self, name: &str, now: SimTime) {
        let Some(q) = self.queues.get_mut(name) else {
            return;
        };
        let dead = q.expire(now);
        if dead.is_empty() {
            return;
        }
        let dlq_name = q.redrive.as_ref().map(|(d, _)| d.clone());
        if let Some(dlq_name) = dlq_name {
            for m in dead {
                // Re-enqueue into the DLQ preserving body.
                self.send_internal(&dlq_name, m.body, now);
            }
        }
    }

    fn send_internal(&mut self, name: &str, body: String, now: SimTime) {
        if let Some(q) = self.queues.get_mut(name) {
            q.next_msg_id += 1;
            q.stats.send_requests += 1;
            q.visible.push_back(Message {
                id: q.next_msg_id,
                body,
                receive_count: 0,
                first_enqueued: now,
            });
        }
    }

    /// SendMessage.
    pub fn send(&mut self, name: &str, body: impl Into<String>, now: SimTime) -> Result<(), SqsError> {
        if !self.queues.contains_key(name) {
            return Err(SqsError::NoSuchQueue(name.into()));
        }
        self.send_internal(name, body.into(), now);
        Ok(())
    }

    /// ReceiveMessage (max 1, like the DS worker): hides the message for
    /// the queue's visibility timeout and returns a receipt handle.
    pub fn receive(
        &mut self,
        name: &str,
        now: SimTime,
    ) -> Result<Option<(Message, ReceiptHandle)>, SqsError> {
        if !self.queues.contains_key(name) {
            return Err(SqsError::NoSuchQueue(name.into()));
        }
        self.run_expiry(name, now);
        let q = self.queues.get_mut(name).unwrap();
        q.stats.receive_requests += 1;
        let Some(mut msg) = q.visible.pop_front() else {
            return Ok(None);
        };
        msg.receive_count += 1;
        q.next_receipt += 1;
        let handle = q.next_receipt;
        let visible_at = now + q.visibility_timeout;
        q.in_flight.insert(
            handle,
            InFlight {
                msg: msg.clone(),
                visible_at,
            },
        );
        q.expiry.push(Reverse((visible_at, handle)));
        Ok(Some((msg, handle)))
    }

    /// ReceiveMessage with a dispatch policy: like [`Sqs::receive`], but the
    /// caller picks *which* visible message to serve via `choose`, which is
    /// handed the visible queue in FIFO order and returns an index into it
    /// (out-of-range falls back to the head; `None` with a non-empty queue
    /// also falls back to the head).  Bookkeeping — receive counting,
    /// receipt handles, visibility hold, expiry — is identical to the plain
    /// receive, so a chooser that always returns 0 is byte-equivalent to
    /// FIFO.  This is the hook the coordinator's tenant-aware queueing
    /// policies (fair-share, priority) use.
    pub fn receive_choose(
        &mut self,
        name: &str,
        now: SimTime,
        choose: impl FnOnce(&[Message]) -> Option<usize>,
    ) -> Result<Option<(Message, ReceiptHandle)>, SqsError> {
        if !self.queues.contains_key(name) {
            return Err(SqsError::NoSuchQueue(name.into()));
        }
        self.run_expiry(name, now);
        let q = self.queues.get_mut(name).unwrap();
        q.stats.receive_requests += 1;
        if q.visible.is_empty() {
            return Ok(None);
        }
        let idx = match choose(q.visible.make_contiguous()) {
            Some(i) if i < q.visible.len() => i,
            _ => 0,
        };
        let mut msg = q.visible.remove(idx).unwrap();
        msg.receive_count += 1;
        q.next_receipt += 1;
        let handle = q.next_receipt;
        let visible_at = now + q.visibility_timeout;
        q.in_flight.insert(
            handle,
            InFlight {
                msg: msg.clone(),
                visible_at,
            },
        );
        q.expiry.push(Reverse((visible_at, handle)));
        Ok(Some((msg, handle)))
    }

    /// DeleteMessage: completes a job.  Stale handles (already expired and
    /// redelivered) are an error, mirroring real SQS.
    pub fn delete(
        &mut self,
        name: &str,
        handle: ReceiptHandle,
        now: SimTime,
    ) -> Result<(), SqsError> {
        self.run_expiry(name, now);
        let q = self
            .queues
            .get_mut(name)
            .ok_or_else(|| SqsError::NoSuchQueue(name.into()))?;
        q.stats.delete_requests += 1;
        q.in_flight
            .remove(&handle)
            .map(|_| ())
            .ok_or(SqsError::InvalidReceipt)
    }

    /// ChangeMessageVisibility: extend/shorten a specific in-flight hold.
    pub fn change_visibility(
        &mut self,
        name: &str,
        handle: ReceiptHandle,
        timeout: SimTime,
        now: SimTime,
    ) -> Result<(), SqsError> {
        self.run_expiry(name, now);
        let q = self
            .queues
            .get_mut(name)
            .ok_or_else(|| SqsError::NoSuchQueue(name.into()))?;
        match q.in_flight.get_mut(&handle) {
            Some(f) => {
                f.visible_at = now + timeout;
                q.expiry.push(Reverse((now + timeout, handle)));
                Ok(())
            }
            None => Err(SqsError::InvalidReceipt),
        }
    }

    /// (ApproximateNumberOfMessages, ApproximateNumberOfMessagesNotVisible)
    /// — the pair `monitor` polls once per minute.
    pub fn approximate_counts(&mut self, name: &str, now: SimTime) -> (usize, usize) {
        self.run_expiry(name, now);
        match self.queues.get(name) {
            Some(q) => (q.visible.len(), q.in_flight.len()),
            None => (0, 0),
        }
    }

    /// ApproximateAgeOfOldestMessage: age of the oldest not-yet-deleted
    /// message (visible or in flight), in sim-time ms.  0 for an empty
    /// or missing queue.  One of the SQS metrics the monitor publishes
    /// for the autoscaling alarms.
    pub fn oldest_message_age(&mut self, name: &str, now: SimTime) -> SimTime {
        self.run_expiry(name, now);
        let Some(q) = self.queues.get(name) else {
            return 0;
        };
        q.visible
            .iter()
            .map(|m| m.first_enqueued)
            .chain(q.in_flight.values().map(|f| f.msg.first_enqueued))
            .min()
            .map(|t| now.saturating_sub(t))
            .unwrap_or(0)
    }

    /// Earliest time at which an in-flight message may become visible
    /// again (drives lazy event scheduling in the coordinator).
    pub fn next_visibility_change(&self, name: &str) -> Option<SimTime> {
        self.queues
            .get(name)?
            .in_flight
            .values()
            .map(|f| f.visible_at)
            .min()
    }

    pub fn stats(&self, name: &str) -> SqsStats {
        self.queues.get(name).map(|q| q.stats).unwrap_or_default()
    }

    /// Total requests across all queues (billing).
    pub fn total_requests(&self) -> u64 {
        self.queues
            .values()
            .map(|q| q.stats.send_requests + q.stats.receive_requests + q.stats.delete_requests)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{MINUTE, SECOND};

    fn sqs_with_queue(vis: SimTime) -> Sqs {
        let mut s = Sqs::new();
        s.create_queue("jobs", vis);
        s
    }

    #[test]
    fn send_receive_delete() {
        let mut s = sqs_with_queue(MINUTE);
        s.send("jobs", "j1", 0).unwrap();
        let (m, h) = s.receive("jobs", 1).unwrap().unwrap();
        assert_eq!(m.body, "j1");
        assert_eq!(m.receive_count, 1);
        s.delete("jobs", h, 2).unwrap();
        assert_eq!(s.approximate_counts("jobs", 3), (0, 0));
    }

    #[test]
    fn fifo_order_of_visible() {
        let mut s = sqs_with_queue(MINUTE);
        for i in 0..5 {
            s.send("jobs", format!("j{i}"), 0).unwrap();
        }
        for i in 0..5 {
            let (m, _) = s.receive("jobs", 1).unwrap().unwrap();
            assert_eq!(m.body, format!("j{i}"));
        }
    }

    #[test]
    fn invisible_while_in_flight() {
        let mut s = sqs_with_queue(MINUTE);
        s.send("jobs", "j", 0).unwrap();
        let _ = s.receive("jobs", 0).unwrap().unwrap();
        assert!(s.receive("jobs", 30 * SECOND).unwrap().is_none());
        assert_eq!(s.approximate_counts("jobs", 30 * SECOND), (0, 1));
    }

    #[test]
    fn reappears_after_visibility_timeout() {
        let mut s = sqs_with_queue(MINUTE);
        s.send("jobs", "j", 0).unwrap();
        let (_, h1) = s.receive("jobs", 0).unwrap().unwrap();
        let (m2, _) = s.receive("jobs", MINUTE).unwrap().unwrap();
        assert_eq!(m2.body, "j");
        assert_eq!(m2.receive_count, 2);
        // Stale handle no longer deletes.
        assert_eq!(s.delete("jobs", h1, MINUTE), Err(SqsError::InvalidReceipt));
    }

    #[test]
    fn delete_before_timeout_prevents_redelivery() {
        let mut s = sqs_with_queue(MINUTE);
        s.send("jobs", "j", 0).unwrap();
        let (_, h) = s.receive("jobs", 0).unwrap().unwrap();
        s.delete("jobs", h, 10 * SECOND).unwrap();
        assert!(s.receive("jobs", 2 * MINUTE).unwrap().is_none());
        assert_eq!(s.stats("jobs").redeliveries, 0);
    }

    #[test]
    fn dead_letter_after_max_receives() {
        let mut s = sqs_with_queue(MINUTE);
        s.create_queue("dlq", MINUTE);
        s.set_redrive("jobs", "dlq", RedrivePolicy { max_receive_count: 3 }).unwrap();
        s.send("jobs", "poison", 0).unwrap();
        // Receive + let it time out, 3 times.
        let mut t = 0;
        for i in 1..=3 {
            let (m, _) = s.receive("jobs", t).unwrap().unwrap();
            assert_eq!(m.receive_count, i);
            t += MINUTE;
        }
        // Fourth attempt: message has hit max_receive_count; expiry moves
        // it to the DLQ instead of redelivering.
        assert!(s.receive("jobs", t).unwrap().is_none());
        assert_eq!(s.approximate_counts("dlq", t), (1, 0));
        assert_eq!(s.stats("jobs").dead_lettered, 1);
    }

    #[test]
    fn redrive_requires_existing_dlq() {
        let mut s = sqs_with_queue(MINUTE);
        assert!(s
            .set_redrive("jobs", "missing", RedrivePolicy { max_receive_count: 2 })
            .is_err());
    }

    #[test]
    fn change_visibility_extends_hold() {
        let mut s = sqs_with_queue(MINUTE);
        s.send("jobs", "j", 0).unwrap();
        let (_, h) = s.receive("jobs", 0).unwrap().unwrap();
        s.change_visibility("jobs", h, 10 * MINUTE, 30 * SECOND).unwrap();
        // Would have expired at 1m; now hidden until 10m30s.
        assert!(s.receive("jobs", 5 * MINUTE).unwrap().is_none());
        assert!(s.receive("jobs", 11 * MINUTE).unwrap().is_some());
    }

    #[test]
    fn next_visibility_change_tracks_min() {
        let mut s = sqs_with_queue(MINUTE);
        s.send("jobs", "a", 0).unwrap();
        s.send("jobs", "b", 0).unwrap();
        let _ = s.receive("jobs", 0).unwrap();
        let _ = s.receive("jobs", 10 * SECOND).unwrap();
        assert_eq!(s.next_visibility_change("jobs"), Some(MINUTE));
    }

    #[test]
    fn oldest_message_age_tracks_head_of_line() {
        let mut s = sqs_with_queue(MINUTE);
        assert_eq!(s.oldest_message_age("jobs", 5 * MINUTE), 0);
        assert_eq!(s.oldest_message_age("nope", 5 * MINUTE), 0);
        s.send("jobs", "a", MINUTE).unwrap();
        s.send("jobs", "b", 2 * MINUTE).unwrap();
        assert_eq!(s.oldest_message_age("jobs", 3 * MINUTE), 2 * MINUTE);
        // In-flight messages still count (they are not deleted).
        let (_, h) = s.receive("jobs", 3 * MINUTE).unwrap().unwrap();
        assert_eq!(s.oldest_message_age("jobs", 3 * MINUTE), 2 * MINUTE);
        s.delete("jobs", h, 3 * MINUTE).unwrap();
        assert_eq!(s.oldest_message_age("jobs", 3 * MINUTE), MINUTE);
    }

    #[test]
    fn receive_choose_serves_the_chosen_message() {
        let mut s = sqs_with_queue(MINUTE);
        for i in 0..3 {
            s.send("jobs", format!("j{i}"), 0).unwrap();
        }
        // The chooser sees the full visible queue in FIFO order and picks
        // the middle message.
        let (m, h) = s
            .receive_choose("jobs", 1, |msgs| {
                assert_eq!(msgs.len(), 3);
                assert_eq!(msgs[0].body, "j0");
                Some(1)
            })
            .unwrap()
            .unwrap();
        assert_eq!(m.body, "j1");
        assert_eq!(m.receive_count, 1);
        // Bookkeeping matches plain receive: hidden while in flight,
        // deletable by handle, remaining messages keep FIFO order.
        assert_eq!(s.approximate_counts("jobs", 1), (2, 1));
        s.delete("jobs", h, 2).unwrap();
        let (m2, _) = s.receive("jobs", 3).unwrap().unwrap();
        assert_eq!(m2.body, "j0");
    }

    #[test]
    fn receive_choose_falls_back_to_head_of_line() {
        let mut s = sqs_with_queue(MINUTE);
        s.send("jobs", "a", 0).unwrap();
        s.send("jobs", "b", 0).unwrap();
        // None and out-of-range both degrade to FIFO.
        let (m, _) = s.receive_choose("jobs", 1, |_| None).unwrap().unwrap();
        assert_eq!(m.body, "a");
        let (m, _) = s.receive_choose("jobs", 1, |_| Some(99)).unwrap().unwrap();
        assert_eq!(m.body, "b");
        // Empty queue: chooser is never consulted.
        assert!(s
            .receive_choose("jobs", 1, |_| panic!("chooser on empty queue"))
            .unwrap()
            .is_none());
        assert!(s.receive_choose("nope", 1, |_| Some(0)).is_err());
    }

    #[test]
    fn receive_choose_redelivers_on_timeout_like_receive() {
        let mut s = sqs_with_queue(MINUTE);
        s.send("jobs", "j", 0).unwrap();
        let (_, h1) = s.receive_choose("jobs", 0, |_| Some(0)).unwrap().unwrap();
        // Unfinished in-flight message reappears after the timeout, with
        // the receive count advanced and the old handle dead.
        let (m2, _) = s.receive_choose("jobs", MINUTE, |_| Some(0)).unwrap().unwrap();
        assert_eq!(m2.receive_count, 2);
        assert_eq!(s.delete("jobs", h1, MINUTE), Err(SqsError::InvalidReceipt));
    }

    #[test]
    fn missing_queue_errors() {
        let mut s = Sqs::new();
        assert!(s.send("nope", "x", 0).is_err());
        assert!(s.receive("nope", 0).is_err());
        assert!(s.delete("nope", 1, 0).is_err());
    }

    #[test]
    fn counts_after_mixed_ops() {
        let mut s = sqs_with_queue(MINUTE);
        for i in 0..10 {
            s.send("jobs", format!("{i}"), 0).unwrap();
        }
        let mut handles = vec![];
        for _ in 0..4 {
            handles.push(s.receive("jobs", 0).unwrap().unwrap().1);
        }
        s.delete("jobs", handles[0], 1).unwrap();
        assert_eq!(s.approximate_counts("jobs", 1), (6, 3));
        // At timeout the 3 remaining in-flight return.
        assert_eq!(s.approximate_counts("jobs", MINUTE), (9, 0));
    }
}
