//! Simulated AWS substrate — the five services Distributed-Something
//! coordinates, plus billing.
//!
//! Each service is a *passive*, synchronous state machine: all mutating
//! calls take the current [`crate::sim::SimTime`] and the event loop in
//! [`crate::coordinator::run`] decides when things happen.  That keeps
//! every service unit-testable in isolation and the whole-account
//! simulation deterministic.
//!
//! Fidelity notes per service live in their module docs; the
//! paper-behaviour each one must reproduce is indexed in DESIGN.md §2.

pub mod account;
pub mod billing;
pub mod cloudwatch;
pub mod ec2;
pub mod ecs;
pub mod s3;
pub mod sqs;

pub use account::AwsAccount;
