//! CloudWatch: metrics, alarms, and logs.
//!
//! DS leans on CloudWatch three ways (paper, Step 4):
//!
//! * per-instance CPUUtilization metrics feed the crash reaper —
//!   "if CPU usage dips below 1% for 15 consecutive minutes … the
//!   instance will be automatically terminated and a new one will take
//!   its place";
//! * per-job and per-container logs record progress;
//! * the monitor deletes alarms of dead instances hourly and exports all
//!   logs to S3 at the end of the run.

pub mod alarms;
pub mod logs;
pub mod metrics;

pub use alarms::{Alarm, AlarmAction, AlarmState, Alarms, Comparison};
pub use logs::Logs;
pub use metrics::Metrics;
