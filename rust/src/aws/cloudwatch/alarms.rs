//! Threshold alarms with consecutive-period evaluation and actions.
//!
//! The paper places two alarms per instance:
//! * the *crash reaper*: CPU < 1% for 15 consecutive 1-minute periods →
//!   terminate (the fleet replaces it);
//! * the *idle reboot*: placed by the Docker, reboots a machine "sitting
//!   idle for 15 minutes".
//!
//! Missing datapoints are treated as *breaching* (a crashed or
//! disconnected machine stops publishing, which is exactly the case the
//! reaper exists for).

use std::collections::HashMap;

use crate::sim::SimTime;

use super::metrics::Metrics;
use crate::aws::ec2::{FleetId, InstanceId};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Comparison {
    LessThan,
    GreaterThan,
}

/// What to do when the alarm fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlarmAction {
    TerminateInstance(InstanceId),
    RebootInstance(InstanceId),
    /// Grow the fleet per the monitor's scaling policy (the high
    /// queue-backlog alarm of `coordinator::autoscale`).
    ScaleOut(FleetId),
    /// Shrink the fleet per the monitor's scaling policy (the low
    /// queue-backlog alarm).
    ScaleIn(FleetId),
}

impl AlarmAction {
    /// Scaling actions re-fire on every breaching evaluation period
    /// (AWS scaling policies keep acting while their alarm stays in
    /// ALARM), unlike one-shot actions that fire only on the Ok→Alarm
    /// transition.  The autoscale controller's cooldowns decide how
    /// often the repeated signal actually moves the fleet.
    fn refires(&self) -> bool {
        matches!(self, AlarmAction::ScaleOut(_) | AlarmAction::ScaleIn(_))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlarmState {
    Ok,
    Alarm,
}

/// One alarm definition + current state.
#[derive(Debug, Clone)]
pub struct Alarm {
    pub name: String,
    pub metric: String,
    pub dimension: String,
    pub comparison: Comparison,
    pub threshold: f64,
    /// Length of one evaluation period.
    pub period: SimTime,
    /// Consecutive breaching periods required to fire.
    pub eval_periods: u32,
    pub action: AlarmAction,
    pub state: AlarmState,
    /// Consecutive breaching periods observed so far.
    breaching: u32,
    /// End of the last evaluated period.
    last_eval: SimTime,
}

/// The alarm service.
#[derive(Debug, Default)]
pub struct Alarms {
    alarms: HashMap<String, Alarm>,
}

impl Alarms {
    pub fn new() -> Self {
        Self::default()
    }

    /// PutMetricAlarm (idempotent by name; resets state).
    #[allow(clippy::too_many_arguments)]
    pub fn put_alarm(
        &mut self,
        name: &str,
        metric: &str,
        dimension: &str,
        comparison: Comparison,
        threshold: f64,
        period: SimTime,
        eval_periods: u32,
        action: AlarmAction,
        now: SimTime,
    ) {
        self.alarms.insert(
            name.to_string(),
            Alarm {
                name: name.to_string(),
                metric: metric.to_string(),
                dimension: dimension.to_string(),
                comparison,
                threshold,
                period,
                eval_periods,
                action,
                state: AlarmState::Ok,
                breaching: 0,
                last_eval: now,
            },
        );
    }

    /// DeleteAlarms.
    pub fn delete_alarm(&mut self, name: &str) {
        self.alarms.remove(name);
    }

    /// Delete every alarm whose dimension matches (monitor's hourly reap
    /// of dead instances' alarms).
    pub fn delete_for_dimension(&mut self, dimension: &str) -> usize {
        let before = self.alarms.len();
        self.alarms.retain(|_, a| a.dimension != dimension);
        before - self.alarms.len()
    }

    pub fn delete_all(&mut self) -> usize {
        let n = self.alarms.len();
        self.alarms.clear();
        n
    }

    pub fn len(&self) -> usize {
        self.alarms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.alarms.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&Alarm> {
        self.alarms.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.alarms.keys().cloned().collect();
        v.sort();
        v
    }

    /// Evaluate all alarms up to `now`; returns actions that newly fired
    /// (state transition Ok → Alarm), in alarm-name order.
    pub fn evaluate(&mut self, metrics: &Metrics, now: SimTime) -> Vec<AlarmAction> {
        let mut fired = Vec::new();
        let mut names: Vec<String> = self.alarms.keys().cloned().collect();
        names.sort();
        for name in names {
            let a = self.alarms.get_mut(&name).unwrap();
            // Evaluate each complete period since last_eval.
            while a.last_eval + a.period <= now {
                let from = a.last_eval;
                let to = a.last_eval + a.period;
                a.last_eval = to;
                let avg = metrics.avg(&a.metric, &a.dimension, from, to);
                let breaching = match (avg, a.comparison) {
                    // Missing data counts as breaching (dead machine).
                    (None, _) => true,
                    (Some(v), Comparison::LessThan) => v < a.threshold,
                    (Some(v), Comparison::GreaterThan) => v > a.threshold,
                };
                if breaching {
                    a.breaching += 1;
                    if a.breaching >= a.eval_periods
                        && (a.state == AlarmState::Ok || a.action.refires())
                    {
                        a.state = AlarmState::Alarm;
                        fired.push(a.action);
                    }
                } else {
                    a.breaching = 0;
                    a.state = AlarmState::Ok;
                }
            }
        }
        fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MINUTE;

    fn reaper(alarms: &mut Alarms, inst: InstanceId, now: SimTime) {
        alarms.put_alarm(
            &format!("cpu-low-i{inst}"),
            "CPUUtilization",
            &format!("i-{inst}"),
            Comparison::LessThan,
            1.0,
            MINUTE,
            15,
            AlarmAction::TerminateInstance(inst),
            now,
        );
    }

    fn publish(m: &mut Metrics, inst: InstanceId, from_min: u64, to_min: u64, v: f64) {
        for t in from_min..to_min {
            m.put("CPUUtilization", &format!("i-{inst}"), t * MINUTE + 1, v);
        }
    }

    #[test]
    fn fires_after_15_idle_minutes() {
        let mut alarms = Alarms::new();
        let mut m = Metrics::new();
        reaper(&mut alarms, 7, 0);
        publish(&mut m, 7, 0, 5, 80.0); // busy 5 min
        publish(&mut m, 7, 5, 25, 0.2); // crashed: 20 min idle
        assert!(alarms.evaluate(&m, 10 * MINUTE).is_empty());
        // 15 breaching periods complete at minute 20.
        let fired = alarms.evaluate(&m, 20 * MINUTE);
        assert_eq!(fired, vec![AlarmAction::TerminateInstance(7)]);
        // Does not re-fire while still in Alarm state.
        assert!(alarms.evaluate(&m, 25 * MINUTE).is_empty());
    }

    #[test]
    fn busy_minute_resets_streak() {
        let mut alarms = Alarms::new();
        let mut m = Metrics::new();
        reaper(&mut alarms, 1, 0);
        publish(&mut m, 1, 0, 14, 0.2); // 14 idle...
        publish(&mut m, 1, 14, 15, 50.0); // ...then busy
        publish(&mut m, 1, 15, 29, 0.2); // 14 idle again
        assert!(alarms.evaluate(&m, 29 * MINUTE).is_empty());
        publish(&mut m, 1, 29, 30, 0.2); // 15th consecutive
        assert_eq!(alarms.evaluate(&m, 30 * MINUTE).len(), 1);
    }

    #[test]
    fn missing_data_is_breaching() {
        let mut alarms = Alarms::new();
        let m = Metrics::new(); // machine never published at all
        reaper(&mut alarms, 3, 0);
        let fired = alarms.evaluate(&m, 15 * MINUTE);
        assert_eq!(fired, vec![AlarmAction::TerminateInstance(3)]);
    }

    #[test]
    fn greater_than_comparison() {
        let mut alarms = Alarms::new();
        let mut m = Metrics::new();
        alarms.put_alarm(
            "hot",
            "CPUUtilization",
            "i-9",
            Comparison::GreaterThan,
            90.0,
            MINUTE,
            3,
            AlarmAction::RebootInstance(9),
            0,
        );
        publish(&mut m, 9, 0, 3, 99.0);
        assert_eq!(
            alarms.evaluate(&m, 3 * MINUTE),
            vec![AlarmAction::RebootInstance(9)]
        );
    }

    #[test]
    fn delete_for_dimension_reaps() {
        let mut alarms = Alarms::new();
        reaper(&mut alarms, 1, 0);
        reaper(&mut alarms, 2, 0);
        assert_eq!(alarms.len(), 2);
        assert_eq!(alarms.delete_for_dimension("i-1"), 1);
        assert_eq!(alarms.len(), 1);
        assert_eq!(alarms.delete_all(), 1);
        assert!(alarms.is_empty());
    }

    #[test]
    fn scaling_alarms_refire_every_breaching_period() {
        let mut alarms = Alarms::new();
        let mut m = Metrics::new();
        alarms.put_alarm(
            "backlog-high",
            "QueueBacklogPerUnit",
            "queue:q",
            Comparison::GreaterThan,
            4.0,
            MINUTE,
            2,
            AlarmAction::ScaleOut(1),
            0,
        );
        for t in 0..6u64 {
            m.put("QueueBacklogPerUnit", "queue:q", t * MINUTE + 1, 40.0);
        }
        // Sustained breach: fires at period 2 and on every period after,
        // unlike a one-shot action (the cooldown throttles downstream).
        assert!(alarms.evaluate(&m, MINUTE).is_empty());
        assert_eq!(alarms.evaluate(&m, 2 * MINUTE), vec![AlarmAction::ScaleOut(1)]);
        assert_eq!(alarms.evaluate(&m, 3 * MINUTE), vec![AlarmAction::ScaleOut(1)]);
        // Recovery resets the streak like any alarm: periods 3..6 still
        // breach (3 more fires), the 0.0 point at minute 6 ends it.
        m.put("QueueBacklogPerUnit", "queue:q", 6 * MINUTE + 1, 0.0);
        assert_eq!(alarms.evaluate(&m, 7 * MINUTE).len(), 3);
        assert_eq!(alarms.get("backlog-high").unwrap().state, AlarmState::Ok);
    }

    #[test]
    fn recovery_returns_to_ok_and_can_refire() {
        let mut alarms = Alarms::new();
        let mut m = Metrics::new();
        reaper(&mut alarms, 4, 0);
        publish(&mut m, 4, 0, 15, 0.0);
        assert_eq!(alarms.evaluate(&m, 15 * MINUTE).len(), 1);
        publish(&mut m, 4, 15, 16, 60.0); // one busy minute -> Ok
        assert!(alarms.evaluate(&m, 16 * MINUTE).is_empty());
        publish(&mut m, 4, 16, 31, 0.0); // idle again -> re-fires
        assert_eq!(alarms.evaluate(&m, 31 * MINUTE).len(), 1);
    }
}
