//! Metric store: named series of (time, value) datapoints per dimension.

use std::collections::HashMap;

use crate::sim::SimTime;

/// Key: (metric name, dimension value) — e.g. ("CPUUtilization", "i-0042").
type Key = (String, String);

/// Time-ordered datapoints per metric/dimension.
#[derive(Debug, Default)]
pub struct Metrics {
    series: HashMap<Key, Vec<(SimTime, f64)>>,
    put_count: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// PutMetricData.  Datapoints must arrive in non-decreasing time order
    /// per series (the simulator always does).
    pub fn put(&mut self, metric: &str, dimension: &str, t: SimTime, value: f64) {
        self.put_count += 1;
        let s = self
            .series
            .entry((metric.to_string(), dimension.to_string()))
            .or_default();
        debug_assert!(s.last().map(|&(lt, _)| lt <= t).unwrap_or(true));
        s.push((t, value));
    }

    /// Datapoints in [from, to).
    pub fn query(
        &self,
        metric: &str,
        dimension: &str,
        from: SimTime,
        to: SimTime,
    ) -> &[(SimTime, f64)] {
        let Some(s) = self
            .series
            .get(&(metric.to_string(), dimension.to_string()))
        else {
            return &[];
        };
        let lo = s.partition_point(|&(t, _)| t < from);
        let hi = s.partition_point(|&(t, _)| t < to);
        &s[lo..hi]
    }

    /// Average over [from, to), if any datapoints exist.
    pub fn avg(&self, metric: &str, dimension: &str, from: SimTime, to: SimTime) -> Option<f64> {
        let pts = self.query(metric, dimension, from, to);
        if pts.is_empty() {
            return None;
        }
        Some(pts.iter().map(|&(_, v)| v).sum::<f64>() / pts.len() as f64)
    }

    /// Most recent datapoint at or before `t`.
    pub fn latest(&self, metric: &str, dimension: &str, t: SimTime) -> Option<(SimTime, f64)> {
        let s = self
            .series
            .get(&(metric.to_string(), dimension.to_string()))?;
        let idx = s.partition_point(|&(pt, _)| pt <= t);
        idx.checked_sub(1).map(|i| s[i])
    }

    /// Drop all series for a dimension (instance terminated & reaped).
    pub fn drop_dimension(&mut self, dimension: &str) {
        self.series.retain(|(_, d), _| d != dimension);
    }

    pub fn put_count(&self) -> u64 {
        self.put_count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_query_window() {
        let mut m = Metrics::new();
        for t in 0..10u64 {
            m.put("CPUUtilization", "i-1", t * 60, t as f64);
        }
        let pts = m.query("CPUUtilization", "i-1", 120, 300);
        assert_eq!(pts, &[(120, 2.0), (180, 3.0), (240, 4.0)]);
        assert!(m.query("CPUUtilization", "i-2", 0, 1_000).is_empty());
    }

    #[test]
    fn avg_and_latest() {
        let mut m = Metrics::new();
        m.put("CPUUtilization", "i-1", 0, 10.0);
        m.put("CPUUtilization", "i-1", 60, 20.0);
        m.put("CPUUtilization", "i-1", 120, 60.0);
        assert_eq!(m.avg("CPUUtilization", "i-1", 0, 121), Some(30.0));
        assert_eq!(m.avg("CPUUtilization", "i-1", 500, 600), None);
        assert_eq!(m.latest("CPUUtilization", "i-1", 119), Some((60, 20.0)));
        assert_eq!(m.latest("CPUUtilization", "i-1", 120), Some((120, 60.0)));
    }

    #[test]
    fn dimensions_independent() {
        let mut m = Metrics::new();
        m.put("CPUUtilization", "i-1", 0, 1.0);
        m.put("CPUUtilization", "i-2", 0, 2.0);
        m.put("MemoryUtilization", "i-1", 0, 3.0);
        assert_eq!(m.query("CPUUtilization", "i-1", 0, 1).len(), 1);
        m.drop_dimension("i-1");
        assert!(m.query("CPUUtilization", "i-1", 0, 1).is_empty());
        assert!(m.query("MemoryUtilization", "i-1", 0, 1).is_empty());
        assert_eq!(m.query("CPUUtilization", "i-2", 0, 1).len(), 1);
    }
}
