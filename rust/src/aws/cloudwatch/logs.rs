//! Log groups, streams, and S3 export.
//!
//! "Each individual job processed will create a log of the CellProfiler
//! output, and each Docker container will create a log showing CPU,
//! memory, and disk usage."  At cleanup the monitor "exports all the logs
//! from your analysis onto your S3 bucket".

use std::collections::BTreeMap;

use crate::aws::s3::{Body, S3};
use crate::sim::SimTime;

/// Log groups → streams → timestamped lines.
#[derive(Debug, Default)]
pub struct Logs {
    groups: BTreeMap<String, BTreeMap<String, Vec<(SimTime, String)>>>,
}

impl Logs {
    pub fn new() -> Self {
        Self::default()
    }

    /// CreateLogGroup (idempotent).
    pub fn create_group(&mut self, group: &str) {
        self.groups.entry(group.to_string()).or_default();
    }

    pub fn group_exists(&self, group: &str) -> bool {
        self.groups.contains_key(group)
    }

    /// PutLogEvents: appends to a stream, creating it on first write.
    /// The group must exist (DS's startCluster creates groups up front).
    pub fn put(&mut self, group: &str, stream: &str, t: SimTime, line: impl Into<String>) {
        if let Some(g) = self.groups.get_mut(group) {
            g.entry(stream.to_string())
                .or_default()
                .push((t, line.into()));
        }
    }

    /// All lines of one stream.
    pub fn stream(&self, group: &str, stream: &str) -> &[(SimTime, String)] {
        self.groups
            .get(group)
            .and_then(|g| g.get(stream))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Stream names in a group (sorted).
    pub fn streams(&self, group: &str) -> Vec<&str> {
        self.groups
            .get(group)
            .map(|g| g.keys().map(|k| k.as_str()).collect())
            .unwrap_or_default()
    }

    /// Total line count in a group.
    pub fn line_count(&self, group: &str) -> usize {
        self.groups
            .get(group)
            .map(|g| g.values().map(Vec::len).sum())
            .unwrap_or(0)
    }

    /// CreateExportTask: write every stream of `group` as one S3 object
    /// under `prefix` (like CloudWatch's S3 export).  Returns object count.
    pub fn export_to_s3(
        &self,
        group: &str,
        s3: &mut S3,
        bucket: &str,
        prefix: &str,
        now: SimTime,
    ) -> usize {
        let Some(g) = self.groups.get(group) else {
            return 0;
        };
        let mut n = 0;
        for (stream, lines) in g {
            let mut text = String::new();
            for (t, line) in lines {
                text.push_str(&format!("{} {}\n", crate::sim::clock::fmt_time(*t), line));
            }
            let key = format!("{prefix}/{group}/{stream}.log");
            // Export target bucket must exist; DS documents adding the
            // bucket policy during AWS setup.
            let _ = s3.put(bucket, &key, Body::Bytes(text.into_bytes()), now);
            n += 1;
        }
        n
    }

    /// DeleteLogGroup.
    pub fn delete_group(&mut self, group: &str) {
        self.groups.remove(group);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_requires_group() {
        let mut l = Logs::new();
        l.put("nope", "s", 0, "dropped");
        assert_eq!(l.line_count("nope"), 0);
        l.create_group("g");
        l.put("g", "s", 1, "kept");
        assert_eq!(l.stream("g", "s"), &[(1, "kept".to_string())]);
    }

    #[test]
    fn streams_listed_sorted() {
        let mut l = Logs::new();
        l.create_group("g");
        l.put("g", "zeta", 0, "z");
        l.put("g", "alpha", 0, "a");
        assert_eq!(l.streams("g"), vec!["alpha", "zeta"]);
        assert_eq!(l.line_count("g"), 2);
    }

    #[test]
    fn export_writes_one_object_per_stream() {
        let mut l = Logs::new();
        let mut s3 = S3::new();
        s3.create_bucket("bkt");
        l.create_group("app_perInstance");
        l.put("app_perInstance", "i-1", 0, "boot");
        l.put("app_perInstance", "i-1", 60_000, "job done");
        l.put("app_perInstance", "i-2", 0, "boot");
        let n = l.export_to_s3("app_perInstance", &mut s3, "bkt", "exportedlogs", 99);
        assert_eq!(n, 2);
        let listed = s3.list_prefix("bkt", "exportedlogs/");
        assert_eq!(listed.len(), 2);
        let obj = s3.get("bkt", "exportedlogs/app_perInstance/i-1.log").unwrap();
        let text = String::from_utf8(obj.body.bytes().unwrap().to_vec()).unwrap();
        assert!(text.contains("boot"));
        assert!(text.contains("00:01:00.000 job done"));
    }

    #[test]
    fn delete_group_removes_streams() {
        let mut l = Logs::new();
        l.create_group("g");
        l.put("g", "s", 0, "x");
        l.delete_group("g");
        assert!(!l.group_exists("g"));
        assert_eq!(l.line_count("g"), 0);
    }
}
