//! Instance lifecycle: pending → running → terminated.

use crate::sim::SimTime;

use super::pricing::InstanceType;

/// Opaque instance identifier (`i-000042` in logs).
pub type InstanceId = u64;

/// How an instance is bought, which decides how it is billed and whether
/// the spot market can reclaim it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lifecycle {
    /// Spot: billed from the per-pool price walk, interrupted whenever
    /// the pool price rises above the fleet's per-unit bid × weight.
    Spot,
    /// On-demand: billed flat at the catalog hourly price, never
    /// interrupted (the fleet's `ON_DEMAND_BASE` floor).
    OnDemand,
}

/// Why an instance stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TerminationReason {
    /// Spot price rose above the fleet's bid.
    SpotInterruption,
    /// CloudWatch alarm action (the CPU<1%-for-15-min crash reaper).
    AlarmAction,
    /// Fleet target capacity reduced (monitor downscale / cheapest mode).
    FleetDownscale,
    /// Fleet cancelled at end of run.
    FleetCancelled,
    /// The instance's workers found the queue empty and shut it down
    /// (paper: "If SQS tells them there are no visible jobs then they
    /// shut themselves down").
    SelfShutdown,
    /// Simulated hardware/OS crash (stops doing work; stays "running"
    /// until the alarm reaper notices, unless replaced).
    Crash,
    /// The instance's failure domain went dark (correlated AZ outage).
    AzOutage,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Fleet request fulfilled; machine booting (ECS agent not yet up).
    Pending,
    Running,
    Terminated,
}

/// One EC2 instance.
#[derive(Debug, Clone)]
pub struct Instance {
    pub id: InstanceId,
    pub itype: &'static InstanceType,
    pub fleet: super::fleet::FleetId,
    pub state: InstanceState,
    pub requested_at: SimTime,
    /// When the machine became Running (boot complete).
    pub running_at: Option<SimTime>,
    pub terminated_at: Option<SimTime>,
    pub termination_reason: Option<TerminationReason>,
    /// Set when a simulated crash has made the machine a zombie: it still
    /// bills but its containers stop publishing work/CPU.
    pub crashed: bool,
    /// The per-unit bid this instance was launched under (USD/h); its
    /// effective bid is `bid × weight`.
    pub bid: f64,
    /// Weighted-capacity units this instance contributes to its fleet.
    pub weight: u32,
    /// Spot (interruptible, market-billed) or on-demand (flat-billed).
    pub lifecycle: Lifecycle,
    /// Name tag assigned by the first Docker placed on it (paper: "When a
    /// Docker container gets placed it gives the instance it's on its own
    /// name").
    pub name_tag: Option<String>,
    /// Failure-domain index the instance runs in (0 = the home domain;
    /// always 0 when no topology is installed).
    pub domain: u32,
}

impl Instance {
    /// Billable lifetime [requested_at, terminated_at or `now`).
    /// Real AWS bills spot from launch to termination; we bill from
    /// `running_at` (boot time is seconds in-sim and free-ish either way).
    pub fn billable_span(&self, now: SimTime) -> Option<(SimTime, SimTime)> {
        let start = self.running_at?;
        let end = self.terminated_at.unwrap_or(now);
        (end > start).then_some((start, end))
    }

    pub fn is_active(&self) -> bool {
        matches!(self.state, InstanceState::Pending | InstanceState::Running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::pricing::instance_type;

    fn inst() -> Instance {
        Instance {
            id: 1,
            itype: instance_type("m5.large").unwrap(),
            fleet: 0,
            state: InstanceState::Pending,
            requested_at: 100,
            running_at: None,
            terminated_at: None,
            termination_reason: None,
            crashed: false,
            bid: 0.05,
            weight: 1,
            lifecycle: Lifecycle::Spot,
            name_tag: None,
            domain: 0,
        }
    }

    #[test]
    fn billable_span_requires_running() {
        let mut i = inst();
        assert_eq!(i.billable_span(1_000), None);
        i.running_at = Some(200);
        assert_eq!(i.billable_span(1_000), Some((200, 1_000)));
        i.terminated_at = Some(700);
        assert_eq!(i.billable_span(1_000), Some((200, 700)));
    }

    #[test]
    fn zero_length_span_is_none() {
        let mut i = inst();
        i.running_at = Some(500);
        i.terminated_at = Some(500);
        assert_eq!(i.billable_span(9_999), None);
    }

    #[test]
    fn active_states() {
        let mut i = inst();
        assert!(i.is_active());
        i.state = InstanceState::Running;
        assert!(i.is_active());
        i.state = InstanceState::Terminated;
        assert!(!i.is_active());
    }
}
