//! Spot market: deterministic per-pool price paths + capacity pools.
//!
//! A *capacity pool* is one instance type in the Fleet file's single
//! subnet/AZ — exactly AWS's (type, AZ) pool granularity for a
//! one-subnet fleet request.  Each pool gets an independent price path: a
//! mean-reverting random walk in log-price around
//! `spot_base_fraction × on_demand`, with occasional demand spikes that
//! multiply the price for a while (these are what interrupt fleets
//! bidding near the base).  Paths are generated lazily in fixed
//! 60-second steps from a per-pool forked RNG, so `price_at(type, t)` is
//! O(1) amortized, identical across replays, and independent of query
//! order.  Because the walks are independent, volatility hits pools
//! *unevenly* — which is what makes [`Diversified`] allocation worth
//! something (see [`super::fleet::AllocationStrategy`]).
//!
//! Capacity pools model the "if there is limited capacity for your
//! requested configuration" behaviour: a pool's free capacity shrinks
//! during spikes (other bidders took the machines), which delays fleet
//! fulfillment even when the bid clears the price.  [`snapshot`] exposes
//! a pool's joint (price, free capacity) state to the allocation
//! strategies in one query.
//!
//! With a [`crate::topology::ClusterTopology`] installed
//! ([`SpotMarket::install_domains`]) every (type, domain) pair gets its
//! own independent path — AWS's real (type, AZ) pool granularity — and
//! correlated faults ([`MarketFault`]) overlay deterministic windows on
//! one domain: an outage zeroes its free capacity, a price storm
//! multiplies its published prices.  Without a topology the market is
//! bit-identical to the pre-topology single-pool behaviour (same seeds,
//! same walks, same query results).
//!
//! [`Diversified`]: super::fleet::AllocationStrategy::Diversified
//! [`snapshot`]: SpotMarket::snapshot

use std::collections::HashMap;

use crate::sim::clock::{SimTime, MINUTE};
use crate::sim::SimRng;

use super::pricing::{instance_type, InstanceType};

/// Price-path step length.
pub const STEP: SimTime = MINUTE;

/// Machines left in a pool of `capacity` when `used` fraction is taken
/// by outside demand — the one place the capacity model lives, shared by
/// [`SpotMarket::free_capacity`] and [`SpotMarket::snapshot`].
fn free_machines(capacity: u32, used: f64) -> u32 {
    (f64::from(capacity) * (1.0 - used)).floor().max(0.0) as u32
}

/// One capacity pool's market state at an instant: everything an
/// allocation strategy ranks pools by.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolSnapshot {
    /// The pool's instance type (pool == type for a one-subnet fleet).
    pub itype: &'static str,
    /// Published spot price, USD per instance-hour.
    pub price: f64,
    /// Machines currently free in the pool.
    pub free: u32,
    /// Long-run base price the walk mean-reverts to.
    pub base: f64,
}

/// Volatility presets used by the experiments (T5 sweeps these).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Volatility {
    /// Quiet market: rare, small spikes.  Interruptions are uncommon.
    Low,
    /// 2022-typical: occasional spikes above 0.5x on-demand.
    Medium,
    /// Contended AZ: frequent spikes past on-demand parity.
    High,
}

impl Volatility {
    /// (per-step spike probability, spike multiplier range, step sigma)
    fn params(self) -> (f64, (f64, f64), f64) {
        match self {
            Volatility::Low => (0.0005, (1.3, 1.8), 0.004),
            Volatility::Medium => (0.002, (1.5, 2.8), 0.010),
            Volatility::High => (0.008, (1.8, 4.0), 0.022),
        }
    }
}

struct Path {
    /// Published (spike-inclusive) price per STEP, extended lazily.
    steps: Vec<f64>,
    /// The underlying mean-reverting walk, WITHOUT spike multipliers.
    /// Kept separate so a long spike multiplies the base level once,
    /// not compoundingly per step.
    walk: f64,
    /// Fraction of the pool consumed by outside demand, per STEP.
    pool_used: Vec<f64>,
    rng: SimRng,
    /// Remaining steps of an active spike and its multiplier.
    spike_left: u32,
    spike_mult: f64,
    base: f64,
}

impl Path {
    fn extend_to(&mut self, step_idx: usize, vol: Volatility) {
        let (p_spike, (m_lo, m_hi), sigma) = vol.params();
        while self.steps.len() <= step_idx {
            // Mean-revert the un-spiked walk in log space.
            let log_last = (self.walk / self.base).ln();
            let drift = -0.05 * log_last;
            let noise = self.rng.normal() * sigma;
            self.walk = (self.base * (log_last + drift + noise).exp())
                .max(self.base * 0.2);
            // Spikes: start with prob p_spike, last 10-120 steps, and
            // multiply the walk level while active.
            if self.spike_left == 0 && self.rng.chance(p_spike) {
                self.spike_left = self.rng.range_u64(10, 120) as u32;
                self.spike_mult = self.rng.range_f64(m_lo, m_hi);
            }
            let mut used = 0.25 + 0.1 * self.rng.normal().clamp(-2.0, 2.0);
            let price = if self.spike_left > 0 {
                self.spike_left -= 1;
                // During a spike most of the pool is taken.
                used = (used + 0.6).min(0.98);
                self.walk * self.spike_mult
            } else {
                self.walk
            };
            self.steps.push(price);
            self.pool_used.push(used.clamp(0.0, 0.98));
        }
    }
}

/// What a correlated fault does to one domain's market for a window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MarketFaultKind {
    /// Free capacity is zero for the window (running instances are the
    /// driver's problem — see `coordinator::run`).
    Outage,
    /// Published prices are multiplied by `magnitude` for the window.
    PriceStorm,
}

/// One deterministic fault window overlaying a domain's pools.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MarketFault {
    pub domain: u32,
    pub kind: MarketFaultKind,
    /// Window `[start, end)` in simulated ms (STEP-aligned in practice:
    /// TOPOLOGY files declare whole minutes).
    pub start: SimTime,
    pub end: SimTime,
    /// Price multiplier for `PriceStorm`; ignored for `Outage`.
    pub magnitude: f64,
}

/// The spot market for all instance types, keyed (domain, type).
pub struct SpotMarket {
    vol: Volatility,
    paths: HashMap<(u32, &'static str), Path>,
    seed: u64,
    /// Number of installed failure domains; 0 = no topology, which keeps
    /// the per-type RNG streams bit-identical to the pre-topology market.
    domain_count: u32,
    faults: Vec<MarketFault>,
}

impl SpotMarket {
    pub fn new(seed: u64, vol: Volatility) -> Self {
        Self {
            vol,
            paths: HashMap::new(),
            seed,
            domain_count: 0,
            faults: Vec::new(),
        }
    }

    pub fn volatility(&self) -> Volatility {
        self.vol
    }

    /// Install `n` failure domains (call before any query; the domain
    /// count is folded into each pool's RNG seed).
    pub fn install_domains(&mut self, n: u32) {
        debug_assert!(self.paths.is_empty(), "install_domains before queries");
        self.domain_count = n;
    }

    pub fn domain_count(&self) -> u32 {
        self.domain_count
    }

    /// Overlay a deterministic fault window on one domain.
    pub fn install_fault(&mut self, fault: MarketFault) {
        self.faults.push(fault);
    }

    /// Product of active price-storm multipliers on `domain` at `t`.
    fn storm_mult(&self, domain: u32, t: SimTime) -> f64 {
        self.faults
            .iter()
            .filter(|f| {
                f.domain == domain
                    && f.kind == MarketFaultKind::PriceStorm
                    && f.start <= t
                    && t < f.end
            })
            .map(|f| f.magnitude)
            .product()
    }

    /// Whether an outage window covers `domain` at `t`.
    fn outage_active(&self, domain: u32, t: SimTime) -> bool {
        self.faults.iter().any(|f| {
            f.domain == domain && f.kind == MarketFaultKind::Outage && f.start <= t && t < f.end
        })
    }

    fn path(&mut self, domain: u32, ty: &'static InstanceType) -> &mut Path {
        let seed = self.seed;
        let domained = self.domain_count > 0;
        self.paths.entry((domain, ty.name)).or_insert_with(|| {
            // Stable per-pool stream: seed ^ hash(name) without a
            // topology (bit-identical to the pre-topology market),
            // seed ^ hash("name@domain") with one.
            let fold = |h: u64, b: u8| (h ^ u64::from(b)).wrapping_mul(0x1000_0000_01b3u64);
            let mut tag = ty.name.bytes().fold(0xcbf2_9ce4_8422_2325u64, fold);
            if domained {
                tag = fold(tag, b'@');
                for b in domain.to_string().bytes() {
                    tag = fold(tag, b);
                }
            }
            let mut rng = SimRng::new(seed ^ tag);
            let base = ty.on_demand_hourly * ty.spot_base_fraction;
            // Warm start: ±5% of base.
            let p0 = base * rng.range_f64(0.95, 1.05);
            Path {
                steps: vec![p0],
                walk: p0,
                pool_used: vec![0.25],
                rng,
                spike_left: 0,
                spike_mult: 1.0,
                base,
            }
        })
    }

    /// Spot price (USD/h) of `type_name` at simulated time `t` (home
    /// domain).
    pub fn price_at(&mut self, type_name: &str, t: SimTime) -> f64 {
        self.price_at_in(0, type_name, t)
    }

    /// Spot price (USD/h) in failure domain `domain`, storm-adjusted.
    pub fn price_at_in(&mut self, domain: u32, type_name: &str, t: SimTime) -> f64 {
        let ty = instance_type(type_name).expect("unknown instance type");
        let vol = self.vol;
        let mult = self.storm_mult(domain, t);
        let idx = (t / STEP) as usize;
        let path = self.path(domain, ty);
        path.extend_to(idx, vol);
        path.steps[idx] * mult
    }

    /// Free machines of this type at time `t` (home domain).
    pub fn free_capacity(&mut self, type_name: &str, t: SimTime) -> u32 {
        self.free_capacity_in(0, type_name, t)
    }

    /// Free machines in failure domain `domain` (zero during an outage).
    pub fn free_capacity_in(&mut self, domain: u32, type_name: &str, t: SimTime) -> u32 {
        let ty = instance_type(type_name).expect("unknown instance type");
        let vol = self.vol;
        if self.outage_active(domain, t) {
            return 0;
        }
        let idx = (t / STEP) as usize;
        let path = self.path(domain, ty);
        path.extend_to(idx, vol);
        free_machines(ty.pool_capacity, path.pool_used[idx])
    }

    /// Joint (price, free-capacity) view of one pool at time `t` — a
    /// single path access where `price_at` + `free_capacity` would do
    /// two.  Allocation strategies rank these.  Home domain.
    pub fn snapshot(&mut self, type_name: &str, t: SimTime) -> PoolSnapshot {
        self.snapshot_in(0, type_name, t)
    }

    /// Joint pool view in failure domain `domain`, fault-adjusted.
    pub fn snapshot_in(&mut self, domain: u32, type_name: &str, t: SimTime) -> PoolSnapshot {
        let ty = instance_type(type_name).expect("unknown instance type");
        let vol = self.vol;
        let mult = self.storm_mult(domain, t);
        let dark = self.outage_active(domain, t);
        let idx = (t / STEP) as usize;
        let path = self.path(domain, ty);
        path.extend_to(idx, vol);
        PoolSnapshot {
            itype: ty.name,
            price: path.steps[idx] * mult,
            free: if dark {
                0
            } else {
                free_machines(ty.pool_capacity, path.pool_used[idx])
            },
            base: path.base,
        }
    }

    /// Integrate the price path over [start, end): instance-hours × $/h.
    /// This is what a terminated instance gets billed.  Home domain.
    pub fn cost_integral(&mut self, type_name: &str, start: SimTime, end: SimTime) -> f64 {
        self.cost_integral_in(0, type_name, start, end)
    }

    /// Price-path integral in failure domain `domain`, storm-adjusted.
    pub fn cost_integral_in(
        &mut self,
        domain: u32,
        type_name: &str,
        start: SimTime,
        end: SimTime,
    ) -> f64 {
        if end <= start {
            return 0.0;
        }
        let mut total = 0.0;
        let mut t = start;
        while t < end {
            let step_end = ((t / STEP) + 1) * STEP;
            let seg_end = step_end.min(end);
            let price = self.price_at_in(domain, type_name, t);
            total += price * (seg_end - t) as f64 / crate::sim::HOUR as f64;
            t = seg_end;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::HOUR;

    #[test]
    fn deterministic_and_order_independent() {
        let mut a = SpotMarket::new(1, Volatility::Medium);
        let mut b = SpotMarket::new(1, Volatility::Medium);
        // Query b in reverse order; prices must match a's.
        let times: Vec<SimTime> = (0..50).map(|i| i * 7 * MINUTE).collect();
        let pa: Vec<f64> = times.iter().map(|&t| a.price_at("m5.xlarge", t)).collect();
        let pb: Vec<f64> = times
            .iter()
            .rev()
            .map(|&t| b.price_at("m5.xlarge", t))
            .collect();
        let pb_rev: Vec<f64> = pb.into_iter().rev().collect();
        assert_eq!(pa, pb_rev);
    }

    #[test]
    fn price_near_base_in_quiet_market() {
        let mut m = SpotMarket::new(7, Volatility::Low);
        let ty = instance_type("m5.large").unwrap();
        let base = ty.on_demand_hourly * ty.spot_base_fraction;
        let mean: f64 = (0..500)
            .map(|i| m.price_at("m5.large", i * STEP))
            .sum::<f64>()
            / 500.0;
        assert!((mean / base - 1.0).abs() < 0.25, "mean={mean} base={base}");
    }

    #[test]
    fn high_volatility_spikes_above_on_demand_sometimes() {
        let mut m = SpotMarket::new(3, Volatility::High);
        let ty = instance_type("m5.xlarge").unwrap();
        let max = (0..5_000)
            .map(|i| m.price_at("m5.xlarge", i * STEP))
            .fold(0.0f64, f64::max);
        assert!(
            max > ty.on_demand_hourly * 0.8,
            "high vol never spiked: max={max}"
        );
    }

    #[test]
    fn types_have_independent_paths() {
        let mut m = SpotMarket::new(11, Volatility::Medium);
        let a: Vec<f64> = (0..20).map(|i| m.price_at("m5.large", i * STEP)).collect();
        let b: Vec<f64> = (0..20).map(|i| m.price_at("c5.xlarge", i * STEP)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn cost_integral_flat_region() {
        let mut m = SpotMarket::new(13, Volatility::Low);
        let p = m.price_at("m5.large", 0);
        // Within a single step the price is constant.
        let c = m.cost_integral("m5.large", 0, STEP);
        assert!((c - p * (STEP as f64 / HOUR as f64)).abs() < 1e-12);
        assert_eq!(m.cost_integral("m5.large", 100, 100), 0.0);
    }

    #[test]
    fn cost_integral_additive() {
        let mut m = SpotMarket::new(17, Volatility::Medium);
        let whole = m.cost_integral("m5.2xlarge", 0, 3 * HOUR);
        let parts = m.cost_integral("m5.2xlarge", 0, HOUR)
            + m.cost_integral("m5.2xlarge", HOUR, 2 * HOUR)
            + m.cost_integral("m5.2xlarge", 2 * HOUR, 3 * HOUR);
        assert!((whole - parts).abs() < 1e-9);
    }

    #[test]
    fn capacity_drops_during_spikes() {
        let mut m = SpotMarket::new(19, Volatility::High);
        let ty = instance_type("m5.large").unwrap();
        let caps: Vec<u32> = (0..5_000)
            .map(|i| m.free_capacity("m5.large", i * STEP))
            .collect();
        let min = *caps.iter().min().unwrap();
        let max = *caps.iter().max().unwrap();
        assert!(min < ty.pool_capacity / 4, "min={min}");
        assert!(max > ty.pool_capacity / 2, "max={max}");
    }

    #[test]
    fn snapshot_agrees_with_scalar_queries() {
        let mut m = SpotMarket::new(29, Volatility::Medium);
        for i in 0..200 {
            let t = i * STEP;
            let s = m.snapshot("c5.2xlarge", t);
            assert_eq!(s.price, m.price_at("c5.2xlarge", t));
            assert_eq!(s.free, m.free_capacity("c5.2xlarge", t));
            assert_eq!(s.itype, "c5.2xlarge");
        }
    }

    #[test]
    fn pools_spike_unevenly() {
        // The premise of Diversified allocation: at high volatility, the
        // instants where one pool is spiking are mostly NOT the instants
        // where another is.
        let mut m = SpotMarket::new(31, Volatility::High);
        let spiking = |m: &mut SpotMarket, ty: &str, t: SimTime| {
            let ty_ = instance_type(ty).unwrap();
            m.price_at(ty, t) > ty_.spot_base() * 1.5
        };
        let (mut a_only, mut both, mut a_any) = (0u32, 0u32, 0u32);
        for i in 0..5_000 {
            let t = i * STEP;
            let a = spiking(&mut m, "m5.large", t);
            let b = spiking(&mut m, "c5.xlarge", t);
            if a {
                a_any += 1;
                if b {
                    both += 1;
                } else {
                    a_only += 1;
                }
            }
        }
        assert!(a_any > 0, "high volatility never spiked");
        assert!(
            a_only > both,
            "independent pools should mostly spike alone: alone={a_only} together={both}"
        );
    }

    #[test]
    fn prices_always_positive() {
        let mut m = SpotMarket::new(23, Volatility::High);
        for i in 0..2_000 {
            assert!(m.price_at("r5.xlarge", i * STEP) > 0.0);
        }
    }

    #[test]
    fn legacy_queries_are_unchanged_by_the_domain_plumbing() {
        // A market without install_domains must answer exactly like the
        // pre-topology market: same seed tag, same walk, and the *_in
        // variants with domain 0 agree with the legacy methods.
        let mut m = SpotMarket::new(41, Volatility::Medium);
        for i in 0..300 {
            let t = i * STEP;
            assert_eq!(m.price_at("m5.large", t), m.price_at_in(0, "m5.large", t));
            assert_eq!(
                m.free_capacity("m5.large", t),
                m.free_capacity_in(0, "m5.large", t)
            );
        }
    }

    #[test]
    fn domains_have_independent_paths() {
        let mut m = SpotMarket::new(43, Volatility::Medium);
        m.install_domains(2);
        let a: Vec<f64> = (0..20)
            .map(|i| m.price_at_in(0, "m5.large", i * STEP))
            .collect();
        let b: Vec<f64> = (0..20)
            .map(|i| m.price_at_in(1, "m5.large", i * STEP))
            .collect();
        assert_ne!(a, b);
        // ...and deterministically so, independent of query order.
        let mut m2 = SpotMarket::new(43, Volatility::Medium);
        m2.install_domains(2);
        let b2: Vec<f64> = (0..20)
            .map(|i| m2.price_at_in(1, "m5.large", i * STEP))
            .collect();
        assert_eq!(b, b2);
    }

    #[test]
    fn outage_zeroes_capacity_only_in_window_and_domain() {
        let mut m = SpotMarket::new(47, Volatility::Low);
        m.install_domains(2);
        m.install_fault(MarketFault {
            domain: 0,
            kind: MarketFaultKind::Outage,
            start: 10 * STEP,
            end: 20 * STEP,
            magnitude: 1.0,
        });
        assert!(m.free_capacity_in(0, "m5.large", 9 * STEP) > 0);
        assert_eq!(m.free_capacity_in(0, "m5.large", 10 * STEP), 0);
        assert_eq!(m.free_capacity_in(0, "m5.large", 19 * STEP), 0);
        assert!(m.free_capacity_in(0, "m5.large", 20 * STEP) > 0);
        // The other domain is untouched.
        assert!(m.free_capacity_in(1, "m5.large", 15 * STEP) > 0);
        assert_eq!(m.snapshot_in(0, "m5.large", 15 * STEP).free, 0);
        // Outages do not move prices.
        assert_eq!(
            m.price_at_in(0, "m5.large", 15 * STEP),
            m.snapshot_in(0, "m5.large", 15 * STEP).price
        );
    }

    #[test]
    fn price_storm_multiplies_prices_in_window() {
        let mut m = SpotMarket::new(53, Volatility::Low);
        m.install_domains(2);
        let before = m.price_at_in(0, "m5.large", 15 * STEP);
        m.install_fault(MarketFault {
            domain: 0,
            kind: MarketFaultKind::PriceStorm,
            start: 10 * STEP,
            end: 20 * STEP,
            magnitude: 3.0,
        });
        let during = m.price_at_in(0, "m5.large", 15 * STEP);
        assert!((during - before * 3.0).abs() < 1e-12);
        // Outside the window and in the other domain: no effect.
        assert_eq!(m.price_at_in(0, "m5.large", 25 * STEP), {
            let mut clean = SpotMarket::new(53, Volatility::Low);
            clean.install_domains(2);
            clean.price_at_in(0, "m5.large", 25 * STEP)
        });
        // Billing integrates the storm-adjusted path.
        let c = m.cost_integral_in(0, "m5.large", 15 * STEP, 16 * STEP);
        assert!((c - during * (STEP as f64 / HOUR as f64)).abs() < 1e-12);
    }
}
