//! Elastic Compute Cloud: instance catalog, spot market, spot fleets.
//!
//! The paper's compute substrate is a *spot fleet*: a bid price, a list of
//! acceptable machine types, and a target capacity; AWS fills it from
//! whichever pools are cheap, takes "anywhere from a couple of minutes to
//! several hours" to fulfill depending on bid vs. capacity, and reclaims
//! instances whenever the spot price rises above the bid.  This module
//! reproduces each of those behaviours:
//!
//! * [`pricing`]  — the instance-type catalog (vCPU / memory / on-demand $)
//! * [`market`]   — deterministic per-pool spot price paths (mean-reverting
//!   log-walk with spikes) and finite capacity pools; one pool per
//!   instance type for the Fleet file's single subnet
//! * [`instance`] — instance lifecycle (pending → running → terminated),
//!   spot vs. on-demand
//! * [`fleet`]    — SpotFleetRequest evaluation: heterogeneous pools with
//!   weighted capacity, [`AllocationStrategy`], on-demand base,
//!   fulfillment latency, interruption, replacement, target-capacity
//!   modification, per-pool cost/interruption breakdown

pub mod fleet;
pub mod instance;
pub mod market;
pub mod pricing;

pub use fleet::{
    AllocationStrategy, DomainUsage, Ec2, FleetEvent, FleetId, InstanceSlot, PoolBreakdown,
    SpotFleetSpec,
};
pub use instance::{Instance, InstanceId, InstanceState, Lifecycle, TerminationReason};
pub use market::{MarketFault, MarketFaultKind, PoolSnapshot, SpotMarket, Volatility};
pub use pricing::{instance_type, InstanceType, INSTANCE_TYPES};
