//! Spot fleet requests: allocation, fulfillment latency, interruption,
//! replacement.
//!
//! Reproduced paper behaviours:
//!
//! * "depending on current AWS capacity and the price that you bid, it can
//!   take anywhere from a couple of minutes to several hours for your
//!   machines to be ready" — fulfillment latency grows as the bid
//!   approaches the spot price and collapses to "wait for the next
//!   evaluation" when the pool has no free capacity.
//! * Interruption: any running instance whose pool price rises above its
//!   fleet's bid is reclaimed.
//! * Replacement: an active fleet relaunches toward its target capacity
//!   whenever instances die (crash reaper, self-shutdown, interruption) —
//!   which is also the paper's cost leak that `monitor` exists to close.
//! * Cheapest mode: `modify_target` lowers the *requested* capacity
//!   without terminating running machines.

use std::collections::HashMap;

use crate::sim::clock::{SimTime, SECOND};
use crate::sim::SimRng;

use super::instance::{Instance, InstanceId, InstanceState, TerminationReason};
use super::market::SpotMarket;
use super::pricing::instance_type;

/// Fleet request identifier (`sfr-0007`).
pub type FleetId = u64;

/// A spot fleet request: what `startCluster` submits.
#[derive(Debug, Clone)]
pub struct SpotFleetSpec {
    /// CLUSTER_MACHINES from the Config file.
    pub target_capacity: u32,
    /// MACHINE_PRICE: max USD/h per machine.
    pub bid_hourly: f64,
    /// MACHINE_TYPE list; allocation picks the cheapest eligible pool.
    pub allowed_types: Vec<String>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FleetState {
    Active,
    Cancelled,
}

#[derive(Debug)]
struct Fleet {
    spec: SpotFleetSpec,
    state: FleetState,
}

/// What happened during a fleet evaluation tick.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetEvent {
    /// A new instance was requested; it becomes Running at `ready_at`.
    InstanceRequested {
        id: InstanceId,
        ready_at: SimTime,
        itype: &'static str,
        price: f64,
    },
    /// A running instance was reclaimed (spot price exceeded the bid).
    InstanceInterrupted { id: InstanceId, price: f64 },
    /// Deficit that could not be fulfilled this tick (no eligible pool).
    CapacityUnavailable { fleet: FleetId, missing: u32 },
}

/// One billed instance lifetime: written on termination.
#[derive(Debug, Clone, PartialEq)]
pub struct CostRecord {
    pub instance: InstanceId,
    pub itype: &'static str,
    pub span: (SimTime, SimTime),
    pub cost_usd: f64,
    pub reason: TerminationReason,
}

/// The EC2 service: spot market + instances + fleets.
pub struct Ec2 {
    pub market: SpotMarket,
    instances: HashMap<InstanceId, Instance>,
    fleets: HashMap<FleetId, Fleet>,
    next_instance: InstanceId,
    next_fleet: FleetId,
    rng: SimRng,
    cost_log: Vec<CostRecord>,
}

impl Ec2 {
    pub fn new(market: SpotMarket, rng: SimRng) -> Self {
        Self {
            market,
            instances: HashMap::new(),
            fleets: HashMap::new(),
            next_instance: 0,
            next_fleet: 0,
            rng,
            cost_log: Vec::new(),
        }
    }

    /// RequestSpotFleet: returns the fleet id; instances appear on the
    /// next `evaluate_fleets` call.
    pub fn request_spot_fleet(&mut self, spec: SpotFleetSpec) -> FleetId {
        for t in &spec.allowed_types {
            assert!(
                instance_type(t).is_some(),
                "unknown instance type in fleet spec: {t}"
            );
        }
        self.next_fleet += 1;
        let id = self.next_fleet;
        self.fleets.insert(
            id,
            Fleet {
                spec,
                state: FleetState::Active,
            },
        );
        id
    }

    /// ModifySpotFleetRequest: change target capacity.  Never terminates
    /// running instances (cheapest mode relies on this).
    pub fn modify_target(&mut self, fleet: FleetId, target: u32) {
        if let Some(f) = self.fleets.get_mut(&fleet) {
            f.spec.target_capacity = target;
        }
    }

    /// CancelSpotFleetRequests with TerminateInstances: end of run.
    pub fn cancel_fleet(&mut self, fleet: FleetId, now: SimTime) -> Vec<InstanceId> {
        let Some(f) = self.fleets.get_mut(&fleet) else {
            return Vec::new();
        };
        f.state = FleetState::Cancelled;
        let ids: Vec<InstanceId> = self
            .instances
            .values()
            .filter(|i| i.fleet == fleet && i.is_active())
            .map(|i| i.id)
            .collect();
        let mut ids = ids;
        ids.sort_unstable();
        for &id in &ids {
            self.terminate(id, TerminationReason::FleetCancelled, now);
        }
        ids
    }

    pub fn fleet_target(&self, fleet: FleetId) -> u32 {
        self.fleets
            .get(&fleet)
            .map(|f| f.spec.target_capacity)
            .unwrap_or(0)
    }

    pub fn fleet_is_active(&self, fleet: FleetId) -> bool {
        self.fleets
            .get(&fleet)
            .map(|f| f.state == FleetState::Active)
            .unwrap_or(false)
    }

    /// Number of non-terminated instances in a fleet.
    pub fn active_count(&self, fleet: FleetId) -> u32 {
        self.instances
            .values()
            .filter(|i| i.fleet == fleet && i.is_active())
            .count() as u32
    }

    /// All instance ids in a fleet in a given state, sorted.
    pub fn instances_in_state(&self, fleet: FleetId, state: InstanceState) -> Vec<InstanceId> {
        let mut v: Vec<InstanceId> = self
            .instances
            .values()
            .filter(|i| i.fleet == fleet && i.state == state)
            .map(|i| i.id)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn instance(&self, id: InstanceId) -> Option<&Instance> {
        self.instances.get(&id)
    }

    pub fn instance_mut(&mut self, id: InstanceId) -> Option<&mut Instance> {
        self.instances.get_mut(&id)
    }

    /// Fulfillment latency model.  Boot floor plus a "bid headroom" term:
    /// bidding barely above the price means waiting for capacity to turn
    /// over ("a couple of minutes to several hours").
    fn fulfillment_delay(rng: &mut SimRng, bid: f64, price: f64) -> SimTime {
        let boot = rng.range_u64(45 * SECOND, 120 * SECOND);
        let headroom = (bid / price - 1.0).max(0.0);
        if headroom > 0.5 {
            return boot; // comfortably above market: near-immediate
        }
        // Headroom 0..0.5 maps to an extra expected 0..~45 min wait.
        let tight = 1.0 - headroom / 0.5;
        let extra_mean = tight * tight * 45.0 * 60.0; // seconds
        let extra = rng.exp(extra_mean.max(1.0)).min(4.0 * 3_600.0);
        boot + (extra * 1_000.0) as SimTime
    }

    /// One evaluation tick: interrupt out-bid instances, then fill any
    /// deficit from the cheapest eligible pool.  The coordinator calls
    /// this on every market tick (once per simulated minute).
    pub fn evaluate_fleets(&mut self, now: SimTime) -> Vec<FleetEvent> {
        let mut events = Vec::new();

        // 1. Interruptions: price > bid.
        let mut to_interrupt: Vec<(InstanceId, f64)> = Vec::new();
        for inst in self.instances.values() {
            if !inst.is_active() {
                continue;
            }
            let price = self.market.price_at(inst.itype.name, now);
            if price > inst.bid {
                to_interrupt.push((inst.id, price));
            }
        }
        to_interrupt.sort_unstable_by_key(|&(id, _)| id);
        for (id, price) in to_interrupt {
            self.terminate(id, TerminationReason::SpotInterruption, now);
            events.push(FleetEvent::InstanceInterrupted { id, price });
        }

        // 2. Fulfillment toward target, cheapest-eligible-pool-first.
        let fleet_ids: Vec<FleetId> = {
            let mut v: Vec<FleetId> = self
                .fleets
                .iter()
                .filter(|(_, f)| f.state == FleetState::Active)
                .map(|(&id, _)| id)
                .collect();
            v.sort_unstable();
            v
        };
        for fid in fleet_ids {
            let (target, bid, types) = {
                let f = &self.fleets[&fid];
                (
                    f.spec.target_capacity,
                    f.spec.bid_hourly,
                    f.spec.allowed_types.clone(),
                )
            };
            let active = self.active_count(fid);
            if active >= target {
                continue;
            }
            let mut deficit = target - active;
            // Rank eligible pools by current price.
            let mut pools: Vec<(&'static str, f64, u32)> = types
                .iter()
                .filter_map(|t| {
                    let ty = instance_type(t)?;
                    let price = self.market.price_at(ty.name, now);
                    let free = self.market.free_capacity(ty.name, now);
                    (price <= bid && free > 0).then_some((ty.name, price, free))
                })
                .collect();
            pools.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            for (tname, price, free) in pools {
                if deficit == 0 {
                    break;
                }
                let take = deficit.min(free);
                for _ in 0..take {
                    self.next_instance += 1;
                    let id = self.next_instance;
                    let ready_at =
                        now + Self::fulfillment_delay(&mut self.rng, bid, price);
                    self.instances.insert(
                        id,
                        Instance {
                            id,
                            itype: instance_type(tname).unwrap(),
                            fleet: fid,
                            state: InstanceState::Pending,
                            requested_at: now,
                            running_at: None,
                            terminated_at: None,
                            termination_reason: None,
                            crashed: false,
                            bid,
                            name_tag: None,
                        },
                    );
                    events.push(FleetEvent::InstanceRequested {
                        id,
                        ready_at,
                        itype: tname,
                        price,
                    });
                }
                deficit -= take;
            }
            if deficit > 0 {
                events.push(FleetEvent::CapacityUnavailable {
                    fleet: fid,
                    missing: deficit,
                });
            }
        }
        events
    }

    /// Boot complete: Pending → Running.  No-op if it died while booting.
    pub fn mark_running(&mut self, id: InstanceId, now: SimTime) -> bool {
        match self.instances.get_mut(&id) {
            Some(i) if i.state == InstanceState::Pending => {
                i.state = InstanceState::Running;
                i.running_at = Some(now);
                true
            }
            _ => false,
        }
    }

    /// TerminateInstances: bill and mark.  Idempotent.
    pub fn terminate(&mut self, id: InstanceId, reason: TerminationReason, now: SimTime) {
        let Some(inst) = self.instances.get_mut(&id) else {
            return;
        };
        if inst.state == InstanceState::Terminated {
            return;
        }
        inst.state = InstanceState::Terminated;
        inst.terminated_at = Some(now);
        inst.termination_reason = Some(reason);
        let itype = inst.itype.name;
        // AWS bills Linux spot per-second with a 60-second minimum: even
        // a boot-poll-shutdown instance costs a billing minute (this is
        // what makes unmonitored churn expensive — experiment T3/T7).
        if let Some(start) = inst.running_at {
            let end = now.max(start + crate::sim::MINUTE);
            let cost = self.market.cost_integral(itype, start, end);
            self.cost_log.push(CostRecord {
                instance: id,
                itype,
                span: (start, end),
                cost_usd: cost,
                reason,
            });
        }
    }

    /// Billed instance lifetimes so far.
    pub fn cost_log(&self) -> &[CostRecord] {
        &self.cost_log
    }

    /// Bill any still-running instances up to `now` (end-of-run report for
    /// scenarios that never tear down).
    pub fn accrued_cost_of_active(&mut self, now: SimTime) -> f64 {
        let spans: Vec<(&'static str, SimTime, SimTime)> = self
            .instances
            .values()
            .filter(|i| i.is_active())
            .filter_map(|i| i.billable_span(now).map(|(s, e)| (i.itype.name, s, e)))
            .collect();
        spans
            .into_iter()
            .map(|(t, s, e)| self.market.cost_integral(t, s, e))
            .sum()
    }

    /// All instances (sorted by id) — used by reports and tests.
    pub fn all_instances(&self) -> Vec<&Instance> {
        let mut v: Vec<&Instance> = self.instances.values().collect();
        v.sort_by_key(|i| i.id);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::market::Volatility;
    use crate::sim::{HOUR, MINUTE};

    fn ec2(vol: Volatility, seed: u64) -> Ec2 {
        Ec2::new(SpotMarket::new(seed, vol), SimRng::new(seed ^ 0xEC2))
    }

    fn spec(n: u32, bid: f64) -> SpotFleetSpec {
        SpotFleetSpec {
            target_capacity: n,
            bid_hourly: bid,
            allowed_types: vec!["m5.large".into()],
        }
    }

    #[test]
    fn fleet_fulfills_to_target() {
        let mut e = ec2(Volatility::Low, 1);
        let fid = e.request_spot_fleet(spec(8, 0.09));
        let evs = e.evaluate_fleets(0);
        let launched = evs
            .iter()
            .filter(|ev| matches!(ev, FleetEvent::InstanceRequested { .. }))
            .count();
        assert_eq!(launched, 8);
        assert_eq!(e.active_count(fid), 8);
        // Second tick: no extra launches.
        assert!(e.evaluate_fleets(MINUTE).is_empty());
    }

    #[test]
    fn low_bid_gets_no_machines() {
        let mut e = ec2(Volatility::Low, 2);
        let fid = e.request_spot_fleet(spec(4, 0.001)); // far below base
        let evs = e.evaluate_fleets(0);
        assert!(matches!(
            evs.as_slice(),
            [FleetEvent::CapacityUnavailable { missing: 4, .. }]
        ));
        assert_eq!(e.active_count(fid), 0);
    }

    #[test]
    fn high_bid_fulfills_faster_than_tight_bid() {
        // Statistical: mean ready_at over many instances.
        let mean_delay = |bid: f64, seed: u64| -> f64 {
            let mut e = ec2(Volatility::Low, seed);
            e.request_spot_fleet(SpotFleetSpec {
                target_capacity: 50,
                bid_hourly: bid,
                allowed_types: vec!["m5.large".into()],
            });
            let evs = e.evaluate_fleets(0);
            let delays: Vec<f64> = evs
                .iter()
                .filter_map(|ev| match ev {
                    FleetEvent::InstanceRequested { ready_at, .. } => {
                        Some(*ready_at as f64)
                    }
                    _ => None,
                })
                .collect();
            delays.iter().sum::<f64>() / delays.len() as f64
        };
        let base = 0.096 * 0.31;
        let tight = mean_delay(base * 1.02, 3);
        let comfy = mean_delay(base * 2.0, 3);
        assert!(
            tight > comfy * 2.0,
            "tight bid should wait longer: tight={tight} comfy={comfy}"
        );
    }

    #[test]
    fn interruption_when_price_exceeds_bid() {
        // High volatility + bid at base: must eventually interrupt.
        let mut e = ec2(Volatility::High, 5);
        let base = 0.096 * 0.31;
        let fid = e.request_spot_fleet(spec(4, base * 1.05));
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        let mut interrupted = 0;
        for k in 1..(48 * 60) {
            let evs = e.evaluate_fleets(k * MINUTE);
            interrupted += evs
                .iter()
                .filter(|ev| matches!(ev, FleetEvent::InstanceInterrupted { .. }))
                .count();
            for ev in &evs {
                if let FleetEvent::InstanceRequested { id, .. } = ev {
                    e.mark_running(*id, k * MINUTE + 1);
                }
            }
        }
        assert!(interrupted > 0, "48h of high volatility, no interruptions?");
        // Fleet kept replacing: still near target at the end.
        assert!(e.active_count(fid) >= 3);
    }

    #[test]
    fn terminate_bills_once() {
        let mut e = ec2(Volatility::Low, 7);
        let _fid = e.request_spot_fleet(spec(1, 0.09));
        let evs = e.evaluate_fleets(0);
        let id = match &evs[0] {
            FleetEvent::InstanceRequested { id, .. } => *id,
            _ => panic!(),
        };
        e.mark_running(id, MINUTE);
        e.terminate(id, TerminationReason::SelfShutdown, HOUR);
        e.terminate(id, TerminationReason::SelfShutdown, 2 * HOUR); // no double bill
        assert_eq!(e.cost_log().len(), 1);
        let rec = &e.cost_log()[0];
        assert_eq!(rec.reason, TerminationReason::SelfShutdown);
        // ~59 minutes of m5.large spot ≈ base price
        assert!(rec.cost_usd > 0.0 && rec.cost_usd < 0.096);
    }

    #[test]
    fn modify_target_does_not_kill_running() {
        let mut e = ec2(Volatility::Low, 9);
        let fid = e.request_spot_fleet(spec(6, 0.09));
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        e.modify_target(fid, 1); // cheapest mode
        e.evaluate_fleets(2 * MINUTE);
        assert_eq!(e.active_count(fid), 6, "cheapest mode must not terminate");
        // But a death is not replaced.
        let victim = e.instances_in_state(fid, InstanceState::Running)[0];
        e.terminate(victim, TerminationReason::Crash, 3 * MINUTE);
        e.evaluate_fleets(4 * MINUTE);
        assert_eq!(e.active_count(fid), 5);
    }

    #[test]
    fn cancel_fleet_terminates_everything() {
        let mut e = ec2(Volatility::Low, 11);
        let fid = e.request_spot_fleet(spec(5, 0.09));
        e.evaluate_fleets(0);
        let killed = e.cancel_fleet(fid, 10 * MINUTE);
        assert_eq!(killed.len(), 5);
        assert_eq!(e.active_count(fid), 0);
        // Cancelled fleet never relaunches.
        assert!(e.evaluate_fleets(11 * MINUTE).is_empty());
    }

    #[test]
    fn replacement_after_alarm_termination() {
        let mut e = ec2(Volatility::Low, 13);
        let fid = e.request_spot_fleet(spec(3, 0.09));
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        let victim = e.instances_in_state(fid, InstanceState::Running)[0];
        e.terminate(victim, TerminationReason::AlarmAction, 5 * MINUTE);
        assert_eq!(e.active_count(fid), 2);
        let evs = e.evaluate_fleets(6 * MINUTE);
        assert_eq!(
            evs.iter()
                .filter(|ev| matches!(ev, FleetEvent::InstanceRequested { .. }))
                .count(),
            1
        );
        assert_eq!(e.active_count(fid), 3);
    }

    #[test]
    fn allocation_prefers_cheapest_pool() {
        let mut e = ec2(Volatility::Low, 15);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 2,
            bid_hourly: 0.50,
            allowed_types: vec!["m5.2xlarge".into(), "m5.large".into()],
        });
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            let t = e.instance(id).unwrap().itype.name;
            assert_eq!(t, "m5.large", "should pick the cheaper pool");
        }
    }

    #[test]
    fn unknown_type_panics() {
        let mut e = ec2(Volatility::Low, 17);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e.request_spot_fleet(SpotFleetSpec {
                target_capacity: 1,
                bid_hourly: 1.0,
                allowed_types: vec!["quantum.9000xl".into()],
            })
        }));
        assert!(r.is_err());
    }
}
